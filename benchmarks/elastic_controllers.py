"""Elastic replica lifecycle: the SLA-vs-cost frontier as a benchmark.

Every arm replays the ``scale_up`` 10x load step (same seed, same
arrival draws) and differs only in the control law:

- **epoch_baseline**: the registry ``scale_up`` scenario — the
  epoch-boundary ``QueueTargetAutoscaler``, instantaneous and free,
  one decision per epoch.
- **controller arms**: the ``sim.elastic`` mid-run controllers (step /
  proportional / cost_weighted) ticking every second, paying a real
  cold start per provisioned replica and draining before every
  decommission.  The frontier sweeps controller kind x
  ``target_queue_ms`` x ``cold_start_ms`` x ``max_replicas``, plus
  burst and diurnal workload arms where epoch-boundary scaling cannot
  act at all (single-epoch trace workloads).

Each row reports pooled attainment against replica-seconds (the cost
axis), the per-epoch replica trajectory, and the provision/
decommission/lost counters.

Two tier-1-visible gates ride on the rows (``benchmarks/run.py
--smoke`` fails if either regresses):

- **zero-loss drain**: across every elastic arm, no in-flight request
  is ever lost to scale-in (``n_arrived == n_completed + n_rejected``
  in every epoch) — decommission waits for the queue to empty, by
  construction.
- **mid-run beats epoch**: the proportional controller capped at 3
  replicas clears the epoch baseline's pooled attainment at *lower*
  replica-seconds, despite paying 500 ms cold starts the baseline
  gets for free (full scale: 0.936 vs 0.916 attainment at 228 vs 250
  replica-seconds).

``--json`` at full scale writes ``BENCH_elastic_controllers.json``.
"""
from __future__ import annotations

from dataclasses import replace
from typing import List, Tuple

from repro.scenario.build import build
from repro.scenario.registry import elastic_scenario, get_scenario

# Fast mode scales the workload AND the controller's time knobs
# (control interval, cold start) by the same factor, so the
# ticks-per-epoch geometry — and with it the frontier shape — survives
# at smoke scale (the drift_resilience convention).
FAST_SCALE = 0.3
FULL_N = 2000


def _with_autoscaler(sc, **kw):
    dep = sc.deployment
    return replace(sc, deployment=replace(
        dep, autoscaler=replace(dep.autoscaler, **kw)))


def _run(sc):
    """Run a scenario end to end; return (pooled attainment,
    replica-seconds, lost in-flight requests, row fields)."""
    out = build(sc).run()
    rep_s = sum(e.result.replica_seconds for e in out.epochs)
    lost = sum(e.result.n_arrived - e.result.n_completed
               - e.result.n_rejected for e in out.epochs)
    prov = sum(e.result.n_provisioned for e in out.epochs)
    deco = sum(e.result.n_decommissioned for e in out.epochs)
    att = out.sla_attainment
    reps = "/".join(str(r) for r in out.replica_history)
    derived = (f"attain={att:.4f};replica_s={rep_s:.1f};"
               f"replicas={reps};acc={out.mean_accuracy:.3f};"
               f"provisioned={prov};decommissioned={deco};lost={lost}")
    return att, rep_s, lost, (out.mean_latency * 1e3, derived)


def bench_rows(fast: bool = False) -> List[Tuple[str, float, str]]:
    s = FAST_SCALE if fast else 1.0
    n = int(FULL_N * s)
    kw = dict(control_interval_ms=1_000.0 * s, cold_start_ms=500.0 * s,
              n_requests=n, name="bench_elastic")
    prop = elastic_scenario(kind="proportional", **kw)
    arms: List[Tuple[str, object]] = [
        ("step", elastic_scenario(kind="step", **kw)),
        ("proportional", prop),
        ("cost_weighted_c0.5", elastic_scenario(
            kind="cost_weighted", cost_per_replica_s=0.5, **kw)),
        # The gate arm: capped capacity forces the frontier point that
        # beats the epoch baseline on BOTH axes.
        ("proportional_max3", _with_autoscaler(prop, max_replicas=3)),
    ]
    if not fast:
        arms += [
            ("proportional_target10", elastic_scenario(
                kind="proportional", target_queue_ms=10.0, **kw)),
            ("proportional_target50", elastic_scenario(
                kind="proportional", target_queue_ms=50.0, **kw)),
            ("proportional_cold0", _with_autoscaler(prop,
                                                    cold_start_ms=0.0)),
            ("proportional_cold2000", _with_autoscaler(
                prop, cold_start_ms=2_000.0)),
        ]
    # Trace-shaped workloads are single-epoch, so the epoch-boundary
    # autoscaler never gets to act — only a mid-run controller can
    # follow a flash crowd or a diurnal swing.
    wl = prop.workload
    arms.append(("burst_proportional", replace(
        prop, workload=replace(
            wl, arrival="burst", rate_schedule=(), epochs=1,
            rate_rps=4.0, burst_rate_rps=80.0, burst_every_ms=10_000.0,
            burst_len_ms=1_500.0,
            n_requests=min(n, 1500)))))
    if not fast:
        arms.append(("diurnal_proportional", replace(
            prop, workload=replace(
                wl, arrival="diurnal", rate_schedule=(), epochs=1,
                rate_rps=12.0, period_ms=20_000.0, amplitude=0.9,
                n_requests=min(n, 1500)))))

    # The epoch-boundary baseline, at the same scale as the arms.
    base = get_scenario("scale_up")
    base = replace(base, workload=replace(base.workload, n_requests=n))
    base_att, base_rep_s, base_lost, (lat, derived) = _run(base)
    rows = [("elastic_controllers/epoch_baseline", lat, derived)]

    gate = None
    total_lost = base_lost
    for label, sc in arms:
        att, rep_s, lost, (lat, derived) = _run(sc)
        total_lost += lost
        if label == "proportional_max3":
            gate = (att, rep_s)
        rows.append((f"elastic_controllers/{label}", lat, derived))

    # Gate 1: drain-based scale-in never loses an in-flight request.
    assert total_lost == 0, \
        f"{total_lost} in-flight requests lost to scale-in"
    # Gate 2: the mid-run controller beats epoch-boundary scaling on
    # the 10x step — higher pooled attainment at lower replica-seconds,
    # cold starts included.
    att, rep_s = gate
    assert att > base_att, \
        (f"mid-run attainment {att:.4f} <= epoch-boundary "
         f"baseline {base_att:.4f}")
    assert rep_s < base_rep_s, \
        (f"mid-run replica-seconds {rep_s:.1f} >= epoch-boundary "
         f"baseline {base_rep_s:.1f}")
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in bench_rows():
        print(f"{row[0]},{row[1]:.3f},{row[2]}")
