"""Roofline benchmark: reads the dry-run artifacts and emits per-cell
roofline terms (the §Roofline table), plus kernel micro-benchmarks."""
from __future__ import annotations

import glob
import json
import os
import time
from typing import List, Tuple

Row = Tuple[str, float, str]

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def roofline_rows(mesh: str = "single") -> List[Row]:
    rows: List[Row] = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("status") != "ok":
            rows.append((f"roofline/{r['arch']}/{r['shape']}/{mesh}", 0.0, "FAILED"))
            continue
        ro = r["roofline"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{mesh}",
            ro["compute_s"] * 1e6,
            f"compute_s={ro['compute_s']:.4g};memory_s={ro['memory_s']:.4g};"
            f"collective_s={ro['collective_s']:.4g};dominant={ro['dominant']};"
            f"useful={ro['useful_flops_ratio']:.3f};mfu_bound={ro['mfu_bound']:.4f}"))
    return rows


def kernel_micro(seq_len: int = 512) -> List[Row]:
    """Interpret-mode kernel micro-bench (CPU): correctness-path timing +
    analytic TPU roofline estimate per kernel.  ``seq_len`` scales the
    problem down for the --smoke harness."""
    import jax
    import jax.numpy as jnp
    from repro.distributed.hlo import HBM_BW, PEAK_FLOPS_BF16
    from repro.kernels import ops

    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, KV, S, hd = 1, 4, 2, seq_len, 64
    blk = min(128, seq_len)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=blk, block_k=blk)
    out.block_until_ready()
    t0 = time.perf_counter()
    ops.flash_attention(q, k, v, block_q=blk, block_k=blk).block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    flops = 4 * B * H * S * S * hd * 0.5  # causal
    tpu_est_us = flops / PEAK_FLOPS_BF16 * 1e6
    rows.append((f"kernel/flash_attention_{S}", us,
                 f"flops={flops:.3g};tpu_roofline_us={tpu_est_us:.2f}"))
    return rows
