"""Premodel & tail-SLA routing: the conditional-profile story as a
benchmark.

Two studies, both over ``scenario.registry`` families:

- **premodel_mix**: a half-easy/half-hard input mix under one SLA.
  Arms: ``none`` (unconditional profiles — the historical router),
  ``centroid`` (online nearest-centroid premodel + per-class
  conditional profiles), ``oracle`` (frozen true-class ablation — the
  classifier-quality ceiling).  All three replay the *identical*
  workload (same salted class/feature/scale assignment, same arrival
  and service draws), so accuracy deltas are attributable to
  conditioning alone.
- **tail_sla**: 20% of inferences run 3.5x slow.  Arms: mean-based
  budgets (the paper's EWMA presentation) vs streaming-p95 budgets
  (``PolicySpec.latency_quantile=0.95``), measuring SLA attainment
  against the spike tail.

Both acceptance gates are asserted here and therefore visible to
tier-1 via ``benchmarks/run.py --smoke``: the conditional arm must buy
>= +0.02 mean accuracy over the unconditional arm at attainment within
0.01 on ``premodel_mix``, and the quantile arm must beat the mean arm
on SLA attainment on ``tail_sla``.  ``--json`` at full scale writes
``BENCH_premodel.json``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.scenario.build import build

# Smoke scale: large enough that the learning transients (the premodel
# discovering per-class truth, the p95 trackers warming past the
# Gaussian fallback) wash out and both assertions hold with margin.
FAST_N = 2000


def _run(scenario):
    return build(scenario).run()


def premodel_rows(fast: bool = False) -> List[Tuple[str, float, str]]:
    from repro.scenario.registry import premodel_scenario

    kw = dict(n_requests=FAST_N) if fast else {}
    rows: List[Tuple[str, float, str]] = []
    arms: Dict[str, object] = {}
    for arm in ("none", "centroid", "oracle"):
        sc = premodel_scenario(premodel=arm, name=f"bench_premodel_{arm}",
                               **kw)
        r = _run(sc)
        arms[arm] = r
        res = r.result
        rows.append((
            f"premodel/mix_{arm}",
            res.mean_latency * 1e3,
            f"attain={r.sla_attainment:.4f};acc={r.mean_accuracy:.4f};"
            f"p95={res.p95_latency:.1f};p99={res.p99_latency:.1f};"
            f"wait_p95={res.p95_queue_wait:.1f}"))

    # The conditional-routing guarantee: >= +0.02 accuracy at the same
    # attainment (within 0.01), per-input-class conditioning paying for
    # itself without shedding or missing more.
    cond, uncond = arms["centroid"], arms["none"]
    d_acc = cond.mean_accuracy - uncond.mean_accuracy
    d_att = cond.sla_attainment - uncond.sla_attainment
    assert d_acc >= 0.02, \
        (f"conditional routing accuracy gain {d_acc:+.4f} < +0.02 "
         f"({cond.mean_accuracy:.4f} vs {uncond.mean_accuracy:.4f})")
    assert abs(d_att) <= 0.01, \
        (f"conditional routing moved attainment by {d_att:+.4f} "
         f"(> 0.01): {cond.sla_attainment:.4f} vs "
         f"{uncond.sla_attainment:.4f}")
    return rows


def tail_rows(fast: bool = False) -> List[Tuple[str, float, str]]:
    from repro.scenario.registry import tail_sla_scenario

    kw = dict(n_requests=FAST_N) if fast else {}
    rows: List[Tuple[str, float, str]] = []
    arms: Dict[str, object] = {}
    for label, q in (("p95", 0.95), ("mean", None)):
        sc = tail_sla_scenario(quantile=q, name=f"bench_tail_{label}", **kw)
        r = _run(sc)
        arms[label] = r
        res = r.result
        rows.append((
            f"premodel/tail_sla_{label}",
            res.mean_latency * 1e3,
            f"attain={r.sla_attainment:.4f};acc={r.mean_accuracy:.4f};"
            f"p95={res.p95_latency:.1f};p99={res.p99_latency:.1f};"
            f"wait_p95={res.p95_queue_wait:.1f}"))

    # The tail-budget guarantee: judging eligibility/admission at the
    # streaming p95 beats the mean-based budget on SLA attainment when
    # the latency distribution has a real tail (measured ~+0.02).
    d = arms["p95"].sla_attainment - arms["mean"].sla_attainment
    assert d >= 0.005, \
        (f"quantile budgets did not beat mean budgets on attainment: "
         f"{arms['p95'].sla_attainment:.4f} vs "
         f"{arms['mean'].sla_attainment:.4f} ({d:+.4f})")
    return rows


def bench_rows(fast: bool = False) -> List[Tuple[str, float, str]]:
    return premodel_rows(fast=fast) + tail_rows(fast=fast)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in bench_rows():
        print(f"{row[0]},{row[1]:.3f},{row[2]}")
