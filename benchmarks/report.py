"""Regenerate the EXPERIMENTS.md roofline tables from dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.report [--dir benchmarks/results/dryrun]
"""
import argparse
import glob
import json
import os


def load(dir_, mesh):
    rows = {}
    for f in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        with open(f) as fh:
            r = json.load(fh)
        rows[(r["arch"], r["shape"])] = r
    return rows


def table(rows, title):
    out = [f"### {title}", "",
           "| arch | shape | compute | memory | collective | dominant | useful | MFU bound |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(rows.items()):
        if r.get("status") != "ok":
            out.append(f"| {arch} | {shape} | — | — | — | FAILED | — | — |")
            continue
        ro = r["roofline"]
        out.append(
            f"| {arch} | {shape} | {ro['compute_s']*1e3:.2f} ms | "
            f"{ro['memory_s']*1e3:.2f} ms | {ro['collective_s']*1e3:.2f} ms | "
            f"{ro['dominant']} | {ro['useful_flops_ratio']:.2f} | "
            f"{ro['mfu_bound']:.4f} |")
    return "\n".join(out)


def compare(base, opt):
    out = ["### Baseline → optimized (single-pod)", "",
           "| arch | shape | step bound before | after | × | dominant before → after |",
           "|---|---|---|---|---|---|"]
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        if b.get("status") != "ok" or o.get("status") != "ok":
            continue
        bs = max(b["roofline"][k] for k in ("compute_s", "memory_s", "collective_s"))
        os_ = max(o["roofline"][k] for k in ("compute_s", "memory_s", "collective_s"))
        out.append(
            f"| {key[0]} | {key[1]} | {bs*1e3:.2f} ms | {os_*1e3:.2f} ms | "
            f"{bs/os_:.2f}× | {b['roofline']['dominant']} → {o['roofline']['dominant']} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--baseline", default="benchmarks/results/dryrun_baseline")
    args = ap.parse_args()
    single = load(args.dir, "single")
    multi = load(args.dir, "multi")
    base = load(args.baseline, "single")
    print(table(single, "Roofline — single pod (16×16 = 256 chips), optimized"))
    print()
    if multi:
        print(table(multi, "Roofline — multi-pod (2×16×16 = 512 chips), optimized"))
        print()
    if base:
        print(compare(base, single))


if __name__ == "__main__":
    main()
