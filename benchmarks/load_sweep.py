"""Arrival-rate sweep: SLA attainment vs offered load, per policy.

Beyond-paper benchmark on the discrete-event serving simulator
(``repro.sim``): open-loop Poisson traffic over the paper's Table-2 zoo
with one endpoint per model, swept across arrival rates.  Queue-blind
policies (the paper's, unchanged) collapse once their favourite
endpoints saturate; queue-aware ModiPick folds W_queue(m) into the
budget and trades accuracy for attainment instead.

Rows: ``load_sweep/<policy>/rate_<rps>`` with attainment, accuracy,
p99 end-to-end latency, mean queue wait, and rejections.
"""
from __future__ import annotations

from typing import List, Tuple

SLA_MS = 250.0
RATES_RPS = (2.0, 5.0, 10.0, 20.0, 40.0, 80.0)
N_REQUESTS = 1500
SEED = 7


def _policies():
    from repro.core.policy import DynamicGreedy, ModiPick, StaticGreedy
    return [
        ("modipick", lambda: ModiPick(t_threshold=20.0), False),
        ("qa_modipick", lambda: ModiPick(t_threshold=20.0), True),
        ("dynamic_greedy", lambda: DynamicGreedy(), False),
        ("qa_dynamic_greedy", lambda: DynamicGreedy(), True),
        ("static_greedy", lambda: StaticGreedy(SLA_MS), False),
    ]


def sweep_rows(rates=RATES_RPS, t_sla: float = SLA_MS,
               n_requests: int = N_REQUESTS, seed: int = SEED
               ) -> List[Tuple[str, float, str]]:
    from repro.core.netmodel import NetworkModel
    from repro.core.zoo import TABLE2
    from repro.sim.arrivals import PoissonArrivals
    from repro.sim.engine import ServingSimulator
    from repro.sim.replica import per_model_replicas

    net = NetworkModel(50.0, 25.0)
    rows = []
    for name, policy_fn, queue_aware in _policies():
        for rate in rates:
            sim = ServingSimulator(
                TABLE2, net, per_model_replicas(TABLE2), seed=seed,
                queue_aware=queue_aware)
            r = sim.run(policy_fn(), t_sla, n_requests,
                        arrivals=PoissonArrivals(rate))
            rows.append((
                f"load_sweep/{name}/rate_{rate:g}",
                r.mean_latency * 1e3,  # us_per_call column: e2e in us
                f"attain={r.sla_attainment:.3f};acc={r.mean_accuracy:.3f};"
                f"p99_ms={r.p99_latency:.1f};qwait_ms={r.mean_queue_wait:.1f};"
                f"rejected={r.n_rejected}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in sweep_rows():
        print(f"{row[0]},{row[1]:.3f},{row[2]}")
