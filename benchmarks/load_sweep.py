"""Arrival-rate sweep: SLA attainment vs offered load, per policy — the
admission-policy axis (shed-vs-degrade frontier) — and the vectorized
SLA-frontier sweep driven straight through ``select_batch``.

Beyond-paper benchmark on the discrete-event serving simulator
(``repro.sim``): open-loop Poisson traffic over the paper's Table-2 zoo
with one endpoint per model, swept across arrival rates.  Queue-blind
policies (the paper's, unchanged) collapse once their favourite
endpoints saturate; queue-aware ModiPick folds W_queue(m) into the
budget and trades accuracy for attainment instead.  The admission axis
sweeps queue-aware ModiPick under three shedding regimes — none,
substrate depth-cap, and router-side SLA-aware — recording the
shed-vs-degrade frontier (how much traffic each mode drops vs how much
accuracy/attainment the survivors keep).

Rows: ``load_sweep/<policy>/rate_<rps>`` with attainment, accuracy,
p99 end-to-end latency, mean queue wait, and rejections;
``load_sweep/admission_<mode>/rate_<rps>`` for the admission axis;
``sla_frontier/<policy>/sla_<ms>`` for the batched frontier.
"""
from __future__ import annotations

from typing import List, Tuple

SLA_MS = 250.0
RATES_RPS = (2.0, 5.0, 10.0, 20.0, 40.0, 80.0)
N_REQUESTS = 1500
SEED = 7

ADMISSION_RATES = (10.0, 20.0, 40.0, 80.0)
ADMISSION_DEPTH_CAP = 3

FRONTIER_SLAS = (100.0, 150.0, 250.0, 400.0)
FRONTIER_BATCH = 50_000


def _policies():
    from repro.core.policy import DynamicGreedy, ModiPick, StaticGreedy
    return [
        ("modipick", lambda: ModiPick(t_threshold=20.0), False),
        ("qa_modipick", lambda: ModiPick(t_threshold=20.0), True),
        ("dynamic_greedy", lambda: DynamicGreedy(), False),
        ("qa_dynamic_greedy", lambda: DynamicGreedy(), True),
        ("static_greedy", lambda: StaticGreedy(SLA_MS), False),
    ]


def sweep_rows(rates=RATES_RPS, t_sla: float = SLA_MS,
               n_requests: int = N_REQUESTS, seed: int = SEED
               ) -> List[Tuple[str, float, str]]:
    from repro.core.netmodel import NetworkModel
    from repro.core.zoo import TABLE2
    from repro.sim.arrivals import PoissonArrivals
    from repro.sim.engine import ServingSimulator
    from repro.sim.replica import per_model_replicas

    net = NetworkModel(50.0, 25.0)
    rows = []
    for name, policy_fn, queue_aware in _policies():
        for rate in rates:
            sim = ServingSimulator(
                TABLE2, net, per_model_replicas(TABLE2), seed=seed,
                queue_aware=queue_aware)
            r = sim.run(policy_fn(), t_sla, n_requests,
                        arrivals=PoissonArrivals(rate))
            rows.append((
                f"load_sweep/{name}/rate_{rate:g}",
                r.mean_latency * 1e3,  # us_per_call column: e2e in us
                f"attain={r.sla_attainment:.3f};acc={r.mean_accuracy:.3f};"
                f"p99_ms={r.p99_latency:.1f};qwait_ms={r.mean_queue_wait:.1f};"
                f"rejected={r.n_rejected}"))
    return rows


def admission_rows(rates=ADMISSION_RATES, t_sla: float = SLA_MS,
                   n_requests: int = N_REQUESTS, seed: int = SEED
                   ) -> List[Tuple[str, float, str]]:
    """Shed-vs-degrade frontier: queue-aware ModiPick under three
    admission regimes.  ``none`` degrades only (serves everything,
    eats the queueing delay), ``depth_cap`` sheds on substrate
    back-pressure after selection, ``sla_aware`` sheds router-side
    before selection whenever no model can meet the remaining budget."""
    from repro.core.netmodel import NetworkModel
    from repro.core.policy import ModiPick
    from repro.core.zoo import TABLE2
    from repro.router import SlaAwareAdmission
    from repro.sim.arrivals import PoissonArrivals
    from repro.sim.engine import ServingSimulator
    from repro.sim.replica import per_model_replicas

    net = NetworkModel(50.0, 25.0)
    modes = [
        ("none", None, None),
        ("depth_cap", ADMISSION_DEPTH_CAP, None),
        ("sla_aware", None, SlaAwareAdmission()),
    ]
    rows = []
    for mode, cap, admission in modes:
        for rate in rates:
            sim = ServingSimulator(
                TABLE2, net, per_model_replicas(TABLE2, max_queue_depth=cap),
                seed=seed, queue_aware=True, admission=admission)
            r = sim.run(ModiPick(t_threshold=20.0), t_sla, n_requests,
                        arrivals=PoissonArrivals(rate))
            rows.append((
                f"load_sweep/admission_{mode}/rate_{rate:g}",
                r.mean_latency * 1e3,
                f"attain={r.sla_attainment:.3f};acc={r.mean_accuracy:.3f};"
                f"shed={r.n_rejected / max(r.n_arrived, 1):.3f};"
                f"p99_ms={r.p99_latency:.1f};"
                f"qwait_ms={r.mean_queue_wait:.1f}"))
    return rows


def frontier_rows(slas=FRONTIER_SLAS, n: int = FRONTIER_BATCH,
                  seed: int = SEED) -> List[Tuple[str, float, str]]:
    """Accuracy/attainment frontier per SLA, computed by the vectorized
    policy engine: ``n`` network draws per SLA point go through one
    ``select_batch`` call and are scored against the true latency process
    — the MDInference-style frontier at selection scales the sequential
    closed loop cannot afford."""
    import time

    import numpy as np

    from repro.core.policy import DynamicGreedy, ModiPick
    from repro.core.zoo import TABLE2, make_store, true_profiles

    store = make_store(TABLE2)
    tab = store.table()
    truth = true_profiles(TABLE2)
    mu_true = np.array([truth[nm].mu_ms for nm in tab.names])
    sig_true = np.array([truth[nm].sigma_ms for nm in tab.names])
    acc_true = np.array([truth[nm].top1 / 100.0 for nm in tab.names])

    rows = []
    rng = np.random.default_rng(seed)
    for sla in slas:
        t_input = np.clip(rng.normal(50.0, 25.0, size=n), 0.0, None)
        budgets = sla - 2.0 * t_input
        for name, pol in [("modipick", ModiPick(t_threshold=20.0)),
                          ("dynamic_greedy", DynamicGreedy())]:
            # Untimed warm-up on a throwaway rng: the auto backend's
            # fused path jit-compiles once per (pool, batch-bucket);
            # the rows record steady-state selections/sec, and the
            # measured rng stream is untouched.
            pol.select_batch(store, budgets, np.random.default_rng(0))
            t0 = time.perf_counter()
            names = pol.select_batch(store, budgets, rng)
            dt = time.perf_counter() - t0
            idx = np.array([tab.index[nm] for nm in names])
            lat = np.maximum(0.05, rng.normal(mu_true[idx], sig_true[idx]))
            e2e = 2.0 * t_input + lat
            rows.append((
                f"sla_frontier/{name}/sla_{sla:g}", dt / n * 1e6,
                f"attain={(e2e <= sla).mean():.3f};"
                f"acc={acc_true[idx].mean():.3f};"
                f"selps={n / dt:.0f}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in sweep_rows() + admission_rows() + frontier_rows():
        print(f"{row[0]},{row[1]:.3f},{row[2]}")
