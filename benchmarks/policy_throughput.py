"""Selection-throughput microbenchmark: scalar loop vs numpy-batched vs
jitted/Pallas ModiPick on the Table-2 zoo.

The paper puts selection on the hot path of every inference (§3.3), so
selections/sec bounds how much traffic one router can carry and how big
a sweep the simulators can afford.  Rows:

    policy_throughput/<impl>/batch_<B>

with ``us_per_call`` = microseconds per selection and ``derived``
carrying ``selps`` (selections/sec) plus ``speedup`` vs the scalar loop
at the same batch size.  ``benchmarks/run.py --json`` records the rows
in ``BENCH_policy_throughput.json`` so the perf trajectory is tracked
across PRs.
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

Row = Tuple[str, float, str]

BATCHES = (1, 1_000, 100_000)
FAST_BATCHES = (1, 1_000)
SLA_MS = 250.0
SCALAR_CAP = 5_000   # scalar rate is measured on at most this many calls
REPEATS = 3
SEED = 23


def _budgets(rng, n: int):
    import numpy as np
    t_input = np.clip(rng.normal(50.0, 25.0, size=n), 0.0, None)
    return np.maximum(SLA_MS - 2.0 * t_input, 5.0)


def _best_rate(fn, n: int, repeats: int = REPEATS) -> float:
    """Best-of-N selections/sec for ``fn()`` covering ``n`` selections."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return n / best


def bench_rows(fast: bool = False,
               batches: Sequence[int] = None) -> List[Row]:
    import numpy as np

    from repro.core import policy_vec
    from repro.core.policy import ModiPick
    from repro.core.zoo import TABLE2, make_store

    batches = tuple(batches or (FAST_BATCHES if fast else BATCHES))
    store = make_store(TABLE2)
    policy = ModiPick(t_threshold=20.0)
    rng = np.random.default_rng(SEED)
    rows: List[Row] = []
    for B in batches:
        budgets = _budgets(rng, B)

        m = min(B, SCALAR_CAP)
        scalar_rng = np.random.default_rng(0)

        def scalar():
            for b in budgets[:m]:
                policy.select(store, float(b), scalar_rng)

        scalar_selps = _best_rate(scalar, m)
        rows.append((f"policy_throughput/scalar/batch_{B}",
                     1e6 / scalar_selps,
                     f"selps={scalar_selps:.0f};measured_n={m}"))

        for backend in ("numpy", "jax"):
            # Generator construction stays outside the timed region,
            # matching the scalar loop's pre-built rng — the rows
            # measure selection, not np.random.default_rng().
            brng = np.random.default_rng(1)
            run = lambda: policy.select_batch(  # noqa: E731
                store, budgets, brng, backend=backend)
            try:
                run()  # warm-up (jit compile for the jax path)
            except Exception as e:  # pragma: no cover - missing accelerator
                rows.append((f"policy_throughput/{backend}/batch_{B}", 0.0,
                             f"SKIP:{type(e).__name__}"))
                continue
            selps = _best_rate(run, B)
            rows.append((f"policy_throughput/{backend}/batch_{B}",
                         1e6 / selps,
                         f"selps={selps:.0f};"
                         f"speedup={selps / scalar_selps:.1f}x"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in bench_rows():
        print(f"{row[0]},{row[1]:.3f},{row[2]}")
