"""Scenario suite: run every registered named scenario end to end.

One row per scenario headline (`scenario_suite/<name>`), plus the
slices that make the new mechanisms auditable: per-SLA-class rows for
scenarios with a class mix (`.../class_<name>` — the
ClassAwareAdmission protection frontier) and per-epoch rows for
multi-epoch scenarios (`.../epoch_<e>` — the autoscaler's replica count
and the SLA attainment trajectory across the load step).

`us_per_call` carries mean end-to-end latency in us (matching
load_sweep); `derived` carries attainment/accuracy/shed plus the
slice-specific fields.  `benchmarks/run.py --json` writes the rows to
``BENCH_scenario_suite.json``; ``--smoke`` runs the same registry at
``scale≈0.1`` so tier-1 exercises every named scenario.
"""
from __future__ import annotations

from dataclasses import replace
from typing import List, Tuple

MIN_SMOKE_REQUESTS = 30


def _scaled(scenario, scale: float):
    if scale == 1.0:
        return scenario
    wl = scenario.workload
    n = max(int(wl.n_requests * scale), MIN_SMOKE_REQUESTS * wl.epochs)
    return replace(scenario, workload=replace(wl, n_requests=n))


def suite_rows(scale: float = 1.0) -> List[Tuple[str, float, str]]:
    from repro.scenario import build, get_scenario, list_scenarios

    rows = []
    for name in list_scenarios():
        out = build(_scaled(get_scenario(name), scale)).run()
        r = out.result
        n_arrived = sum(e.result.n_arrived for e in out.epochs)
        n_rejected = sum(e.result.n_rejected for e in out.epochs)
        # headline metrics pool over ALL epochs (completion-weighted),
        # not just the last one — per-epoch rows carry the trajectory
        rows.append((
            f"scenario_suite/{name}",
            out.mean_latency * 1e3,
            f"attain={out.sla_attainment:.3f};acc={out.mean_accuracy:.3f};"
            f"shed={n_rejected / max(n_arrived, 1):.3f};"
            f"qwait_ms={out.mean_queue_wait:.1f};"
            f"replicas={out.replica_history[-1]}"))
        if len(out.epochs) > 1:
            for e in out.epochs:
                er = e.result
                shed = (e.router_stats["n_shed"]
                        / max(e.router_stats["n_routed"], 1))
                rows.append((
                    f"scenario_suite/{name}/epoch_{e.epoch}",
                    er.mean_latency * 1e3,
                    f"replicas={e.n_replicas};"
                    f"attain={er.sla_attainment:.3f};"
                    f"qwait_ms={er.mean_queue_wait:.1f};"
                    f"shed={shed:.3f}"))
        for cls, row in sorted(r.per_class.items()):
            rows.append((
                f"scenario_suite/{name}/class_{cls}",
                row["mean_latency"] * 1e3,
                f"shed={row['shed_rate']:.3f};"
                f"attain={row['attainment']:.3f};"
                f"acc={row['accuracy']:.3f};"
                f"n={int(row['n_arrived'])}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in suite_rows():
        print(f"{row[0]},{row[1]:.3f},{row[2]}")
