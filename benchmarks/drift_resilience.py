"""Drift & fault resilience: the recovery story as a benchmark.

Two arms, both built from the ``scenario.registry`` drift/faulty family:

- **Drift**: NasNet-Large's true latency is multiplied mid-run and later
  restored.  The self-healing windowed profile (``profile="window"``)
  re-learns the drifted latency within one staleness window, falls back
  to the next-best model, and re-discovers NasNet after the world
  recovers; the frozen-profile ablation keeps routing on the seeded
  belief and stays degraded for the whole drift epoch.  One row per
  (``mu_mult`` × profile) with the windowed attainment trajectory:
  ``pre`` (before drift), ``dip`` (the first bucket after the drift
  fires — the detection cost), ``post`` (the rest of the drift epoch —
  the recovered steady state), ``final`` (after the true recovery).
- **Faults**: replica kill/degrade/recover churn on a shared pool, with
  and without the router's retry/hedged-fallback path.

The mu_mult=2.0 point carries the tier-1-visible resilience assertion
(adaptive ``post`` ≥ 0.9 attainment and ≥ 2× the frozen ablation's), so
``benchmarks/run.py --smoke`` fails if self-healing regresses.
``--json`` at full scale writes ``BENCH_drift_resilience.json``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

from repro.scenario.build import build, build_policy

# Full-scale drift geometry (ms).  Fast mode scales every time knob by
# the same factor so the dip/recover shape survives at smoke scale.
DRIFT_AT = 40_000.0
RECOVER_AT = 120_000.0
BUCKET = 10_000.0
FAST_SCALE = 0.25


def _run(scenario):
    """One epoch on the discrete-event engine, returning the engine
    (for ``attainment_timeline``) alongside the run result."""
    h = build(scenario)
    eng = h.engine()
    res = eng.run(build_policy(scenario), scenario.workload.t_sla_ms,
                  scenario.workload.n_requests, arrivals=h.arrivals(0),
                  warm=scenario.policy.warm, store=h.store())
    return eng, res


def _window(timeline: Sequence[Dict[str, float]], lo: float,
            hi: float) -> Tuple[float, float]:
    """Arrival-weighted (attainment, accuracy) over buckets in
    ``[lo, hi)``; NaN when the window saw no traffic."""
    rows = [r for r in timeline if lo <= r["t_ms"] < hi]
    n = sum(r["n"] for r in rows)
    if not n:
        return float("nan"), float("nan")
    att = sum(r["attainment"] * r["n"] for r in rows) / n
    done = sum(r["n"] * (1.0 - r["shed_rate"]) for r in rows)
    acc = (sum(r["accuracy"] * r["n"] * (1.0 - r["shed_rate"])
               for r in rows) / done) if done else 0.0
    return att, acc


def drift_rows(mu_mults: Sequence[float] = (1.5, 2.0, 3.0),
               fast: bool = False) -> List[Tuple[str, float, str]]:
    from repro.scenario.registry import drift_scenario

    s = FAST_SCALE if fast else 1.0
    drift_at, recover_at = DRIFT_AT * s, RECOVER_AT * s
    kw = dict(drift_at_ms=drift_at, recover_at_ms=recover_at)
    if fast:
        mu_mults = (2.0,)
        kw.update(n_requests=600, stale_after=60, window=16)

    rows: List[Tuple[str, float, str]] = []
    post_by_arm: Dict[Tuple[float, str], float] = {}
    for mu_mult in mu_mults:
        for profile in ("window", "frozen"):
            sc = drift_scenario(mu_mult=mu_mult, profile=profile,
                                name=f"bench_drift_{profile}", **kw)
            eng, res = _run(sc)
            tl = eng.attainment_timeline(bucket_ms=BUCKET * s)
            pre, _ = _window(tl, 0.0, drift_at)
            dip, _ = _window(tl, drift_at, drift_at + BUCKET * s)
            post, acc_post = _window(tl, drift_at + BUCKET * s, recover_at)
            final, acc_final = _window(tl, recover_at, math.inf)
            post_by_arm[(mu_mult, profile)] = post
            rows.append((
                f"drift_resilience/drift_mu{mu_mult:g}_{profile}",
                res.mean_latency * 1e3,
                f"pre={pre:.3f};dip={dip:.3f};post={post:.3f};"
                f"final={final:.3f};acc_post={acc_post:.3f};"
                f"acc_final={acc_final:.3f};retries={res.n_retries}"))

    # The resilience guarantee, visible to tier-1 via --smoke: after one
    # adaptation bucket the self-healing arm must be back above 0.9
    # attainment AND at least 2x the frozen ablation (measured ~8x).
    adaptive = post_by_arm[(2.0, "window")]
    frozen = post_by_arm[(2.0, "frozen")]
    assert adaptive >= 0.9, \
        f"adaptive post-drift attainment {adaptive:.3f} < 0.9"
    assert adaptive >= 2.0 * frozen, \
        (f"adaptive post-drift attainment {adaptive:.3f} < 2x frozen "
         f"ablation {frozen:.3f}")
    return rows


def fault_rows(fast: bool = False) -> List[Tuple[str, float, str]]:
    from repro.scenario.registry import faulty_scenario

    s = FAST_SCALE if fast else 1.0
    kw = dict(kill_at_ms=20_000.0 * s, degrade_at_ms=45_000.0 * s,
              revive_at_ms=60_000.0 * s, recover_at_ms=75_000.0 * s)
    if fast:
        kw.update(n_requests=400)

    rows: List[Tuple[str, float, str]] = []
    for retry in (True, False):
        sc = faulty_scenario(retry=retry, name="bench_faulty", **kw)
        eng, res = _run(sc)
        shed = res.n_rejected / max(res.n_arrived, 1)
        stats = eng.router.stats()
        rows.append((
            f"drift_resilience/faulty_{'retry' if retry else 'noretry'}",
            res.mean_latency * 1e3,
            f"attain={res.sla_attainment:.3f};acc={res.mean_accuracy:.3f};"
            f"shed={shed:.3f};retries={res.n_retries};"
            f"retry_routed={stats['n_retry_routed']};"
            f"retry_exhausted={stats['n_retry_exhausted']}"))
    return rows


def bench_rows(fast: bool = False) -> List[Tuple[str, float, str]]:
    return drift_rows(fast=fast) + fault_rows(fast=fast)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in bench_rows():
        print(f"{row[0]},{row[1]:.3f},{row[2]}")
