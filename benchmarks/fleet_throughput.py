"""Fleet scaling, spill frontier, and batching ablation.

Three arms, all built from ``scenario.registry.fleet_scenario``:

- **Scaling**: weak scaling over cell count at a fixed per-cell load
  (300 rps each, 1M requests per cell at full scale — the 10-cell row
  simulates 10M requests).  Two ratios versus the 1-cell baseline:
  ``goodput_frac`` (completed-in-SLA per simulated second vs C× the
  1-cell goodput — does the fleet path preserve attainment at scale?)
  and ``wall_frac`` (simulated requests per wall-second vs the 1-cell
  run — does per-request simulator cost stay flat as the stacked
  (cell × batch × pool) device call grows?).  Full scale asserts both
  ≥ 0.7 at 10 cells.
- **Spill frontier**: the 6-cell time-zone ring on the restricted
  mid/heavy zoo (per-cell capacity ≈144 rps) replaying the Azure-style
  day trace, swept over fleet load with spill on vs off at equal load.
  Full scale asserts a frontier point where spill lifts global SLA
  attainment by ≥ 0.10 — the headline cross-cell number.
- **Batch window**: ``batch_window_ms ∈ {0, 5, 20}`` speculative
  lookahead per cell on the 4-cell fleet (0 stays the engine default;
  the lookahead golden in ``tests/test_engine_soa.py`` stays pinned).

Fast/smoke mode shrinks every arm to toy scale and carries the
tier-1-visible fleet guard: the 4-cell toy fleet must hold ≥ 0.9
attainment AND ≥ 2.5× the 1-cell simulated goodput, so the spill
planner regressing into its bang-bang failure mode (or the fleet path
rotting outright) fails ``benchmarks/run.py --smoke``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Sequence, Tuple

# The restricted mid/heavy zoo: ≈144 rps per-cell capacity (Σ 1/μ), so
# diurnal peaks genuinely saturate a cell and spill has work to do.
HEAVY_SUBSET = ("DenseNet", "NasNet-Mobile", "InceptionV3",
                "InceptionV4", "NasNet-Large")
DAY_TRACE = "examples/azure_functions_day.csv"


def _run_fleet(sc):
    from repro.fleet.engine import FleetEngine
    t0 = time.perf_counter()
    fr = FleetEngine(sc).run()
    return fr, time.perf_counter() - t0


def _goodput_rps(sc, fr) -> float:
    """Completed-in-SLA requests per simulated second."""
    return sc.workload.rate_rps * fr.sla_attainment


def scaling_rows(cells: Sequence[int] = (1, 4, 10),
                 per_cell_rate: float = 300.0,
                 per_cell_n: int = 1_000_000,
                 fast: bool = False) -> List[Tuple[str, float, str]]:
    from repro.scenario.registry import fleet_scenario

    if fast:
        cells, per_cell_rate, per_cell_n = (1, 4), 150.0, 1_200

    rows: List[Tuple[str, float, str]] = []
    base_goodput = base_wall_rps = None
    for c in cells:
        sc = fleet_scenario(n_cells=c, rate_rps=per_cell_rate * c,
                            n_requests=per_cell_n * c, epoch_ms=10_000.0,
                            seed=17, name=f"bench_fleet_scale_{c}")
        fr, wall = _run_fleet(sc)
        goodput = _goodput_rps(sc, fr)
        wall_rps = fr.n_arrived / max(wall, 1e-9)
        if base_goodput is None:
            base_goodput, base_wall_rps = goodput, wall_rps
        goodput_frac = goodput / (c * base_goodput)
        wall_frac = wall_rps / base_wall_rps
        rows.append((
            f"fleet_throughput/scale_{c}cell",
            wall * 1e6 / max(fr.n_arrived, 1),
            f"n={fr.n_arrived};att={fr.sla_attainment:.4f};"
            f"goodput_rps={goodput:.1f};goodput_frac={goodput_frac:.3f};"
            f"wall_rps={wall_rps:.0f};wall_frac={wall_frac:.3f};"
            f"spill_rate={fr.spill_rate:.4f}"))
        if fast and c == 4:
            # The tier-1-visible fleet guard (via run.py --smoke).
            assert fr.sla_attainment >= 0.9, \
                f"4-cell toy fleet attainment {fr.sla_attainment:.3f} < 0.9"
            assert goodput >= 2.5 * base_goodput, \
                (f"4-cell toy goodput {goodput:.1f} rps < 2.5x the "
                 f"1-cell baseline {base_goodput:.1f} rps")
        if not fast and c == 10:
            assert goodput_frac >= 0.7, \
                f"10-cell goodput scaling {goodput_frac:.3f} < 0.7x ideal"
            assert wall_frac >= 0.7, \
                f"10-cell wall-clock scaling {wall_frac:.3f} < 0.7x ideal"
    return rows


def frontier_rows(rates: Sequence[float] = (480.0, 540.0, 600.0, 660.0),
                  n_requests: int = 30_000,
                  fast: bool = False) -> List[Tuple[str, float, str]]:
    from repro.scenario.registry import fleet_scenario

    if fast:
        rates, n_requests = (540.0,), 6_000

    rows: List[Tuple[str, float, str]] = []
    best_lift = 0.0
    for rate in rates:
        att = {}
        for spill in (True, False):
            sc = fleet_scenario(
                n_cells=6, rate_rps=rate, n_requests=n_requests,
                subset=HEAVY_SUBSET, trace_path=DAY_TRACE,
                rotate_phases=True, spill=spill, spill_threshold_ms=40.0,
                epoch_ms=5_000.0, period_ms=60_000.0, seed=19,
                name=f"bench_fleet_frontier_{rate:g}_{spill}")
            fr, wall = _run_fleet(sc)
            att[spill] = fr.sla_attainment
            if spill:
                spill_rate, acc = fr.spill_rate, fr.mean_accuracy
        lift = att[True] - att[False]
        best_lift = max(best_lift, lift)
        rows.append((
            f"fleet_throughput/frontier_rate_{rate:g}",
            wall * 1e6 / max(n_requests, 1),
            f"att_spill={att[True]:.4f};att_nospill={att[False]:.4f};"
            f"lift={lift:+.4f};spill_rate={spill_rate:.3f};"
            f"acc={acc:.4f}"))
    if not fast:
        assert best_lift >= 0.10, \
            (f"no frontier point with >=0.10 spill lift "
             f"(best {best_lift:+.4f})")
    return rows


def window_rows(windows: Sequence[float] = (0.0, 5.0, 20.0),
                n_requests: int = 200_000,
                fast: bool = False) -> List[Tuple[str, float, str]]:
    from repro.scenario.registry import fleet_scenario

    if fast:
        n_requests = 4_000

    rows: List[Tuple[str, float, str]] = []
    for w in windows:
        sc = fleet_scenario(n_cells=4, rate_rps=480.0,
                            n_requests=n_requests, epoch_ms=10_000.0,
                            seed=17, name=f"bench_fleet_window_{w:g}")
        sc = dataclasses.replace(sc, deployment=dataclasses.replace(
            sc.deployment, batch_window_ms=w))
        fr, wall = _run_fleet(sc)
        nb = sum(e.router_stats.get("n_batches", 0) for e in fr.epochs)
        mb = (sum(e.router_stats.get("mean_batch", 0.0)
                  * e.router_stats.get("n_batches", 0)
                  for e in fr.epochs) / nb) if nb else 0.0
        rows.append((
            f"fleet_throughput/window_{w:g}ms",
            wall * 1e6 / max(fr.n_arrived, 1),
            f"att={fr.sla_attainment:.4f};acc={fr.mean_accuracy:.4f};"
            f"mean_batch={mb:.2f};lat={fr.mean_latency:.1f}"))
    return rows


def bench_rows(fast: bool = False) -> List[Tuple[str, float, str]]:
    return (scaling_rows(fast=fast) + frontier_rows(fast=fast)
            + window_rows(fast=fast))


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in bench_rows():
        print(f"{row[0]},{row[1]:.3f},{row[2]}")
