"""One benchmark per paper table/figure (§4), all seeded from the paper's
empirical measurements in repro.core.zoo.

Each function returns a list of CSV rows (name, us_per_call, derived)."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.netmodel import NetworkModel, campus_wifi, prototype_wifi
from repro.core.policy import (DynamicGreedy, ModiPick, PureRandom,
                               RelatedAccurate, RelatedRandom, StaticGreedy)
from repro.core.simulate import Simulator
from repro.core.zoo import (NASNET_FICTIONAL, ON_DEVICE, PROTOTYPE_POOL,
                            TABLE2)

Row = Tuple[str, float, str]
N = 4000


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    us = (time.perf_counter() - t0) * 1e6
    return out, us


def fig3_latency_table() -> List[Row]:
    """Fig. 3: on-device vs cloud inference latency gap."""
    rows = []
    for name, dev_ms in ON_DEVICE.items():
        server = next((e.mu_ms for e in TABLE2 if e.name == name), None)
        if server:
            rows.append((f"fig3/{name}", dev_ms * 1e3,
                         f"on_device_ms={dev_ms};server_ms={server};speedup={dev_ms/server:.1f}x"))
    return rows


def fig5_prototype(n: int = N) -> List[Row]:
    """Fig. 5: end-to-end prototype (2-model pool, MotoX + campus WiFi)."""
    sim = Simulator(entries=PROTOTYPE_POOL, network=prototype_wifi(), seed=11)
    rows = []
    for sla in (75, 100, 115, 150, 200, 300, 400):
        r, us = _timed(lambda: sim.run(ModiPick(t_threshold=20.0), sla, n))
        rows.append((f"fig5/sla_{sla}", us / n,
                     f"violations={1-r.sla_attainment:.3f};accuracy={r.mean_accuracy:.3f}"))
    return rows


def fig6_vs_static_greedy(n: int = N) -> List[Row]:
    """Fig. 6a/6b: ModiPick vs static greedy, 11-model zoo, campus WiFi."""
    sim = Simulator(entries=TABLE2, network=campus_wifi(), seed=12)
    rows = []
    for sla in (100, 115, 150, 200, 250, 300):
        mp, us = _timed(lambda: sim.run(ModiPick(t_threshold=20.0), sla, n))
        sg = sim.run(StaticGreedy(sla), sla, n)
        dg = sim.run(DynamicGreedy(), sla, n)
        lat_red = 1.0 - mp.mean_latency / sg.mean_latency
        rows.append((f"fig6/sla_{sla}", us / n,
                     f"mp_attain={mp.sla_attainment:.3f};sg_attain={sg.sla_attainment:.3f};"
                     f"dg_attain={dg.sla_attainment:.3f};mp_acc={mp.mean_accuracy:.3f};"
                     f"sg_acc={sg.mean_accuracy:.3f};latency_reduction={lat_red:.3f}"))
        top = sorted(mp.model_usage.items(), key=lambda kv: -kv[1])[:3]
        rows.append((f"fig6b/sla_{sla}_usage", 0.0,
                     ";".join(f"{k}={v:.2f}" for k, v in top)))
    return rows


def fig7_cv_sweep(n: int = N) -> List[Row]:
    """Fig. 7: accuracy + attainment vs network CV at SLA 100/250ms."""
    rows = []
    for sla in (100, 250):
        for cv in (0.0, 0.25, 0.5, 0.74, 1.0):
            sim = Simulator(entries=TABLE2,
                            network=NetworkModel.from_cv(50.0, cv), seed=13)
            r, us = _timed(lambda: sim.run(ModiPick(t_threshold=20.0), sla, n))
            rows.append((f"fig7/sla_{sla}_cv_{int(cv*100)}", us / n,
                         f"attain={r.sla_attainment:.3f};acc={r.mean_accuracy:.3f}"))
    return rows


def fig8_usage_vs_cv(n: int = N) -> List[Row]:
    """Fig. 8: model usage mix vs CV at SLA 100/250ms."""
    rows = []
    for sla in (100, 250):
        for cv in (0.0, 0.5, 1.0):
            sim = Simulator(entries=TABLE2,
                            network=NetworkModel.from_cv(50.0, cv), seed=14)
            r = sim.run(ModiPick(t_threshold=20.0), sla, n)
            n_used = sum(1 for v in r.model_usage.values() if v > 0.01)
            top = sorted(r.model_usage.items(), key=lambda kv: -kv[1])[:2]
            rows.append((f"fig8/sla_{sla}_cv_{int(cv*100)}", 0.0,
                         f"n_models={n_used};" +
                         ";".join(f"{k}={v:.2f}" for k, v in top)))
    return rows


def fig9_decomposition(n: int = N) -> List[Row]:
    """Fig. 9: stage decomposition with the adversarial NasNet-Fictional.

    Reproduction note: `modipick_eq3` is Eq. 3 exactly as printed (γ=1) —
    it explores the fictional model ≈38% at high SLA, contradicting the
    paper's "low probability" claim; `modipick_g4` (γ=4 accuracy
    sharpening) recovers the paper's qualitative result.  Both reported.
    """
    entries = TABLE2 + [NASNET_FICTIONAL]
    sim = Simulator(entries=entries,
                    network=NetworkModel(mean_ms=50.0, std_ms=25.0), seed=15)
    rows = []
    for sla in (150, 250, 350):
        for mk, name in [(lambda: ModiPick(20.0), "modipick_eq3"),
                         (lambda: ModiPick(20.0, gamma=4.0), "modipick_g4"),
                         (lambda: PureRandom(), "pure_random"),
                         (lambda: RelatedRandom(20.0), "related_random"),
                         (lambda: RelatedAccurate(20.0), "related_accurate")]:
            r, us = _timed(lambda: sim.run(mk(), sla, n))
            rows.append((f"fig9/sla_{sla}_{name}", us / n,
                         f"attain={r.sla_attainment:.3f};acc={r.mean_accuracy:.3f};"
                         f"fictional={r.model_usage.get('NasNet-Fictional', 0.0):.3f}"))
    return rows


def threshold_ablation(n: int = N) -> List[Row]:
    """§3.3: T_threshold ∈ [0, T_D] trades exploration width for safety.
    T_threshold=0 collapses ModiPick toward dynamic greedy; larger values
    widen M_E (more exploration, slightly earlier fallbacks)."""
    sim = Simulator(entries=TABLE2, network=campus_wifi(), seed=16)
    rows = []
    for thr in (0.0, 5.0, 20.0, 50.0, 100.0, 150.0):
        r, us = _timed(lambda: sim.run(ModiPick(t_threshold=thr), 250.0, n))
        n_used = sum(1 for v in r.model_usage.values() if v > 0.01)
        rows.append((f"threshold/thr_{int(thr)}", us / n,
                     f"attain={r.sla_attainment:.3f};acc={r.mean_accuracy:.3f};"
                     f"n_models={n_used}"))
    return rows


def table2_zoo() -> List[Row]:
    """Table 2: the managed model zoo statistics."""
    return [(f"table2/{e.name}", e.mu_ms * 1e3,
             f"top1={e.top1};sigma_ms={e.sigma_ms}") for e in TABLE2]
