# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names (default: all)")
    ap.add_argument("--fast", action="store_true",
                    help="skip the live-pool serving benchmark and cap "
                         "policy_throughput at small batches")
    ap.add_argument("--smoke", action="store_true",
                    help="run every registered benchmark at toy scale "
                         "(implies --fast): the CI bit-rot guard — a "
                         "benchmark that stopped importing or running "
                         "fails here instead of at sweep time")
    ap.add_argument("--fail-fast", action="store_true",
                    help="abort on the first failing benchmark")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<name>.json per benchmark "
                         "(perf trajectory record)")
    args = ap.parse_args()
    if args.smoke:
        args.fast = True

    from benchmarks import drift_resilience as dr
    from benchmarks import elastic_controllers as ec
    from benchmarks import engine_throughput as et
    from benchmarks import fleet_throughput as ft
    from benchmarks import load_sweep as ls
    from benchmarks import paper_figures as pf
    from benchmarks import policy_throughput as pt
    from benchmarks import premodel as pm
    from benchmarks import roofline as rl
    from benchmarks import scenario_suite as sc

    # Toy-scale knobs used under --smoke; full scale otherwise.
    fig_kw = {"n": 60} if args.smoke else {}

    def smoke_load_sweep():
        return (ls.sweep_rows(rates=(5.0, 40.0), n_requests=120)
                + ls.admission_rows(rates=(40.0,), n_requests=120))

    benches = {
        "table2": pf.table2_zoo,
        "fig3": pf.fig3_latency_table,
        "fig5": lambda: pf.fig5_prototype(**fig_kw),
        "fig6": lambda: pf.fig6_vs_static_greedy(**fig_kw),
        "fig7": lambda: pf.fig7_cv_sweep(**fig_kw),
        "fig8": lambda: pf.fig8_usage_vs_cv(**fig_kw),
        "fig9": lambda: pf.fig9_decomposition(**fig_kw),
        "threshold": lambda: pf.threshold_ablation(**fig_kw),
        "roofline_single": lambda: rl.roofline_rows("single"),
        "roofline_multi": lambda: rl.roofline_rows("multi"),
        "kernels": lambda: rl.kernel_micro(
            seq_len=128 if args.smoke else 512),
        "tpu_pool": (lambda: _tpu_pool(n=120, slas=(100, 600)))
        if args.smoke else _tpu_pool,
        "load_sweep": smoke_load_sweep if args.smoke else
        (lambda: ls.sweep_rows() + ls.admission_rows()),
        "sla_frontier": (lambda: ls.frontier_rows(slas=(250.0,), n=2048))
        if args.smoke else ls.frontier_rows,
        "policy_throughput": lambda: pt.bench_rows(fast=args.fast),
        # events/sec + requests/sec at 10k/100k/1M (2k under --smoke)
        "engine_throughput": lambda: et.bench_rows(fast=args.fast),
        # every registered named scenario, end to end (toy scale under
        # --smoke: the registry's bit-rot guard)
        "scenario_suite": (lambda: sc.suite_rows(scale=0.1))
        if args.smoke else sc.suite_rows,
        # drift/fault recovery trajectories; carries the tier-1-visible
        # resilience assertion (adaptive post-drift attainment >= 0.9
        # and >= 2x the frozen-profile ablation)
        "drift_resilience": lambda: dr.bench_rows(fast=args.fast),
        # mid-run elastic controllers vs epoch-boundary autoscaling;
        # carries the tier-1-visible gates (zero in-flight requests
        # lost to drain-based scale-in, and the capped proportional
        # controller beating the epoch baseline's pooled attainment at
        # lower replica-seconds on the 10x load step)
        "elastic_controllers": lambda: ec.bench_rows(fast=args.fast),
        # multi-cell scaling + spill frontier + batch-window ablation;
        # carries the tier-1-visible fleet guard (4-cell toy >= 0.9
        # attainment and >= 2.5x the 1-cell goodput under --smoke)
        "fleet_throughput": lambda: ft.bench_rows(fast=args.fast),
        # conditional-profile + tail-quantile routing; carries the
        # tier-1-visible premodel guards (conditional >= +0.02 accuracy
        # at equal attainment; p95 budgets beat mean budgets on tail
        # attainment)
        "premodel": lambda: pm.bench_rows(fast=args.fast),
    }
    if args.smoke:
        # Toy pool (2 reduced-width variants, short cache, 6 requests):
        # the real-JAX serving path stays under the bit-rot guard too.
        benches["live_pool"] = lambda: _live_pool(
            widths=(0.5, 1.0), cache_len=32, n=6, tokens_shape=(1, 16))
    elif not args.fast:
        benches["live_pool"] = _live_pool

    selected = args.only.split(",") if args.only else list(benches)
    unknown = [n for n in selected if n not in benches]
    if unknown:
        raise SystemExit(f"unknown benchmark(s): {', '.join(unknown)} "
                         f"(available: {', '.join(benches)})")
    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        try:
            rows = list(benches[name]())
            for row in rows:
                print(f"{row[0]},{row[1]:.3f},{row[2]}")
            if args.json:
                # Toy-scale rows must not clobber the tracked full-scale
                # perf-trajectory records.
                suffix = "_smoke" if args.smoke else ""
                with open(f"BENCH_{name}{suffix}.json", "w") as fh:
                    json.dump({"benchmark": name,
                               "rows": [{"name": r[0], "us_per_call": r[1],
                                         "derived": r[2]} for r in rows]},
                              fh, indent=2)
        except Exception as e:
            failures += 1
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            if args.fail_fast:
                break
    if failures:
        raise SystemExit(1)


def _tpu_pool(n: int = 2000, slas=(100, 300, 600, 1500, 3000)):
    """Beyond-paper: ModiPick over (arch × mesh) TPU pool members whose
    latency profiles come from the dry-run rooflines (core/tpu_pool.py)."""
    import os
    from repro.core.netmodel import NetworkModel
    from repro.core.policy import ModiPick, StaticGreedy
    from repro.core.simulate import Simulator
    from repro.core.tpu_pool import load_pool, to_zoo

    results = "benchmarks/results/dryrun"
    if not os.path.isdir(results) or not load_pool(results):
        results = "benchmarks/results/dryrun_baseline"
    pool = load_pool(results)
    if not pool:
        return [("tpu_pool/skipped", 0.0, "no dry-run artifacts")]
    zoo = to_zoo(pool)
    sim = Simulator(entries=zoo, network=NetworkModel(20.0, 10.0), seed=20)
    rows = []
    for sla in slas:
        mp = sim.run(ModiPick(t_threshold=50.0, gamma=4.0), sla, n)
        sg = sim.run(StaticGreedy(sla), sla, n)
        top = max(mp.model_usage, key=mp.model_usage.get)
        rows.append((f"tpu_pool/sla_{sla}", 0.0,
                     f"mp_attain={mp.sla_attainment:.3f};mp_q={mp.mean_accuracy:.3f};"
                     f"sg_attain={sg.sla_attainment:.3f};sg_q={sg.mean_accuracy:.3f};"
                     f"top={top}"))
    return rows


def _live_pool(widths=(0.5, 1.0, 2.0), cache_len=160, n=60,
               tokens_shape=(4, 128)):
    """Live serving e2e: real JAX pool behind ModiPick vs static greedy."""
    import numpy as np
    from repro.configs.registry import get_config
    from repro.core.netmodel import NetworkModel
    from repro.core.policy import ModiPick, StaticGreedy
    from repro.serving.executor import PoolExecutor
    from repro.serving.pool import scaled_family

    rows = []
    variants = scaled_family(get_config("qwen2-1.5b"), widths=widths,
                             cache_len=cache_len)
    tokens = np.random.default_rng(0).integers(0, 500, tokens_shape,
                                               dtype=np.int32)
    net = NetworkModel(mean_ms=20.0, std_ms=10.0)
    for name, pol in [("modipick", ModiPick(t_threshold=25.0)),
                      ("static_greedy", StaticGreedy(120.0))]:
        ex = PoolExecutor(variants, net, pol, seed=3)
        ex.warm_up(tokens)
        for _ in range(n):
            ex.execute(tokens, t_sla=120.0)
        s = ex.summary()
        rows.append((f"live_pool/{name}", s["mean_latency_ms"] * 1e3,
                     f"attain={s['sla_attainment']:.3f};quality={s['mean_quality']:.3f};"
                     f"p99_ms={s['p99_latency_ms']:.1f}"))
    return rows


if __name__ == '__main__':
    main()
