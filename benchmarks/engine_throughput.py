"""Discrete-event engine throughput: requests/sec and events/sec at
10k / 100k / 1M simulated requests.

The ROADMAP north star is serving millions-of-users traffic "as fast as
the hardware allows"; after the SoA hot-path refactor a 1M-request
scenario is a routine run, and this benchmark keeps it that way.  Rows:

    engine_throughput/<config>/n_<N>

with ``us_per_call`` = wall microseconds per simulated request and
``derived`` carrying ``reqps`` (requests/sec), ``evps`` (lifecycle
events/sec: ARRIVAL+ENQUEUE+FINISH+DEPART per completed request,
ARRIVAL+ENQUEUE per shed one) and the run's attainment as a sanity
anchor.  Configs:

- ``singleton``: queue-aware ModiPick over the paper's per-model
  topology at rate 40 — continuous event times, every routing decision
  a scalar (batch-of-1) selection; the load_sweep workhorse.
- ``batched``: the same policy over 4 replicas per model, driven by
  200-wide simultaneous arrival bursts over a zero-jitter network —
  same-timestamp ENQUEUEs group into one ``route_batch_arrays`` call
  with intra-batch load charging (each admitted pick's μ is charged to
  its replica before the next request in the burst is judged).
- ``batched_snapshot``: ablation of the same burst workload with
  ``charge_batches=False`` — every request in a burst judged against
  the one stale W_queue snapshot (the pre-charging behaviour whose
  attainment collapse this benchmark originally exposed).

``benchmarks/run.py --json`` records the rows in
``BENCH_engine_throughput.json`` so the perf trajectory is tracked
across PRs; ``--smoke`` runs a 2k-request row per config as the tier-1
bit-rot guard and additionally asserts the charged ``batched`` config
attains ≥ 0.5 — a staleness-collapse regression (charging silently
disengaging) fails the smoke run instead of surfacing at sweep time.
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

Row = Tuple[str, float, str]

SIZES = (10_000, 100_000, 1_000_000)
SMOKE_SIZES = (2_000,)
SLA_MS = 250.0
SEED = 3


BURST = 200           # simultaneous arrivals per burst (batched config)
BURST_EVERY_MS = 400.0


def _burst_trace(n: int):
    """n timestamps in BURST-wide simultaneous spikes every
    BURST_EVERY_MS — duplicate times are legal and, over a zero-jitter
    network, reach the router as one route_batch call per burst."""
    import numpy as np
    from repro.sim.arrivals import TraceArrivals
    bursts = -(-n // BURST)
    times = np.repeat(np.arange(bursts) * BURST_EVERY_MS, BURST)[:n]
    return TraceArrivals(times)


def _configs():
    from repro.core.netmodel import NetworkModel
    from repro.core.zoo import TABLE2
    from repro.sim.arrivals import PoissonArrivals
    from repro.sim.engine import ServingSimulator
    from repro.sim.replica import per_model_replicas

    return {
        "singleton": (
            lambda: ServingSimulator(TABLE2, NetworkModel(50.0, 25.0),
                                     per_model_replicas(TABLE2),
                                     seed=SEED, queue_aware=True),
            lambda n: PoissonArrivals(40.0)),
        "batched": (
            lambda: ServingSimulator(
                TABLE2, NetworkModel(50.0, 0.0),
                per_model_replicas(TABLE2, replicas_per_model=4),
                seed=SEED, queue_aware=True),
            _burst_trace),
        "batched_snapshot": (
            lambda: ServingSimulator(
                TABLE2, NetworkModel(50.0, 0.0),
                per_model_replicas(TABLE2, replicas_per_model=4),
                seed=SEED, queue_aware=True, charge_batches=False),
            _burst_trace),
    }


def bench_rows(fast: bool = False,
               sizes: Sequence[int] = None) -> List[Row]:
    from repro.core.policy import ModiPick

    sizes = tuple(sizes or (SMOKE_SIZES if fast else SIZES))
    rows: List[Row] = []
    for name, (make_engine, make_arrivals) in _configs().items():
        for n in sizes:
            eng = make_engine()
            t0 = time.perf_counter()
            r = eng.run(ModiPick(t_threshold=20.0), SLA_MS, n,
                        arrivals=make_arrivals(n))
            wall = time.perf_counter() - t0
            events = 4 * r.n_completed + 2 * r.n_rejected
            rows.append((
                f"engine_throughput/{name}/n_{n}",
                wall * 1e6 / max(n, 1),
                f"reqps={n / wall:.0f};evps={events / wall:.0f};"
                f"wall_s={wall:.2f};attain={r.sla_attainment:.3f};"
                f"shed={r.n_rejected};"
                f"batches={eng.router.stats()['n_batches']}"))
            if fast and name == "batched" and r.sla_attainment < 0.5:
                # Tier-1-visible staleness guard (the smoke run is
                # exercised by tests/test_router.py): charged burst
                # routing attains ~1.0 here; a snapshot-regime relapse
                # collapses it to ~0.15.
                raise AssertionError(
                    f"burst smoke attainment {r.sla_attainment:.3f} < 0.5 "
                    "— intra-batch load charging regressed")
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in bench_rows():
        print(f"{row[0]},{row[1]:.3f},{row[2]}")
