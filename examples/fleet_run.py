"""Multi-cell fleet serving: time zones, sticky users, cross-cell spill.

Six cells sit on a time-zone ring, each replaying the Azure-style day
trace (``examples/azure_functions_day.csv``) shifted by its phase, so
one cell is always near its diurnal peak while the others idle.  Each
cell runs the restricted mid/heavy zoo (≈144 rps capacity), so the
~180 rps peaks genuinely saturate a cell on its own.

The fleet frontend pins every user to a home cell (stateless
splitmix64 hashing) and re-plans per epoch: all pending requests are
judged against every cell in ONE stacked (cell × batch × pool) device
call, and each hot cell's capacity excess spills to cells with
headroom — every spilled request paying the inter-cell RTT inside its
own ModiPick budget (``T_sla − 2·T_input − RTT − W_queue``), so the
move is only made when it is honestly worth it.

The run prints the spill-on vs spill-off comparison: at this operating
point spill turns the peak cell's drowning into fleet-wide headroom.

Run:  PYTHONPATH=src python examples/fleet_run.py
"""
import dataclasses

from repro.fleet import FleetEngine
from repro.scenario import fleet_scenario

HEAVY = ("DenseNet", "NasNet-Mobile", "InceptionV3", "InceptionV4",
         "NasNet-Large")


def run(spill: bool):
    sc = fleet_scenario(
        n_cells=6, rate_rps=540.0, n_requests=30_000, subset=HEAVY,
        trace_path="examples/azure_functions_day.csv", rotate_phases=True,
        spill=spill, spill_threshold_ms=40.0, epoch_ms=5_000.0,
        period_ms=60_000.0, seed=19,
        name=f"fleet_example_{'spill' if spill else 'nospill'}")
    return sc, FleetEngine(sc).run()


def main() -> None:
    print("6-cell time-zone ring, Azure day trace, 540 rps fleet-wide\n")
    results = {}
    for spill in (True, False):
        sc, fr = run(spill)
        results[spill] = fr
        tag = "spill on " if spill else "spill off"
        print(f"{tag}: attain={fr.sla_attainment:.4f} "
              f"acc={fr.mean_accuracy:.4f} lat={fr.mean_latency:6.1f}ms "
              f"spill_rate={fr.spill_rate:.3f} locality={fr.locality:.3f}")
    lift = (results[True].sla_attainment
            - results[False].sla_attainment)
    print(f"\nspill lifts fleet SLA attainment by {lift:+.4f}")

    fr = results[True]
    print("\nper-epoch view (load signal the plan used, per-cell "
          "attainment):")
    for e in fr.epochs:
        att = " ".join(f"{r.sla_attainment:.2f}" if r else " -  "
                       for r in e.cell_results)
        print(f"  epoch {e.epoch:2d}  n={e.result.n_arrived:5d} "
              f"spilled={e.n_spilled:4d}  att=[{att}]")

    # A 1-cell fleet with zero RTT is the single-cell system, bit for
    # bit — the parity contract tests/test_fleet.py pins.
    from repro.fleet import CellSpec, FleetSpec
    from repro.scenario import build, get_scenario
    sc = get_scenario("steady")
    solo = dataclasses.replace(sc, deployment=dataclasses.replace(
        sc.deployment,
        fleet=FleetSpec(cells=(CellSpec("solo"),), rtt_ms=0.0)))
    assert (build(solo).run().result.sla_attainment
            == build(sc).run().result.sla_attainment)
    print("\n1-cell zero-RTT fleet reproduces the single-cell run "
          "exactly (parity contract).")


if __name__ == "__main__":
    main()
