"""Continuous-batching demo: Orca-style slot engine over a shared KV pool.

Requests with different prompt/generation lengths stream through a fixed
decode batch; finished sequences retire immediately and free their slot.

  PYTHONPATH=src python examples/continuous_batching.py --requests 8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import model as M
from repro.serving.batcher import ContinuousBatcher, GenRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    engine = ContinuousBatcher(cfg, params, max_slots=args.slots,
                               cache_len=args.cache_len)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(5, 30)), dtype=np.int32)
        r = GenRequest(rid=i, prompt=prompt,
                       max_new=int(rng.integers(4, 16)))
        reqs.append(r)
        engine.submit(r)

    t0 = time.perf_counter()
    engine.run_to_completion()
    wall = time.perf_counter() - t0
    total_new = sum(len(r.generated) for r in reqs)
    print(f"{args.requests} requests through {args.slots} slots: "
          f"{engine.n_steps} engine steps, {total_new} tokens, "
          f"{total_new/wall:.1f} tok/s")
    for r in reqs:
        ttft = (r.first_token_s - r.arrival_s) * 1e3
        e2e = (r.finish_s - r.arrival_s) * 1e3
        print(f"  req {r.rid}: prompt={len(r.prompt):2d} new={len(r.generated):2d} "
              f"ttft={ttft:6.0f}ms e2e={e2e:6.0f}ms")


if __name__ == "__main__":
    main()
