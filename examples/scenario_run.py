"""The Scenario API: declare an experiment, run it, slice it.

``examples/serve_loaded.py`` used to hand-wire the loaded-serving study
(engine kwargs, arrival processes, policies per point).  The same study
is now three declarative scenarios — and two the hand-wired flow could
not express at all: an SLA-class mix protected by class-aware
admission, and a load step answered by the queue-target autoscaler.

A scenario is plain data (JSON/TOML-friendly); this example builds one
from a dict exactly as a config file would deserialize it, then runs
registry scenarios for the dynamic shapes.

Run:  PYTHONPATH=src python examples/scenario_run.py
      PYTHONPATH=src python examples/scenario_run.py \\
          --scenario examples/drift.toml
"""
import argparse

from repro.scenario import Scenario, build, get_scenario

# The steady/Poisson point, as it would sit in a TOML/JSON config file.
STEADY = {
    "name": "steady_config",
    "workload": {"arrival": "poisson", "rate_rps": 30.0,
                 "n_requests": 600, "t_sla_ms": 250.0},
    "network": {"mean_ms": 50.0, "std_ms": 25.0},
    "deployment": {"topology": "per_model"},
    "policy": {"policy": "modipick", "kwargs": {"t_threshold": 20.0},
               "queue_aware": True},
    "seed": 3,
}


def headline(tag, out):
    r = out.result
    shed = sum(e.result.n_rejected for e in out.epochs)
    n = sum(e.result.n_arrived for e in out.epochs)
    print(f"{tag:>10}  attain={out.sla_attainment:.3f} "
          f"acc={r.mean_accuracy:.3f} shed={shed / max(n, 1):.3f} "
          f"qwait={r.mean_queue_wait:6.1f}ms "
          f"replicas={out.replica_history[-1]}")
    prov = sum(e.result.n_provisioned for e in out.epochs)
    deco = sum(e.result.n_decommissioned for e in out.epochs)
    if prov or deco:  # mid-run elastic lifecycle ran: show the cost axis
        rep_s = sum(e.result.replica_seconds for e in out.epochs)
        print(f"{'':>10}  provisioned={prov} decommissioned={deco} "
              f"replica_seconds={rep_s:.1f} "
              f"history={'/'.join(str(x) for x in out.replica_history)}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", metavar="PATH", default=None,
                    help="run a scenario from a .toml or .json file "
                         "(fault/drift/retry specs included) instead of "
                         "the built-in tour")
    args = ap.parse_args()
    if args.scenario:
        scenario = Scenario.from_file(args.scenario)
        print(f"scenario {scenario.name!r} from {args.scenario}")
        headline(scenario.name, build(scenario).run())
        return

    print("Scenario API: one declarative spec per experiment\n")

    scenario = Scenario.from_dict(STEADY)
    assert Scenario.from_dict(scenario.to_dict()) == scenario  # round trip
    headline("steady", build(scenario).run())

    for name in ("diurnal", "burst"):
        headline(name, build(get_scenario(name)).run())

    print("\nclass_mix: one saturated shared replica; class-aware "
          "admission sheds\n'batch' early so 'interactive' keeps its SLA:")
    out = build(get_scenario("class_mix")).run()
    headline("class_mix", out)
    for cls, row in sorted(out.result.per_class.items()):
        print(f"  {cls:>12}  shed={row['shed_rate']:.3f} "
              f"attain={row['attainment']:.3f} acc={row['accuracy']:.3f}")

    print("\nscale_up: 4 -> 40 rps load step; the queue-target autoscaler "
          "re-sizes\nthe pool from Router.stats() between epochs:")
    out = build(get_scenario("scale_up")).run()
    for e in out.epochs:
        print(f"  epoch {e.epoch}: replicas={e.n_replicas} "
              f"attain={e.result.sla_attainment:.3f} "
              f"qwait={e.result.mean_queue_wait:6.1f}ms")


if __name__ == "__main__":
    main()
