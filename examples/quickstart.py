"""Quickstart: ModiPick in 40 lines.

Runs the paper's model zoo (Table 2) behind the three-stage selection
policy against the measured campus-WiFi network, and compares SLA
attainment/accuracy with the greedy baselines.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.netmodel import campus_wifi
from repro.core.policy import DynamicGreedy, ModiPick, StaticGreedy
from repro.core.simulate import Simulator
from repro.core.zoo import TABLE2


def main():
    sim = Simulator(entries=TABLE2, network=campus_wifi(), seed=0)
    print(f"{'SLA(ms)':>8} | {'policy':16} {'attain%':>8} {'top1%':>6} {'lat(ms)':>8}")
    print("-" * 56)
    for sla in (100, 115, 150, 200, 250, 300):
        for policy in (ModiPick(t_threshold=20.0),
                       DynamicGreedy(),
                       StaticGreedy(sla)):
            r = sim.run(policy, sla, n_requests=3000)
            print(f"{sla:8.0f} | {r.policy:16} {100*r.sla_attainment:8.1f} "
                  f"{100*r.mean_accuracy:6.1f} {r.mean_latency:8.1f}")
        print()

    # What ModiPick actually picked at a mid SLA:
    r = sim.run(ModiPick(t_threshold=20.0), 200.0, 3000)
    print("model usage @ SLA=200ms:")
    for name, frac in sorted(r.model_usage.items(), key=lambda kv: -kv[1]):
        if frac > 0.01:
            print(f"  {name:22s} {100*frac:5.1f}%")


if __name__ == "__main__":
    main()
