"""Serving under load: queue-aware ModiPick vs the paper's policies.

The paper's closed loop (``examples/simulate_paper.py``) sees one
request at a time, so the only latency the budget must absorb is the
network's.  This example drives the discrete-event serving simulator
(``repro.sim``) with open-loop Poisson traffic over per-model endpoints
and shows the new failure mode — queueing delay — and how folding
W_queue(m) into the budget (``T_budget = T_sla − 2·T_input − W_queue``)
restores SLA attainment by trading a little accuracy for idle replicas.

Run:  PYTHONPATH=src python examples/serve_loaded.py
"""
from repro.core.netmodel import NetworkModel
from repro.core.policy import ModiPick
from repro.core.zoo import TABLE2
from repro.sim import (PoissonArrivals, ServingSimulator,
                       per_model_replicas)

T_SLA = 250.0
N = 800
RATES = (2.0, 10.0, 30.0, 60.0)


def run_point(rate: float, queue_aware: bool):
    sim = ServingSimulator(TABLE2, NetworkModel(50.0, 25.0),
                           per_model_replicas(TABLE2), seed=11,
                           queue_aware=queue_aware)
    return sim.run(ModiPick(t_threshold=20.0), T_SLA, N,
                   arrivals=PoissonArrivals(rate))


def main() -> None:
    print(f"SLA={T_SLA:.0f}ms, {N} requests, Table-2 zoo, "
          f"one endpoint per model\n")
    hdr = (f"{'rate(rps)':>9} {'policy':>12} {'attain':>7} {'acc':>6} "
           f"{'mean_ms':>8} {'p99_ms':>9} {'qwait_ms':>9} {'peak_q':>6}")
    print(hdr)
    print("-" * len(hdr))
    for rate in RATES:
        for qa in (False, True):
            r = run_point(rate, qa)
            name = "qa_modipick" if qa else "modipick"
            print(f"{rate:9.0f} {name:>12} {r.sla_attainment:7.3f} "
                  f"{r.mean_accuracy:6.3f} {r.mean_latency:8.1f} "
                  f"{r.p99_latency:9.1f} {r.mean_queue_wait:9.1f} "
                  f"{r.peak_queue_depth:6d}")
        print()
    print("Queue-blind ModiPick keeps routing to saturated endpoints; "
          "queue-aware\nselection spreads to idle, slightly less accurate "
          "models and holds the SLA.")


if __name__ == "__main__":
    main()
