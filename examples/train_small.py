"""Train a small LM with the full production substrate: deterministic data
pipeline, AdamW, remat, periodic checkpointing, crash-safe resume.

  PYTHONPATH=src python examples/train_small.py                  # smoke (~1 min)
  PYTHONPATH=src python examples/train_small.py --preset 100m    # ~100M params,
                                                                 # a few hundred steps
Re-running with the same --ckpt-dir resumes from the latest checkpoint.
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import TokenStream
from repro.training.loop import TrainLoop


def build_cfg(preset: str):
    base = get_config("qwen2-1.5b")
    if preset == "smoke":
        return base.reduced(), 20, 4, 64
    # ~100M-param dense transformer
    cfg = dataclasses.replace(
        base.reduced(), name="qwen2-100m", n_layers=12, d_model=768,
        head_dim=64, n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=8192)
    return cfg, 300, 8, 256


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    cfg, steps, batch, seq = build_cfg(args.preset)
    steps = args.steps or steps
    print(f"model={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"steps={steps} batch={batch} seq={seq}")

    tcfg = TrainConfig(learning_rate=3e-4, warmup_steps=max(10, steps // 10),
                       total_steps=steps, remat="full")
    stream = TokenStream(cfg.vocab_size, batch, seq, seed=0)
    loop = TrainLoop(cfg, tcfg, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                     dtype=jnp.float32, log_every=1)

    def on_step(step, metrics):
        if step % 10 == 0:
            print(f"step {step:4d} loss={metrics['loss']:.4f} "
                  f"grad_norm={metrics['grad_norm']:.3f} "
                  f"lr={metrics['lr']:.2e} {metrics['step_time_s']*1e3:.0f}ms")

    final = loop.run(stream, steps, on_step=on_step)
    print("final:", {k: round(float(v), 4) for k, v in final.items()})


if __name__ == "__main__":
    main()
