"""Regenerate every paper experiment (Figs 5–9) as console tables.

  PYTHONPATH=src python examples/simulate_paper.py
"""
from benchmarks import paper_figures as pf


def main():
    for name, fn in [("Table 2 (model zoo)", pf.table2_zoo),
                     ("Fig 3 (on-device vs cloud)", pf.fig3_latency_table),
                     ("Fig 5 (prototype e2e)", pf.fig5_prototype),
                     ("Fig 6 (vs static greedy)", pf.fig6_vs_static_greedy),
                     ("Fig 7 (CV sweep)", pf.fig7_cv_sweep),
                     ("Fig 8 (usage vs CV)", pf.fig8_usage_vs_cv),
                     ("Fig 9 (decomposition)", pf.fig9_decomposition)]:
        print(f"\n=== {name} ===")
        for row in fn():
            print(f"  {row[0]:34s} {row[2]}")


if __name__ == "__main__":
    main()
