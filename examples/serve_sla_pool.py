"""End-to-end serving driver: a real JAX model pool behind ModiPick.

Builds a width-scaled qwen2 family (the LLM analogue of the paper's
MobileNet↔Inception spectrum), serves batched requests with simulated
mobile-network uplinks, and compares ModiPick against the greedy
baselines — with REAL measured prefill+decode latencies, EWMA profile
learning, and hedged-request straggler mitigation.

  PYTHONPATH=src python examples/serve_sla_pool.py --requests 100
"""
import argparse

import numpy as np

from repro.configs.registry import get_config
from repro.core.netmodel import NetworkModel
from repro.core.policy import DynamicGreedy, ModiPick, StaticGreedy
from repro.serving.executor import PoolExecutor
from repro.serving.pool import scaled_family


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--widths", default="0.5,1.0,2.0")
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--sla-ms", type=float, default=120.0)
    ap.add_argument("--net-mean-ms", type=float, default=20.0)
    ap.add_argument("--net-std-ms", type=float, default=10.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--decode-tokens", type=int, default=2)
    ap.add_argument("--hedging", action="store_true")
    args = ap.parse_args()

    widths = tuple(float(w) for w in args.widths.split(","))
    print(f"building pool: {args.arch} at widths {widths} ...")
    variants = scaled_family(get_config(args.arch), widths=widths,
                             cache_len=args.seq + args.decode_tokens + 8)
    tokens = np.random.default_rng(0).integers(
        0, 500, (args.batch, args.seq), dtype=np.int32)
    net = NetworkModel(mean_ms=args.net_mean_ms, std_ms=args.net_std_ms)

    policies = [
        ("modipick", ModiPick(t_threshold=25.0)),
        ("dynamic_greedy", DynamicGreedy()),
        ("static_greedy", StaticGreedy(args.sla_ms)),
    ]
    for name, policy in policies:
        ex = PoolExecutor(variants, net, policy, seed=3,
                          hedging=args.hedging)
        ex.warm_up(tokens, n_decode=args.decode_tokens)
        if name == "modipick":
            print("learned profiles:",
                  {k: f"{v['mu']:.0f}±{v['sigma']:.0f}ms"
                   for k, v in ex.store.snapshot().items()})
        for _ in range(args.requests):
            ex.execute(tokens, t_sla=args.sla_ms,
                       n_decode=args.decode_tokens)
        s = ex.summary()
        usage = {k: round(v, 2) for k, v in s["usage"].items()}
        print(f"{name:15s} attain={s['sla_attainment']:.2f} "
              f"quality={s['mean_quality']:.3f} "
              f"mean={s['mean_latency_ms']:.0f}ms p99={s['p99_latency_ms']:.0f}ms "
              f"hedged={s['hedged']} usage={usage}")


if __name__ == "__main__":
    main()
