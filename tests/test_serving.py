"""Serving-runtime tests with deterministic fake variants (no JAX), plus
straggler-mitigation behaviour."""
from dataclasses import dataclass
from typing import Callable

import numpy as np
import pytest

from repro.core.netmodel import NetworkModel
from repro.core.policy import DynamicGreedy, ModiPick, StaticGreedy
from repro.serving.executor import PoolExecutor


@dataclass
class FakeVariant:
    name: str
    quality: float
    latency_fn: Callable[[], float]

    def run(self, tokens, n_decode=2) -> float:
        return float(self.latency_fn())


def make_pool(rng):
    return [
        FakeVariant("small", 0.5, lambda: rng.normal(10, 1)),
        FakeVariant("medium", 0.7, lambda: rng.normal(30, 2)),
        FakeVariant("large", 0.9, lambda: rng.normal(80, 4)),
    ]


def executor(policy, seed=0, hedging=False, straggler=None):
    rng = np.random.default_rng(seed)
    pool = make_pool(rng)
    if straggler:
        base = pool[2].latency_fn
        pool[2] = FakeVariant(
            "large", 0.9,
            lambda: base() * (20.0 if rng.random() < straggler else 1.0))
    ex = PoolExecutor(pool, NetworkModel(15.0, 7.0), policy, seed=seed,
                      hedging=hedging)
    ex.warm_up(np.zeros((1, 4), np.int32))
    return ex


def test_modipick_mixes_variants_meeting_sla():
    ex = executor(ModiPick(t_threshold=20.0), seed=1)
    for _ in range(300):
        ex.execute(np.zeros((1, 4), np.int32), t_sla=120.0)
    s = ex.summary()
    assert s["sla_attainment"] > 0.9
    assert s["usage"].get("large", 0) > 0.3  # budget allows the best model


def test_tight_sla_prefers_small():
    ex = executor(ModiPick(t_threshold=10.0), seed=2)
    for _ in range(300):
        ex.execute(np.zeros((1, 4), np.int32), t_sla=45.0)
    s = ex.summary()
    assert s["usage"].get("small", 0) > 0.5
    assert s["usage"].get("large", 0) < 0.1


def test_profiles_learn_real_latencies():
    ex = executor(DynamicGreedy(), seed=3)
    for _ in range(200):
        ex.execute(np.zeros((1, 4), np.int32), t_sla=200.0)
    snap = ex.store.snapshot()
    assert abs(snap["large"]["mu"] - 80) < 10
    assert abs(snap["small"]["mu"] - 10) < 5


def test_hedging_caps_straggler_tail():
    """With 5% 20× stragglers on the large variant, hedged re-issue caps
    the p99 latency; without hedging the tail blows up."""
    def run(hedging):
        ex = executor(StaticGreedy(300.0), seed=4, hedging=hedging,
                      straggler=0.05)
        for _ in range(400):
            ex.execute(np.zeros((1, 4), np.int32), t_sla=300.0)
        return ex.summary()

    no_hedge = run(False)
    hedge = run(True)
    assert hedge["hedged"] > 0
    assert hedge["p99_latency_ms"] < no_hedge["p99_latency_ms"] * 0.7
    assert hedge["sla_attainment"] >= no_hedge["sla_attainment"]


def test_queue_aware_executor_prices_out_backlogged_variant():
    """With an injected 200ms backlog estimate on 'large', queue-aware
    routing excludes it (shifted μ blows the budget) and shifts traffic
    to 'medium' — while plain routing keeps using 'large'."""
    waits = {"small": 0.0, "medium": 0.0, "large": 200.0}

    def run(queue_aware):
        rng = np.random.default_rng(6)
        ex = PoolExecutor(make_pool(rng), NetworkModel(15.0, 7.0),
                          ModiPick(t_threshold=20.0), seed=6,
                          queue_aware=queue_aware,
                          w_queue_fn=lambda n: waits[n])
        ex.warm_up(np.zeros((1, 4), np.int32))
        for _ in range(200):
            ex.execute(np.zeros((1, 4), np.int32), t_sla=150.0)
        return ex.summary()

    qa, plain = run(True), run(False)
    assert qa["usage"].get("large", 0.0) < 0.05
    assert qa["usage"].get("medium", 0.0) > 0.3
    assert plain["usage"].get("large", 0.0) > 0.2


def test_sigma_aware_routing_derates_straggling_variant():
    """ModiPick's σ-aware stage 1 shifts traffic away from a variant whose
    latency becomes erratic — the paper's co-tenant scenario, live."""
    ex = executor(ModiPick(t_threshold=20.0), seed=5, straggler=0.15)
    for _ in range(400):
        ex.execute(np.zeros((1, 4), np.int32), t_sla=150.0)
    s = ex.summary()
    # the erratic 'large' variant loses traffic to 'medium'
    assert s["usage"].get("medium", 0) > s["usage"].get("large", 0)
