"""Discrete-event serving simulator: determinism, conservation,
closed-loop equivalence, queue-aware budgets, and load behaviour."""
import numpy as np
import pytest
from conftest import given, settings, st

from repro.core.netmodel import NetworkModel
from repro.core.policy import (DynamicGreedy, ModiPick, StaticGreedy,
                               budget)
from repro.core.profiles import ModelProfile, ProfileStore
from repro.core.simulate import Simulator
from repro.core.zoo import TABLE2
from repro.sim import (ClosedLoopArrivals, PoissonArrivals, QueueAwareSelector,
                       ServingSimulator, TraceArrivals, per_model_replicas,
                       queue_aware_budget, shared_replicas, shifted_store)

NET = NetworkModel(50.0, 25.0)


def engine(replicas=None, *, seed=0, queue_aware=False, **kw):
    return ServingSimulator(TABLE2, NET,
                            replicas or per_model_replicas(TABLE2),
                            seed=seed, queue_aware=queue_aware, **kw)


def result_key(r):
    return (r.n_arrived, r.n_completed, r.n_rejected, r.sla_attainment,
            r.mean_accuracy, r.mean_latency, r.p99_latency,
            r.mean_queue_wait, tuple(sorted(r.model_usage.items())))


# ----------------------------------------------------------------------
# determinism
def test_deterministic_under_fixed_seed():
    a = engine(seed=3, queue_aware=True).run(
        ModiPick(t_threshold=20.0), 250.0, 600,
        arrivals=PoissonArrivals(30.0))
    b = engine(seed=3, queue_aware=True).run(
        ModiPick(t_threshold=20.0), 250.0, 600,
        arrivals=PoissonArrivals(30.0))
    assert result_key(a) == result_key(b)


# ----------------------------------------------------------------------
# conservation
def test_conservation_all_requests_accounted():
    sim = engine(per_model_replicas(TABLE2, max_queue_depth=2), seed=5,
                 queue_aware=False)
    n = 800
    r = sim.run(ModiPick(t_threshold=20.0), 250.0, n,
                arrivals=PoissonArrivals(60.0))
    assert r.n_arrived == n
    assert r.n_completed + r.n_rejected == n
    assert r.n_rejected > 0  # depth-2 caps under 60 rps must shed load


def test_rejections_count_as_sla_misses():
    sim = engine(per_model_replicas(TABLE2, max_queue_depth=1), seed=5)
    r = sim.run(ModiPick(t_threshold=20.0), 250.0, 500,
                arrivals=PoissonArrivals(80.0))
    met_upper = (r.n_arrived - r.n_rejected) / r.n_arrived
    assert r.sla_attainment <= met_upper + 1e-12


# ----------------------------------------------------------------------
# closed-loop / zero-load equivalence
def test_closed_loop_has_zero_queue_wait():
    r = engine(shared_replicas(1), seed=1).run(
        ModiPick(t_threshold=20.0), 200.0, 400,
        arrivals=ClosedLoopArrivals())
    assert r.mean_queue_wait == 0.0
    assert r.n_rejected == 0


def test_queue_aware_closed_loop_identical_to_plain():
    """W_queue == 0 throughout a closed loop, so queue-aware selection
    must reduce exactly to Eq. 1 behaviour — bit-identical results."""
    plain = engine(shared_replicas(1), seed=2).run(
        ModiPick(t_threshold=20.0), 200.0, 400)
    qa = engine(shared_replicas(1), seed=2, queue_aware=True).run(
        ModiPick(t_threshold=20.0), 200.0, 400)
    assert result_key(plain) == result_key(qa)


def test_zero_load_open_loop_matches_paper_closed_loop():
    """At negligible arrival rate the open-loop engine reproduces the
    paper's closed-loop results within sampling tolerance."""
    n, sla = 800, 200.0
    closed = Simulator(entries=TABLE2, network=NET, seed=1).run(
        ModiPick(t_threshold=20.0), sla, n)
    open_ = engine(seed=1).run(
        ModiPick(t_threshold=20.0), sla, n,
        arrivals=PoissonArrivals(0.2))  # 5s gaps >> max service time
    assert open_.mean_queue_wait < 1.0
    assert abs(open_.sla_attainment - closed.sla_attainment) < 0.05
    assert abs(open_.mean_accuracy - closed.mean_accuracy) < 0.05
    assert abs(open_.mean_latency - closed.mean_latency) < 15.0


# ----------------------------------------------------------------------
# queue-aware budget algebra
def test_queue_aware_budget_reduces_to_eq1():
    assert queue_aware_budget(200.0, 30.0, 0.0) == budget(200.0, 30.0)
    assert queue_aware_budget(200.0, 30.0, 25.0) == 115.0


def store_from(specs):
    profiles = []
    for i, (acc, mu, sigma) in enumerate(specs):
        p = ModelProfile(name=f"m{i}", accuracy=acc)
        p.mu, p.var, p.n_obs = mu, sigma ** 2, 100
        profiles.append(p)
    return ProfileStore(profiles)


pool_strategy = st.lists(
    st.tuples(st.floats(0.05, 1.0), st.floats(1.0, 200.0),
              st.floats(0.0, 20.0)),
    min_size=1, max_size=12)


@given(pool_strategy, st.floats(10.0, 500.0), st.floats(0.0, 50.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_selector_with_zero_wait_equals_plain_policy(pool, t_budget,
                                                     threshold, seed):
    store = store_from(pool)
    policy = ModiPick(t_threshold=threshold)
    plain = policy.select_traced(store, t_budget,
                                 np.random.default_rng(seed))
    qa = QueueAwareSelector(policy).select_traced(
        store, t_budget, lambda m: 0.0, np.random.default_rng(seed))
    assert plain == qa


@given(pool_strategy, st.floats(10.0, 500.0), st.floats(1.0, 100.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_shifted_store_moves_means_only(pool, t_budget, wait, seed):
    store = store_from(pool)
    view = shifted_store(store, lambda m: wait)
    assert view is not store
    for name in store.names():
        assert view[name].mu == pytest.approx(store[name].mu + wait)
        assert view[name].sigma == pytest.approx(store[name].sigma)
        assert view[name].accuracy == store[name].accuracy


def test_queue_aware_respects_shifted_budget():
    """A model whose queue wait eats the whole budget must not be
    chosen by the greedy stage."""
    store = store_from([(0.9, 50.0, 1.0), (0.5, 10.0, 1.0)])
    sel = QueueAwareSelector(DynamicGreedy())
    rng = np.random.default_rng(0)
    # plain: the accurate m0 fits a 100ms budget
    assert DynamicGreedy().select(store, 100.0, rng) == "m0"
    # 80ms backlog in front of m0 pushes it over; m1 idle
    waits = {"m0": 80.0, "m1": 0.0}
    trace = sel.select_traced(store, 100.0, lambda m: waits[m], rng)
    assert trace.chosen == "m1"
    assert not trace.fallback


# ----------------------------------------------------------------------
# request lifecycle / ordering
def test_fifo_order_per_replica():
    sim = engine(shared_replicas(2), seed=9)
    r = sim.run(DynamicGreedy(), 400.0, 400,
                arrivals=PoissonArrivals(50.0))
    assert r.n_completed == 400
    assert r.mean_queue_wait >= 0.0
    # peak depth must have exceeded 1 for the FIFO to be exercised
    assert r.peak_queue_depth > 1


def test_trace_arrivals_replayed_exactly():
    times = [0.0, 10.0, 500.0, 1500.0, 1501.0]
    sim = engine(shared_replicas(1), seed=4)
    r = sim.run(DynamicGreedy(), 400.0, len(times),
                arrivals=TraceArrivals(times))
    assert r.n_arrived == len(times)
    assert r.n_completed == len(times)


def test_utilization_and_usage_consistency():
    r = engine(seed=6, queue_aware=True).run(
        ModiPick(t_threshold=20.0), 250.0, 500,
        arrivals=PoissonArrivals(20.0))
    assert abs(sum(r.model_usage.values()) - 1.0) < 1e-9
    assert all(0.0 <= u <= 1.0 + 1e-9
               for u in r.replica_utilization.values())


# ----------------------------------------------------------------------
# the headline: queue-awareness under load
def test_queue_aware_beats_plain_modipick_at_high_load():
    """Acceptance: at high arrival rates queue-aware ModiPick wins on
    SLA attainment (the queue-blind paper policy keeps feeding
    saturated endpoints)."""
    def run(qa):
        return engine(seed=7, queue_aware=qa).run(
            ModiPick(t_threshold=20.0), 250.0, 1000,
            arrivals=PoissonArrivals(40.0))
    plain, qa = run(False), run(True)
    assert qa.sla_attainment > plain.sla_attainment + 0.3
    assert qa.mean_queue_wait < plain.mean_queue_wait
    # the win is a *selection* effect, not a traffic drop
    assert qa.n_completed == plain.n_completed == 1000


def test_static_greedy_collapses_under_load_too():
    r = engine(seed=8).run(StaticGreedy(250.0), 250.0, 600,
                           arrivals=PoissonArrivals(40.0))
    assert r.sla_attainment < 0.5  # one endpoint takes all the traffic


@pytest.mark.slow
def test_paper_scale_closed_loop_10k():
    """Paper-scale 10k-request closed loop (opt-in: ``-m slow``)."""
    r = Simulator(entries=TABLE2, network=NET, seed=1).run(
        ModiPick(t_threshold=20.0), 250.0, 10_000)
    assert r.n == 10_000
    assert r.sla_attainment > 0.9
