"""Vectorized policy engine: scalar↔batched equivalence (property sweeps
via the conftest shim), ProfileTable snapshot semantics, the seeded
end-to-end goldens pinning the ProfileTable rewire, StaticGreedy
re-freeze, the rejected-inclusive utilization horizon, and the
benchmark-harness smoke."""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from conftest import given, settings, st

from repro.core import policy_vec
from repro.core.netmodel import NetworkModel
from repro.core.policy import (DynamicGreedy, ModiPick, PureRandom,
                               RelatedAccurate, RelatedRandom, StaticGreedy)
from repro.core.profiles import ModelProfile, ProfileStore, ProfileTable
from repro.core.simulate import Simulator
from repro.core.zoo import TABLE2, make_store, true_profiles
from repro.sim import (PoissonArrivals, ServingSimulator, SimRequest,
                       per_model_replicas, shared_replicas)

REPO = Path(__file__).resolve().parent.parent
NET = NetworkModel(50.0, 25.0)


def store_from(specs, alpha=0.1):
    profiles = []
    for i, (acc, mu, sigma) in enumerate(specs):
        p = ModelProfile(name=f"m{i}", accuracy=acc)
        p.mu, p.var, p.n_obs = mu, sigma ** 2, 100
        profiles.append(p)
    return ProfileStore(profiles, alpha=alpha)


pool_strategy = st.lists(
    st.tuples(st.floats(0.05, 1.0),      # accuracy
              st.floats(1.0, 200.0),     # mu
              st.floats(0.0, 20.0)),     # sigma
    min_size=1, max_size=12)

budgets_strategy = st.lists(st.floats(-20.0, 500.0), min_size=1, max_size=32)


# ----------------------------------------------------------------------
# ProfileTable snapshot semantics
# ----------------------------------------------------------------------

def test_table_cached_and_patched_in_place_on_observation():
    store = store_from([(0.9, 50, 1), (0.5, 5, 1)])
    t1 = store.table()
    assert store.table() is t1          # cached, no per-call rebuild
    store.observe("m1", 7.0)            # telemetry patches in place
    t2 = store.table()
    assert t2 is t1                     # no snapshot churn per observe
    assert t2.mu[1] == store["m1"].mu
    assert t2.sigma[1] == store["m1"].sigma
    store.observe_queue("m0", 3.0)
    assert store.table() is t1
    assert t1.queue_mu[0] == store["m0"].queue_mu
    # the patched snapshot equals a from-scratch rebuild, field for field
    fresh = ProfileTable.from_store(store)
    np.testing.assert_array_equal(t1.mu, fresh.mu)
    np.testing.assert_array_equal(t1.sigma, fresh.sigma)
    np.testing.assert_array_equal(t1.queue_mu, fresh.queue_mu)
    assert t1.fastest == fresh.fastest
    np.testing.assert_array_equal(t1.acc_order, fresh.acc_order)
    # explicit invalidation (direct profile mutation) still rebuilds
    store.invalidate()
    assert store.table() is not t1


def test_table_order_matches_scalar_sort():
    store = store_from([(0.5, 9, 0), (0.9, 5, 0), (0.5, 3, 0), (0.7, 1, 0)])
    tab = store.table()
    expect = [p.name for p in sorted(store.profiles.values(),
                                     key=lambda p: -p.accuracy)]
    assert [tab.names[i] for i in tab.acc_order] == expect  # stable ties
    assert tab.names[tab.fastest] == "m3"


def test_shifted_table_reuses_order_and_moves_mu():
    store = store_from([(0.9, 50, 2), (0.5, 5, 1)])
    tab = store.table()
    sh = tab.shifted(np.array([100.0, 0.0]))
    assert sh.acc_order is tab.acc_order
    assert sh.mu[0] == 150.0 and sh.mu[1] == 5.0
    assert np.all(sh.sigma == tab.sigma)
    assert sh.fastest == 1


# ----------------------------------------------------------------------
# scalar ↔ batched equivalence
# ----------------------------------------------------------------------

@given(pool_strategy, budgets_strategy, st.floats(0.0, 50.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=150, deadline=None)
def test_deterministic_policies_bit_identical(pool, budgets, threshold, seed):
    store = store_from(pool)
    budgets = np.asarray(budgets)
    for policy in (DynamicGreedy(), RelatedAccurate(threshold),
                   StaticGreedy(t_sla=float(budgets[0]) + threshold)):
        batched = policy.select_batch(store, budgets,
                                      np.random.default_rng(seed),
                                      backend="numpy")
        scalar = [policy.select(store, float(b), np.random.default_rng(seed))
                  for b in budgets]
        assert batched == scalar, policy.name


@given(pool_strategy, budgets_strategy, st.floats(0.0, 50.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=150, deadline=None)
def test_modipick_probability_vectors_match_scalar(pool, budgets, threshold,
                                                   seed):
    store = store_from(pool)
    tab = store.table()
    budgets = np.asarray(budgets)
    policy = ModiPick(t_threshold=threshold)
    t_u, t_l = budgets, budgets - threshold
    base, has_base, elig, _ = policy_vec.modipick_masks(tab, t_u, t_l)
    probs = policy_vec.modipick_probs(tab, t_u, t_l, elig, policy.gamma)
    for b, tb in enumerate(budgets):
        trace = policy.select_traced(store, float(tb),
                                     np.random.default_rng(seed))
        if trace.fallback:
            assert not has_base[b]
            assert probs[b].sum() == 0.0
            continue
        assert has_base[b]
        assert tab.names[base[b]] == trace.base
        scalar = dict(zip(trace.eligible, trace.probs))
        for j, name in enumerate(tab.names):
            assert abs(probs[b, j] - scalar.get(name, 0.0)) < 1e-9


@given(pool_strategy, budgets_strategy, st.floats(0.0, 50.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_batched_picks_always_valid(pool, budgets, threshold, seed):
    """Every batched pick is a pool member, and infeasible rows fall back
    to the fastest model exactly like the scalar path."""
    store = store_from(pool)
    tab = store.table()
    rng = np.random.default_rng(seed)
    budgets = np.asarray(budgets)
    for policy in (ModiPick(threshold), RelatedRandom(threshold),
                   PureRandom()):
        names = policy.select_batch(store, budgets, rng, backend="numpy")
        assert len(names) == len(budgets)
        assert set(names) <= set(tab.names)
    mp = ModiPick(threshold)
    names = mp.select_batch(store, budgets, rng, backend="numpy")
    for b, tb in enumerate(budgets):
        if mp.select_traced(store, float(tb),
                            np.random.default_rng(0)).fallback:
            assert names[b] == tab.names[tab.fastest]


def test_modipick_batch_frequencies_match_probs():
    """Gumbel-top-1 sampling draws from the same law as the scalar
    rng.choice loop: empirical frequencies at a fixed budget converge to
    the scalar probability vector."""
    store = make_store(TABLE2)
    mp = ModiPick(t_threshold=20.0)
    trace = mp.select_traced(store, 180.0, np.random.default_rng(0))
    B = 100_000
    names = mp.select_batch(store, np.full(B, 180.0),
                            np.random.default_rng(3), backend="numpy")
    for name, p in zip(trace.eligible, trace.probs):
        assert abs(names.count(name) / B - p) < 0.01


def test_backend_env_override_and_validation(monkeypatch):
    store = make_store(TABLE2)
    budgets = np.full(8, 200.0)
    monkeypatch.setenv("REPRO_POLICY_BACKEND", "numpy")
    assert len(ModiPick(20.0).select_batch(
        store, budgets, np.random.default_rng(0))) == 8
    monkeypatch.setenv("REPRO_POLICY_BACKEND", "bogus")
    with pytest.raises(ValueError):
        ModiPick(20.0).select_batch(store, budgets, np.random.default_rng(0))


def test_jax_backend_matches_numpy_distribution():
    """The jitted/Pallas stage 3 produces the same probability rows as
    the numpy reference (float32 tolerance) and valid picks."""
    from repro.kernels import ops
    store = make_store(TABLE2)
    tab = store.table()
    rng = np.random.default_rng(5)
    budgets = rng.uniform(5.0, 350.0, size=257)  # odd size exercises padding
    t_u, t_l = budgets, budgets - 20.0
    _, has_base, elig, _ = policy_vec.modipick_masks(tab, t_u, t_l)
    expect = policy_vec.modipick_probs(tab, t_u, t_l, elig, 1.0)
    got = np.asarray(ops.modipick_probs(tab.mu, tab.sigma, tab.accuracy,
                                        t_u, t_l, elig, gamma=1.0))
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)
    names = ModiPick(20.0).select_batch(store, budgets,
                                        np.random.default_rng(0),
                                        backend="jax")
    assert set(names) <= set(tab.names)
    for b in np.flatnonzero(~has_base):
        assert names[b] == tab.names[tab.fastest]


# ----------------------------------------------------------------------
# seeded end-to-end goldens: the ProfileTable rewire changed nothing
# ----------------------------------------------------------------------

def test_golden_closed_loop_unchanged():
    r = Simulator(entries=TABLE2, network=NET, seed=1).run(
        ModiPick(t_threshold=20.0), 200.0, 800)
    assert r.sla_attainment == 0.9775
    assert r.mean_accuracy == 0.7813437499999999
    assert r.mean_latency == 164.8560532103827
    assert r.p99_latency == 211.51074909935923


def test_golden_queue_aware_open_loop_unchanged():
    eng = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2), seed=3,
                           queue_aware=True)
    r = eng.run(ModiPick(t_threshold=20.0), 250.0, 600,
                arrivals=PoissonArrivals(30.0))
    assert (r.n_arrived, r.n_completed, r.n_rejected) == (600, 600, 0)
    assert r.sla_attainment == 0.9983333333333333
    assert r.mean_accuracy == 0.7975266666666666
    assert r.mean_latency == 191.67831081440173
    assert r.mean_queue_wait == 23.493148434870164


def test_golden_shedding_run_unchanged():
    eng = ServingSimulator(TABLE2, NET,
                           per_model_replicas(TABLE2, max_queue_depth=2),
                           seed=5)
    r = eng.run(DynamicGreedy(), 250.0, 500, arrivals=PoissonArrivals(60.0))
    assert (r.n_arrived, r.n_completed, r.n_rejected) == (500, 179, 321)
    assert r.sla_attainment == 0.178
    assert r.mean_accuracy == 0.8064134078212288
    assert r.mean_latency == 255.1617447042085
    assert r.p99_latency == 342.641615613392
    assert r.mean_queue_wait == 47.55524286454602


# ----------------------------------------------------------------------
# StaticGreedy freeze semantics
# ----------------------------------------------------------------------

def test_static_greedy_refreezes_per_store():
    """Regression: one StaticGreedy instance reused across sweep points
    must freeze against each point's store, not leak the first pick."""
    rng = np.random.default_rng(0)
    pol = StaticGreedy(t_sla=60.0)
    a = store_from([(0.9, 50, 1), (0.5, 5, 1)])
    assert pol.select(a, 10.0, rng) == "m0"
    # within one store the pick stays frozen through drift...
    a.profiles["m0"].mu = 500.0
    a.invalidate()
    assert pol.select(a, 10.0, rng) == "m0"
    # ...but a different store (a new sweep point) re-freezes.
    b = store_from([(0.9, 500, 1), (0.5, 5, 1)])  # m0 too slow here
    assert pol.select(b, 10.0, rng) == "m1"


def test_static_greedy_reset():
    rng = np.random.default_rng(0)
    store = store_from([(0.9, 50, 1), (0.5, 5, 1)])
    pol = StaticGreedy(t_sla=60.0)
    assert pol.select(store, 10.0, rng) == "m0"
    store.profiles["m0"].mu = 500.0
    store.invalidate()
    assert pol.select(store, 10.0, rng) == "m0"  # still frozen
    pol.reset()
    assert pol.select(store, 10.0, rng) == "m1"  # re-frozen post-drift


def test_static_greedy_stays_frozen_under_queue_aware_views():
    """Queue-aware wrapping builds a fresh shifted view per selection;
    the view's ``base`` points back at the real store, so the frozen
    pick must not thaw once W_queue telemetry arrives."""
    from repro.sim import QueueAwareSelector, shifted_store
    store = store_from([(0.9, 50, 1), (0.5, 5, 1)])
    rng = np.random.default_rng(0)
    pol = StaticGreedy(t_sla=60.0)
    sel = QueueAwareSelector(pol)
    assert sel.select(store, 100.0, lambda m: 0.0, rng) == "m0"
    # heavy backlog in front of m0: a shifted view per call, every call
    waits = {"m0": 500.0, "m1": 0.0}
    for _ in range(3):
        assert sel.select(store, 100.0, lambda m: waits[m], rng) == "m0"
    view = shifted_store(store, lambda m: waits[m])
    assert view.base is store


def test_static_greedy_batch_on_bare_table_honours_frozen_pick():
    store = store_from([(0.9, 50, 1), (0.5, 5, 1)])
    pol = StaticGreedy(t_sla=60.0)
    assert pol.select(store, 10.0, np.random.default_rng(0)) == "m0"
    store.profiles["m0"].mu = 500.0  # drift after freeze
    store.invalidate()
    batched = pol.select_batch(store.table(), np.full(4, 10.0),
                               np.random.default_rng(0), backend="numpy")
    assert batched == ["m0"] * 4  # matches what 4 scalar calls return


def test_select_batch_unknown_subclass_falls_back_to_scalar():
    class SharpModiPick(ModiPick):
        """Subclass overriding stage 3 — must not ride ModiPick's batch."""
        def _probs_indices(self, tab, idxs, t_u, t_l):
            p = np.zeros(len(idxs))
            p[int(np.argmax(tab.accuracy[idxs]))] = 1.0
            return p

    store = store_from([(0.9, 50, 1), (0.5, 5, 1), (0.7, 20, 1)])
    budgets = np.full(16, 100.0)
    pol = SharpModiPick(t_threshold=50.0)
    batched = pol.select_batch(store, budgets, np.random.default_rng(0),
                               backend="numpy")
    scalar = [pol.select(store, 100.0, np.random.default_rng(0))
              for _ in budgets]
    assert batched == scalar  # scalar fallback, not Gumbel sampling
    with pytest.raises(TypeError):
        pol.select_batch(store.table(), budgets, np.random.default_rng(0),
                         backend="numpy")


def test_static_greedy_reuse_across_rate_sweep_points():
    from repro.sim.engine import rate_sweep
    sim = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2), seed=2)
    shared = StaticGreedy(250.0)
    reused = rate_sweep(sim, lambda: shared, (5.0, 20.0), 250.0,
                        n_requests=150)
    sim2 = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2), seed=2)
    fresh = rate_sweep(sim2, lambda: StaticGreedy(250.0), (5.0, 20.0), 250.0,
                       n_requests=150)
    for a, b in zip(reused, fresh):
        assert a.model_usage == b.model_usage
        assert a.sla_attainment == b.sla_attainment


# ----------------------------------------------------------------------
# utilization horizon includes rejected requests
# ----------------------------------------------------------------------

def _req(rid, arrival, depart, model="SqueezeNet", service=0.0,
         rejected=False):
    r = SimRequest(rid=rid, arrival_ms=arrival, model=model,
                   service_ms=service, rejected=rejected)
    r.depart_ms = depart
    return r


def test_summarise_horizon_spans_rejected_requests():
    sim = ServingSimulator(TABLE2, NET, shared_replicas(1), seed=0)
    sim.pool.replicas[0].busy_ms = 50.0
    truth = true_profiles(TABLE2)
    completed = [_req(0, 0.0, 100.0, service=50.0)]
    late_reject = _req(1, 900.0, 1000.0, rejected=True)
    with_rej = sim._summarise("p", 250.0, truth, completed, [late_reject])
    assert with_rej.horizon_ms == pytest.approx(1000.0)
    assert with_rej.replica_utilization["r0"] == pytest.approx(50.0 / 1000.0)
    # without the rejected tail the horizon would have been 100ms and
    # utilization inflated 10x:
    without = sim._summarise("p", 250.0, truth, completed, [])
    assert without.horizon_ms == pytest.approx(100.0)
    assert without.replica_utilization["r0"] == pytest.approx(0.5)


# ----------------------------------------------------------------------
# bench harness smoke: the throughput benchmark cannot silently rot
# ----------------------------------------------------------------------

def test_policy_throughput_smoke(tmp_path):
    """Fast invocation of ``benchmarks/run.py policy_throughput`` — runs
    the harness end-to-end (CSV + --json record) at small batches."""
    env = dict(os.environ,
               PYTHONPATH=f"{REPO / 'src'}{os.pathsep}{REPO}")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only",
         "policy_throughput", "--fast", "--json", "--fail-fast"],
        cwd=tmp_path, env=env, capture_output=True, text=True, timeout=570)
    assert out.returncode == 0, out.stderr
    assert "policy_throughput/scalar/batch_1," in out.stdout
    assert "policy_throughput/numpy/batch_1000," in out.stdout
    data = json.loads((tmp_path / "BENCH_policy_throughput.json").read_text())
    assert data["benchmark"] == "policy_throughput"
    assert any(r["name"].startswith("policy_throughput/numpy/")
               for r in data["rows"])


@pytest.mark.slow
def test_policy_throughput_vectorized_speedup():
    """Acceptance: ≥10× selections/sec over the scalar loop at batch ≥10k
    on the Table-2 zoo (the 100k point is the recorded trajectory)."""
    from benchmarks.policy_throughput import bench_rows
    rows = {name: derived for name, _, derived in
            bench_rows(batches=(100_000,))}
    derived = rows["policy_throughput/numpy/batch_100000"]
    speedup = float(dict(kv.split("=") for kv in derived.split(";"))
                    ["speedup"].rstrip("x"))
    assert speedup >= 10.0, derived
