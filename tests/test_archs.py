"""Per-architecture smoke tests: every assigned arch instantiates a
REDUCED same-family config and runs forward / train / prefill+decode on
CPU, asserting output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import api, model as M
from repro.training.train_step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, seq, batch, key=KEY, with_targets=True):
    b = api.make_train_batch(cfg, ShapeConfig("t", seq, batch, "train"), key)
    if not with_targets:
        b.pop("targets", None)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg, 64, 2)
    loss, metrics = M.forward_train(cfg, params, batch)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    tcfg = TrainConfig(total_steps=2, warmup_steps=1, learning_rate=1e-3)
    params, opt = init_train_state(cfg, KEY, jnp.float32)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, 32, 2)
    params, opt, m1 = step(params, opt, batch)
    params, opt, m2 = step(params, opt, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    # one repeated batch: loss must decrease
    assert float(m2["loss"]) < float(m1["loss"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    """Prefill S tokens + decode token S ≡ full forward over S+1 tokens.
    Validates every cache kind (KV ring, SSM state, RG-LRU state, cross)."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:  # avoid capacity-drop divergence
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_params(cfg, KEY, jnp.float32)
    B, S = 2, 37
    n_img = cfg.vlm.n_image_tokens if cfg.vlm else 0
    full = _batch(cfg, S + 1 + n_img, B, with_targets=False)
    toks = full["tokens"]
    pre = dict(full)
    pre["tokens"] = toks[:, :S]
    cache, _ = M.prefill(cfg, params, pre, cache_len=64)
    pos = jnp.full((B,), S + n_img, jnp.int32)
    lg_dec, _ = M.decode_step(cfg, params, cache, toks[:, S], pos)
    _, lg_full = M.prefill(cfg, params, full, cache_len=64)
    a = np.asarray(lg_dec, np.float32)
    b = np.asarray(lg_full, np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)
    assert rel < 2e-3, f"{arch}: rel={rel:.2e}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact(arch):
    """The full (non-reduced) configs carry the exact assigned shapes."""
    cfg = get_config(arch)
    expected = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_configs():
    dbrx = get_config("dbrx-132b").moe
    assert (dbrx.n_experts, dbrx.top_k) == (16, 4)
    moon = get_config("moonshot-v1-16b-a3b").moe
    assert (moon.n_experts, moon.top_k) == (64, 6)


def test_long_context_skip_list():
    """long_500k runs only for sub-quadratic archs (DESIGN.md skip list)."""
    from repro.configs.registry import applicable_shapes
    runs_500k = {a for a in ARCH_IDS
                 if any(s.name == "long_500k"
                        for s in applicable_shapes(get_config(a)))}
    assert runs_500k == {"recurrentgemma-2b", "mamba2-1.3b", "gemma3-4b"}
