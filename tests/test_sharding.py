"""Distribution-layer unit tests: logical-axis rules, divisibility
fallbacks, HLO collective parsing, gradient compression."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.distributed import compression as comp
from repro.distributed import hlo as hlo_mod
from repro.distributed.sharding import logical_to_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)


def test_divisible_dims_shard():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = logical_to_spec(("embed_fsdp", "ff"),
                           {"embed_fsdp": ("data",), "ff": "model"},
                           shape=(2560, 7680), mesh=mesh)
    assert spec == P(("data",), "model")


def test_indivisible_dim_falls_back_to_replication():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = logical_to_spec(("batch", "seq", "heads", None),
                           {"batch": ("data",), "heads": "model", "seq": None},
                           shape=(32, 128, 10, 256), mesh=mesh)  # 10 heads!
    assert spec == P(("data",), None, None, None)


def test_duplicate_mesh_axes_dropped():
    mesh = FakeMesh({"data": 4, "model": 4})
    spec = logical_to_spec(("batch", "cache_seq"),
                           {"batch": ("data",), "cache_seq": "data"},
                           shape=(16, 64), mesh=mesh)
    assert spec == P(("data",), None)  # 'data' already used by batch


@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 32))
@settings(max_examples=100, deadline=None)
def test_fallback_never_breaks_divisibility(dim0, dim1, axis):
    mesh = FakeMesh({"x": axis})
    spec = logical_to_spec(("a", "b"), {"a": "x", "b": "x"},
                           shape=(dim0, dim1), mesh=mesh)
    for d, s in zip((dim0, dim1), spec):
        if s is not None:
            assert d % axis == 0


# ----------------------------------------------------------------------
HLO_SAMPLE = """
  %all-gather.1 = f32[384,96]{1,0} all-gather(%x), channel_id=1, replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}
  %all-reduce.7 = bf16[1024]{0} all-reduce(%y), channel_id=2, replica_groups=[2,4]<=[8], to_apply=%add
  %all-reduce-done.1 = bf16[8]{0} all-reduce-done(%all-reduce-start.1)
  %rs = f32[128,8]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[1,8]<=[8], dimensions={1}, to_apply=%add
  %cp = u8[64]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
"""


def test_collective_parser_counts_and_bytes():
    stats = hlo_mod.collective_bytes(HLO_SAMPLE)
    assert stats.by_kind_count == {"all-gather": 1, "all-reduce": 1,
                                   "reduce-scatter": 1,
                                   "collective-permute": 1}
    ag = 384 * 96 * 4 * (1 / 2)          # group size 2 → (n-1)/n = 1/2
    ar = 1024 * 2 * 2.0 * (3 / 4)        # group size 4
    rs = 128 * 8 * 4 * 7                  # result × (n-1), group 8
    cp = 64
    assert stats.by_kind["all-gather"] == pytest.approx(ag)
    assert stats.by_kind["all-reduce"] == pytest.approx(ar)
    assert stats.by_kind["reduce-scatter"] == pytest.approx(rs)
    assert stats.by_kind["collective-permute"] == pytest.approx(cp)


def test_roofline_terms():
    r = hlo_mod.Roofline(n_chips=256, hlo_flops=1e18, hlo_bytes=1e15,
                         coll_bytes_per_chip=1e9, model_flops=6e17)
    assert r.compute_s == pytest.approx(1e18 / (256 * hlo_mod.PEAK_FLOPS_BF16))
    assert r.memory_s == pytest.approx(1e15 / (256 * hlo_mod.HBM_BW))
    assert r.collective_s == pytest.approx(1e9 / hlo_mod.ICI_BW)
    assert r.dominant == "compute"
    assert 0 < r.mfu <= 1.0


# ----------------------------------------------------------------------
def test_quantize_roundtrip_error_bound():
    x = np.random.default_rng(0).normal(size=(256,)).astype(np.float32)
    q, scale = comp.quantize_int8(jnp.asarray(x))
    back = comp.dequantize_int8(q, scale)
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With EF, the accumulated quantization error stays bounded and the
    long-run mean of the compressed signal matches the true mean."""
    rng = np.random.default_rng(1)
    err = jnp.zeros(64)
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for _ in range(200):
        g = jnp.asarray(rng.normal(size=64).astype(np.float32)) * 1e-3
        q, scale, err = comp.ef_compress(g, err)
        total_true += np.asarray(g)
        total_sent += np.asarray(comp.dequantize_int8(q, scale))
    # EF guarantees sent ≈ true up to the residual error buffer
    np.testing.assert_allclose(total_sent + np.asarray(err), total_true,
                               rtol=1e-4, atol=1e-5)


def test_compressed_psum_under_shard_map():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((jax.device_count(),), ("d",))
    x = jnp.arange(jax.device_count() * 4, dtype=jnp.float32).reshape(
        jax.device_count(), 4)
    f = shard_map(lambda v: comp.compressed_psum(v[0], "d")[None],
                  mesh=mesh, in_specs=P("d", None), out_specs=P("d", None))
    out = f(x)
    expect = x.mean(axis=0)
    np.testing.assert_allclose(np.asarray(out)[0], np.asarray(expect),
                               rtol=0.02, atol=0.02)
