"""Fleet subsystem: 1-cell zero-RTT parity with the seeded golden,
FleetSpec dict/JSON round trips (incl. single-cell back-compat),
sticky-hash determinism and weight proportionality, spill-budget
honesty (the RTT term), the stacked (cell × batch × pool) device
selection vs the per-cell masks oracle, the shard_map path vs the
single-device vmap (subprocess, fake devices), rate-trace loading, and
a multi-cell end-to-end smoke with spill accounting."""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.fleet import (CellSpec, FleetEngine, FleetFrontend, FleetSpec,
                         cell_view, select_fleet, stack_cell_tables)
from repro.scenario import Scenario, build, get_scenario
from repro.scenario.registry import fleet_scenario
from repro.sim.arrivals import load_rate_counts, load_trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The seeded steady-scenario golden (tests/test_policy_vec.py pins the
# same number): the 1-cell zero-RTT fleet must reproduce it exactly.
GOLDEN_ATTAINMENT = 0.9983333333333333


def _with_fleet(sc, fleet):
    return dataclasses.replace(
        sc, deployment=dataclasses.replace(sc.deployment, fleet=fleet))


# ----------------------------------------------------------------------
# parity: a 1-cell fleet is the single-cell system, bit for bit
# ----------------------------------------------------------------------

def test_one_cell_zero_rtt_fleet_matches_golden():
    """Acceptance: wrapping the steady scenario in a 1-cell zero-RTT
    FleetSpec changes nothing — pick for pick (model usage), shed for
    shed (rejects), and the golden attainment to the last digit."""
    sc = get_scenario("steady")
    base = build(sc).run()
    fl = FleetSpec(cells=(CellSpec("solo"),), rtt_ms=0.0)
    wrapped = build(_with_fleet(sc, fl)).run()
    assert base.result.sla_attainment == GOLDEN_ATTAINMENT
    assert wrapped.result.sla_attainment == GOLDEN_ATTAINMENT
    assert wrapped.result.mean_latency == base.result.mean_latency
    assert wrapped.result.mean_accuracy == base.result.mean_accuracy
    assert wrapped.result.n_rejected == base.result.n_rejected
    assert wrapped.result.model_usage == base.result.model_usage

    fr = FleetEngine(_with_fleet(sc, fl)).run()
    assert fr.sla_attainment == base.result.sla_attainment
    assert fr.n_spilled == 0 and fr.locality == 1.0


# ----------------------------------------------------------------------
# spec: round trips + validation + single-cell back-compat
# ----------------------------------------------------------------------

def test_fleet_spec_round_trips_through_json():
    for name in ("fleet_steady", "fleet_diurnal"):
        s = get_scenario(name)
        assert s.deployment.fleet is not None
        via_json = json.loads(json.dumps(s.to_dict()))
        again = Scenario.from_dict(via_json)
        assert again == s
        assert isinstance(again.deployment.fleet, FleetSpec)
        assert all(isinstance(c, CellSpec)
                   for c in again.deployment.fleet.cells)


def test_single_cell_dicts_stay_compatible():
    """Pre-fleet serialized scenarios (no ``fleet`` key at all, or
    ``fleet: null``) still load, and keep ``fleet is None``."""
    sc = get_scenario("steady")
    d = sc.to_dict()
    assert d["deployment"].get("fleet") is None
    assert Scenario.from_dict(d) == sc
    d["deployment"].pop("fleet", None)
    assert Scenario.from_dict(d).deployment.fleet is None


def test_fleet_spec_validation():
    with pytest.raises(ValueError, match="phase"):
        CellSpec("a", phase=1.0)
    with pytest.raises(ValueError, match="weight"):
        CellSpec("a", weight=0.0)
    with pytest.raises(ValueError, match="duplicate"):
        FleetSpec(cells=(CellSpec("a"), CellSpec("a")))
    with pytest.raises(ValueError, match="rtt_ms"):
        FleetSpec(rtt_ms=-1.0)
    with pytest.raises(ValueError, match="epoch_ms"):
        FleetSpec(epoch_ms=0.0)
    # multi-cell fleets reject features the fleet engine does not step
    with pytest.raises(ValueError, match="fleet"):
        _with_fleet(get_scenario("scale_up"),
                    FleetSpec(cells=(CellSpec("a"), CellSpec("b"))))


# ----------------------------------------------------------------------
# frontend: sticky placement + spilled-budget honesty
# ----------------------------------------------------------------------

def test_sticky_hash_is_deterministic_and_weight_proportional():
    sc = fleet_scenario(n_cells=3, weights=(6.0, 3.0, 1.0),
                        name="t_sticky")
    fe = FleetFrontend(sc)
    rids = np.arange(200_000)
    home = fe.home_of_requests(rids)
    assert np.array_equal(home, fe.home_of_requests(rids))
    # same user id -> same cell, always
    uids = fe.uid_of(rids)
    for u in np.unique(uids)[:50]:
        assert np.unique(home[uids == u]).size == 1
    frac = np.bincount(home, minlength=3) / rids.size
    assert np.allclose(frac, (0.6, 0.3, 0.1), atol=0.02)


def test_spilled_budget_pays_rtt_and_load():
    """Honesty: row c of the budget matrix is T_sla − 2·T_input − L_c,
    minus the cross-cell RTT exactly on non-home rows."""
    sc = fleet_scenario(n_cells=3, rtt_ms=35.0, name="t_budget")
    fe = FleetFrontend(sc)
    home = np.array([0, 1, 2, 0])
    load = np.array([5.0, 11.0, 23.0])
    t_u, t_l = fe.budget_matrix(home, load)
    for c in range(3):
        for b, h in enumerate(home):
            want = (sc.workload.t_sla_ms - fe.net2_ms[h] - load[c]
                    - (35.0 if c != h else 0.0))
            assert t_u[c, b] == pytest.approx(want)
    assert np.allclose(t_u - t_l, fe.t_threshold)


# ----------------------------------------------------------------------
# device: stacked selection vs the per-cell masks oracle
# ----------------------------------------------------------------------

def test_select_fleet_stacked_agrees_with_per_cell_masks():
    """Stacked picks are −1 exactly where the cell has no eligible
    variant (per ``masks_device``, the pinned per-cell oracle), and
    otherwise always land on an eligible, un-padded lane."""
    from repro.kernels.policy_select import masks_device

    sc = fleet_scenario(n_cells=3, name="t_stacked")
    # Heterogeneous pools: cell 1 loses the heavy tail, cell 2 keeps
    # only mid models — different npad per cell exercises re-padding.
    views = [cell_view(sc, c) for c in sc.deployment.fleet.cells]
    views[1] = dataclasses.replace(
        views[1], deployment=dataclasses.replace(
            views[1].deployment,
            subset=("MobileNetV1-0.25", "SqueezeNet", "DenseNet")))
    views[2] = dataclasses.replace(
        views[2], deployment=dataclasses.replace(
            views[2].deployment,
            subset=("DenseNet", "NasNet-Mobile", "InceptionV3",
                    "InceptionV4")))
    from repro.scenario.build import ScenarioHarness
    tables = [ScenarioHarness(v).store().table() for v in views]
    stacked = stack_cell_tables(tables)

    rng = np.random.default_rng(7)
    B = 97    # deliberately unaligned with the 256 bucket
    t_u = rng.uniform(2.0, 200.0, size=(3, B))
    t_l = t_u - 20.0
    picks = select_fleet(stacked, t_u, t_l, gamma=1.0, seed=5)
    assert picks.shape == (3, B) and picks.dtype == np.int32
    for c, tbl in enumerate(tables):
        pool = tbl.device_pool()
        _, has_base, elig = masks_device(pool, t_u[c], t_l[c])
        assert np.array_equal(picks[c] == -1, ~has_base)
        ok = picks[c] >= 0
        assert (picks[c][ok] < pool.n).all()
        assert elig[np.arange(B)[ok], picks[c][ok]].all()
    # same seed -> same picks; different seed may differ
    assert np.array_equal(
        picks, select_fleet(stacked, t_u, t_l, gamma=1.0, seed=5))


_SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.shardmap_ops import sharded_fleet_select
from repro.kernels.policy_select import (PAD_MU, PAD_RANK,
                                         select_fleet_stacked)

C, npad, B = 8, 8, 256   # B on the 256 bucket: identical RNG shapes
rng = np.random.default_rng(3)
mu = np.full((C, npad), PAD_MU, np.float32)
sig = np.zeros((C, npad), np.float32)
acc = np.ones((C, npad), np.float32)
rank = np.full((C, npad), PAD_RANK, np.float32)
for c in range(C):
    n = 3 + c % 5
    mu[c, :n] = rng.uniform(3.0, 120.0, n)
    sig[c, :n] = 0.1 * mu[c, :n]
    acc[c, :n] = rng.uniform(0.5, 0.85, n)
    rank[c, :n] = np.argsort(np.argsort(-acc[c, :n]))
t_u = rng.uniform(2.0, 250.0, size=(C, B)).astype(np.float32)
t_l = t_u - 20.0

ref = select_fleet_stacked(mu, sig, acc, rank, t_u, t_l, gamma=1.0, seed=11)
mesh = jax.make_mesh((8,), ("cell",))
keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
    jax.random.PRNGKey(11), jnp.arange(C, dtype=jnp.uint32))
out = sharded_fleet_select(jnp.asarray(mu), jnp.asarray(sig),
                           jnp.asarray(acc), jnp.asarray(rank),
                           jnp.asarray(t_u), jnp.asarray(t_l), keys, mesh,
                           gamma=1.0)
assert np.array_equal(np.asarray(out), ref), "sharded != vmap"
assert (np.asarray(out) == -1).any() and (np.asarray(out) >= 0).any()
print("sharded fleet ok")
"""


def test_sharded_fleet_select_matches_vmap():
    """shard_map over an 8-way fake-device cell mesh is bit-identical
    to the single-device vmapped `select_fleet_stacked` (subprocess so
    pytest's jax keeps 1 device)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run([sys.executable, "-c", _SHARDED], env=env,
                          capture_output=True, text=True, timeout=480,
                          cwd=ROOT)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "sharded fleet ok" in proc.stdout


# ----------------------------------------------------------------------
# arrivals: rate-trace loading
# ----------------------------------------------------------------------

def test_load_trace_formats(tmp_path):
    counts = [10, 30, 50, 30, 10, 5]
    # JSON object and bare list
    (tmp_path / "a.json").write_text(json.dumps({"counts": counts}))
    (tmp_path / "b.json").write_text(json.dumps(counts))
    # Azure-style CSV: numeric minute columns, one row per function
    (tmp_path / "c.csv").write_text(
        "HashOwner,HashFunction,1,2,3,4,5,6\n"
        "o1,f1,4,12,20,12,4,2\n"
        "o1,f2,6,18,30,18,6,3\n")
    # two-column interval,count and bare one-column
    (tmp_path / "d.csv").write_text(
        "interval,count\n" + "\n".join(f"{i},{c}"
                                       for i, c in enumerate(counts)))
    (tmp_path / "e.csv").write_text("\n".join(str(c) for c in counts))
    for fname in ("a.json", "b.json", "c.csv", "d.csv", "e.csv"):
        got = load_rate_counts(str(tmp_path / fname))
        assert np.allclose(got / got.sum(), np.array(counts) / sum(counts))
        tr = load_trace(str(tmp_path / fname), n=4000, rate_rps=100.0,
                        period_ms=60_000.0, seed=1)
        t = np.asarray(tr.times_ms)
        assert t.size == 4000 and (np.diff(t) >= 0).all()
        # the peak bucket must out-arrive the valley bucket
        k = (t % 60_000.0 / 60_000.0 * len(counts)).astype(int)
        occ = np.bincount(k, minlength=len(counts))
        assert occ[2] > 3 * occ[5]
    with pytest.raises(ValueError, match="phase"):
        load_trace(str(tmp_path / "a.json"), n=10, rate_rps=1.0, phase=1.0)
    (tmp_path / "bad.json").write_text("[0, 0]")
    with pytest.raises(ValueError, match="all-zero"):
        load_trace(str(tmp_path / "bad.json"), n=10, rate_rps=1.0)


# ----------------------------------------------------------------------
# engine: multi-cell end to end
# ----------------------------------------------------------------------

def test_fleet_engine_multi_cell_smoke():
    sc = fleet_scenario(n_cells=3, rate_rps=90.0, n_requests=3_000,
                        epoch_ms=5_000.0, seed=23, name="t_fleet_e2e")
    fr = FleetEngine(sc).run()
    assert fr.n_arrived == 3_000
    assert fr.n_completed + sum(e.result.n_rejected
                                for e in fr.epochs) == 3_000
    assert 0.0 <= fr.spill_rate <= 1.0
    assert fr.locality == 1.0 - fr.spill_rate
    assert fr.sla_attainment > 0.9
    assert len(fr.epochs) >= 2
    # every cell served traffic, and the merged per-epoch results carry
    # per-cell replica utilization under cell-prefixed keys
    served = sum(e.n_assigned for e in fr.epochs)
    assert (served > 0).all()
    keys = set()
    for e in fr.epochs:
        keys.update(e.result.replica_utilization)
    assert any(k.startswith("cell0/") for k in keys)
    assert any(k.startswith("cell2/") for k in keys)
    # the ScenarioResult adapter exposes the same run to suite code
    sr = fr.as_scenario_result()
    assert sr.fleet is fr and len(sr.epochs) == len(fr.epochs)
    assert sr.epochs[0].router_stats["n_routed"] > 0


def test_fleet_spill_stays_off_when_disabled():
    sc = fleet_scenario(n_cells=3, rate_rps=90.0, n_requests=2_000,
                        spill=False, epoch_ms=5_000.0, seed=23,
                        name="t_fleet_nospill")
    fr = FleetEngine(sc).run()
    assert fr.n_spilled == 0 and fr.locality == 1.0


def test_harness_dispatches_multi_cell_fleet_to_fleet_engine():
    sc = fleet_scenario(n_cells=2, rate_rps=60.0, n_requests=1_500,
                        epoch_ms=5_000.0, seed=29, name="t_dispatch")
    out = build(sc).run()
    assert out.fleet is not None
    assert out.fleet.n_cells == 2
    assert sum(e.result.n_arrived for e in out.epochs) == 1_500
