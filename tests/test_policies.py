"""Property tests for the ModiPick selection policies (seeded sweeps via
the conftest shim; uses real hypothesis when available)."""
import math

import numpy as np
import pytest
from conftest import given, settings, st

from repro.core.policy import (DynamicGreedy, ModiPick, PureRandom,
                               RelatedAccurate, RelatedRandom, StaticGreedy,
                               budget)
from repro.core.profiles import ModelProfile, ProfileStore


def store_from(specs, alpha=0.1):
    profiles = []
    for i, (acc, mu, sigma) in enumerate(specs):
        p = ModelProfile(name=f"m{i}", accuracy=acc)
        p.mu, p.var, p.n_obs = mu, sigma ** 2, 100
        profiles.append(p)
    return ProfileStore(profiles, alpha=alpha)


pool_strategy = st.lists(
    st.tuples(st.floats(0.05, 1.0),      # accuracy
              st.floats(1.0, 200.0),     # mu
              st.floats(0.0, 20.0)),     # sigma
    min_size=1, max_size=12)


@given(pool_strategy, st.floats(10.0, 500.0), st.floats(0.0, 50.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=200, deadline=None)
def test_modipick_stage_invariants(pool, t_budget, threshold, seed):
    store = store_from(pool)
    rng = np.random.default_rng(seed)
    policy = ModiPick(t_threshold=threshold)
    trace = policy.select_traced(store, t_budget, rng)
    names = set(store.names())
    assert trace.chosen in names
    t_u, t_l = t_budget, t_budget - threshold
    if trace.fallback:
        # infeasible: fallback must be the fastest model (§3.3.1)
        fastest = min(store.profiles.values(), key=lambda p: p.mu).name
        assert trace.chosen == fastest
    else:
        # stage 1 base satisfies Eq. 2
        bp = store[trace.base]
        assert bp.mu + bp.sigma < t_u and bp.mu - bp.sigma < t_l
        # every eligible model obeys the hard limit (⇒ positive utility)
        for n in trace.eligible:
            p = store[n]
            assert p.mu + p.sigma < t_u
        assert trace.chosen in trace.eligible
        assert trace.base in trace.eligible
        # probabilities normalized
        assert math.isclose(sum(trace.probs), 1.0, rel_tol=1e-9)
        assert all(pr >= 0 for pr in trace.probs)


@given(pool_strategy, st.floats(10.0, 500.0), st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_modipick_zero_threshold_zero_sigma_matches_greedy(pool, t_budget, seed):
    """Paper §3.3.1: with T_threshold=0 and tight σ, stage 1 equals the
    dynamic greedy pick — and with a single-member exploration set the
    final choice matches too when the base is strictly fastest-fitting."""
    pool = [(a, mu, 0.0) for a, mu, _ in pool]
    store = store_from(pool)
    rng = np.random.default_rng(seed)
    trace = ModiPick(t_threshold=0.0).select_traced(store, t_budget, rng)
    greedy = DynamicGreedy().select_traced(store, t_budget, rng)
    if trace.fallback:
        # Eq. 2 is strict (<) while Eq. 1 is ≤: at the exact boundary the
        # greedy pick may still fit.  Otherwise both must fall back.
        if not greedy.fallback:
            assert store[greedy.chosen].mu >= t_budget - 1e-9
        return
    # The stage-1 base model must equal the greedy choice (Eq. 2 → Eq. 1) —
    # up to accuracy ties and the strict-vs-≤ boundary.
    if trace.base != greedy.chosen:
        gp, bp = store[greedy.chosen], store[trace.base]
        assert gp.mu >= t_budget - 1e-9 or gp.accuracy == bp.accuracy


@given(pool_strategy, st.floats(10.0, 500.0), st.floats(0.0, 50.0),
       st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_exploration_set_policies_share_stages(pool, t_budget, threshold, seed):
    store = store_from(pool)
    rng = np.random.default_rng(seed)
    mp = ModiPick(threshold).select_traced(store, t_budget, rng)
    rr = RelatedRandom(threshold).select_traced(store, t_budget, rng)
    ra = RelatedAccurate(threshold).select_traced(store, t_budget, rng)
    assert mp.fallback == rr.fallback == ra.fallback
    if not mp.fallback:
        assert set(mp.eligible) == set(rr.eligible) == set(ra.eligible)
        accs = [store[n].accuracy for n in ra.eligible]
        assert store[ra.chosen].accuracy == max(accs)


@given(pool_strategy, st.floats(10.0, 500.0), st.integers(0, 2**31 - 1))
@settings(max_examples=200, deadline=None)
def test_dynamic_greedy_never_over_budget(pool, t_budget, seed):
    """§3.2.2 invariant: DynamicGreedy only returns a model with
    μ > T_budget via the explicit fastest-model fallback — and falls
    back only when *no* model fits the budget."""
    store = store_from(pool)
    rng = np.random.default_rng(seed)
    trace = DynamicGreedy().select_traced(store, t_budget, rng)
    if trace.fallback:
        assert all(p.mu > t_budget for p in store.profiles.values())
        fastest = min(store.profiles.values(), key=lambda p: p.mu).name
        assert trace.chosen == fastest
    else:
        assert store[trace.chosen].mu <= t_budget
        # greedy: nothing more accurate also fits
        for p in store.profiles.values():
            if p.accuracy > store[trace.chosen].accuracy:
                assert p.mu > t_budget


def test_static_greedy_frozen():
    store = store_from([(0.9, 50, 1), (0.5, 5, 1)])
    pol = StaticGreedy(t_sla=60.0)
    rng = np.random.default_rng(0)
    first = pol.select(store, 10.0, rng)
    # profiles drift, static greedy must not react
    store.profiles["m0"].mu = 500.0
    assert pol.select(store, 10.0, rng) == first == "m0"


def test_budget_eq1():
    assert budget(200.0, 30.0) == 140.0


@given(st.lists(st.floats(1.0, 100.0), min_size=2, max_size=200),
       st.floats(0.01, 0.5))
@settings(max_examples=100, deadline=None)
def test_ewma_profile_tracks_within_range(samples, alpha):
    p = ModelProfile(name="m", accuracy=0.5)
    for s in samples:
        p.update(s, alpha)
    assert min(samples) - 1e-6 <= p.mu <= max(samples) + 1e-6
    assert p.sigma >= 0.0


def test_cold_model_flagging():
    store = store_from([(0.9, 50, 1), (0.5, 5, 1)], alpha=0.2)
    store.cold_age = 10
    for _ in range(20):
        store.mark_selected("m1")
        store.observe("m1", 5.0)
    assert "m0" in store.cold_models()
    assert "m1" not in store.cold_models()


def test_utility_prefers_accuracy_given_equal_profiles():
    # NasNet-Fictional scenario: identical latency profile, lower accuracy
    # ⇒ strictly lower selection probability, but non-zero (explorable).
    store = store_from([(0.826, 112.61, 0.36), (0.50, 112.61, 0.36),
                        (0.779, 31.11, 0.19)])
    rng = np.random.default_rng(0)
    trace = ModiPick(t_threshold=20.0).select_traced(store, 180.0, rng)
    assert not trace.fallback
    probs = dict(zip(trace.eligible, trace.probs))
    if "m0" in probs and "m1" in probs:
        assert probs["m0"] > probs["m1"] > 0.0
