"""Sharded Pallas-kernel wrappers vs unsharded oracles on a fake-device
mesh (subprocess so pytest's jax keeps 1 device)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import shardmap_ops as S
from repro.kernels import ref

mesh = jax.make_mesh((2, 4), ("data", "model"))
ks = jax.random.split(jax.random.PRNGKey(0), 4)

# flash attention: H=KV=4 divides model=4
B, H, KV, Sq, hd = 2, 4, 4, 256, 64
q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32)
k = jax.random.normal(ks[1], (B, KV, Sq, hd), jnp.float32)
v = jax.random.normal(ks[2], (B, KV, Sq, hd), jnp.float32)
out = S.sharded_flash_attention(q, k, v, mesh, causal=True)
exp = ref.flash_attention_ref(q, k, v, causal=True)
np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)
print("flash ok")

# decode attention
G = 2
qd = jax.random.normal(ks[3], (B, KV, G, hd), jnp.float32)
pos = jnp.array([100, 33], jnp.int32)
out = S.sharded_decode_attention(qd, k, v, pos, mesh)
exp = ref.decode_attention_ref(qd, k, v, pos)
np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)
print("decode ok")

# ssd: H=4, G=4 divide model=4
N, P_ = 32, 16
x = jax.random.normal(ks[0], (B, 4, 128, P_), jnp.float32) * 0.5
dt = jax.nn.softplus(jax.random.normal(ks[1], (B, 4, 128), jnp.float32))
A = -jnp.exp(jax.random.normal(ks[2], (4,), jnp.float32) * 0.3)
Bm = jax.random.normal(ks[3], (B, 4, 128, N), jnp.float32) * 0.3
Cm = jax.random.normal(ks[0], (B, 4, 128, N), jnp.float32) * 0.3
out = S.sharded_ssd_scan(x, dt, A, Bm, Cm, mesh, chunk=64)
exp = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4, atol=2e-4)
print("ssd ok")

# rglru: W=128 divides model=4
a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, 128, 128), jnp.float32))
b = jax.random.normal(ks[2], (B, 128, 128), jnp.float32) * 0.1
out = S.sharded_rglru_scan(a, b, mesh, block_s=64)
exp = ref.rglru_scan_ref(a, b)
np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-5)
print("rglru ok")

# fallback: heads don't divide -> replicated heads still correct
q3 = jax.random.normal(ks[0], (B, 3, Sq, hd), jnp.float32)
k3 = jax.random.normal(ks[1], (B, 3, Sq, hd), jnp.float32)
out = S.sharded_flash_attention(q3, k3, k3, mesh, causal=True)
exp = ref.flash_attention_ref(q3, k3, k3, causal=True)
np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5)
print("fallback ok")
"""


def test_sharded_kernels_match_oracles():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=480,
                          cwd=ROOT)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    for tag in ("flash ok", "decode ok", "ssd ok", "rglru ok", "fallback ok"):
        assert tag in proc.stdout
