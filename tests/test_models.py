"""Unit tests for model components (hypothesis where it pays off)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro.configs.base import MoEConfig, SSMConfig
from repro.configs.registry import get_config
from repro.kernels import ref
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import materialize, rmsnorm
from repro.models.rglru import rglru_scan_xla

KEY = jax.random.PRNGKey(0)


def test_windowed_attention_equals_masked_full():
    B, S, H, KV, hd, W = 2, 192, 4, 2, 32, 48
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    banded = attn_mod.attention_windowed(q, k, v, pos, pos, window=W, q_chunk=64)
    naive = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, window=W).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


def test_moe_dispatch_matches_dense_oracle():
    cfg = dataclasses.replace(
        get_config("dbrx-132b").reduced(),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0, group_size=16))
    params = materialize(moe_mod.moe_template(cfg), KEY, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
    out, aux = moe_mod.moe_ffn(params, x, cfg)
    oracle = moe_mod.moe_ffn_dense_eval(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0.0


def test_moe_drops_bounded_by_capacity():
    cfg = dataclasses.replace(
        get_config("dbrx-132b").reduced(),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=0.5, group_size=16))
    params = materialize(moe_mod.moe_template(cfg), KEY, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
    out, _ = moe_mod.moe_ffn(params, x, cfg)
    assert np.isfinite(np.asarray(out)).all()


def test_ssd_chunked_matches_sequential_ref():
    B, H, G, S, hd, N = 2, 4, 1, 96, 16, 24
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, G, N), jnp.float32) * 0.3
    C_ = jax.random.normal(ks[4], (B, S, G, N), jnp.float32) * 0.3
    y = ssm_mod.ssd_chunked(xh, dt, A, B_, C_, chunk=32)
    y_ref = ref.ssd_scan_ref(
        xh.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
        A, B_.transpose(0, 2, 1, 3), C_.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(y_ref.transpose(0, 2, 1, 3)),
                               rtol=1e-4, atol=1e-4)


def test_ssd_final_state_matches_decode_continuation():
    """Prefill final state + one decode step ≡ longer sequential scan."""
    B, H, G, S, hd, N = 1, 2, 1, 64, 8, 16
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (B, S + 1, H, hd), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 1, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S + 1, G, N), jnp.float32) * 0.3
    C_ = jax.random.normal(ks[4], (B, S + 1, G, N), jnp.float32) * 0.3
    _, state = ssm_mod.ssd_chunked(xh[:, :S], dt[:, :S], A, B_[:, :S],
                                   C_[:, :S], chunk=32, return_final_state=True)
    # manual single-step with the recurrence h = exp(dtA) h + dt·B⊗x
    decay = jnp.exp(dt[:, S] * A)  # (B,H)
    Bh = jnp.repeat(B_[:, S], H // G, axis=1)
    upd = dt[:, S][..., None, None] * xh[:, S][..., None] * Bh[:, :, None, :]
    h_next = state * decay[..., None, None] + upd
    y_full = ref.ssd_scan_ref(
        xh.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
        B_.transpose(0, 2, 1, 3), C_.transpose(0, 2, 1, 3))
    Ch = jnp.repeat(C_[:, S], H // G, axis=1)
    y_step = jnp.einsum("bhpn,bhn->bhp", h_next, Ch)
    np.testing.assert_allclose(np.asarray(y_step),
                               np.asarray(y_full[:, :, S]), rtol=1e-4, atol=1e-4)


@given(st.integers(1, 3), st.integers(2, 64), st.integers(1, 32),
       st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_rglru_scan_property(B, S, W, seed):
    """Associative-scan path ≡ sequential recurrence for random shapes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W), jnp.float32))
    b = jax.random.normal(ks[1], (B, S, W), jnp.float32)
    h = rglru_scan_xla(a, b)
    h_ref = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


def test_rmsnorm_scale_identity():
    x = jax.random.normal(KEY, (2, 8, 16), jnp.float32)
    out = rmsnorm(x, jnp.zeros(16), 1e-6)
    norm = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(norm, 1.0, rtol=1e-3)


def test_vocab_padding_masked():
    cfg = get_config("internvl2-2b").reduced()  # vocab 512 (already padded)
    assert cfg.padded_vocab % 256 == 0
    full = get_config("internvl2-2b")
    assert full.padded_vocab >= full.vocab_size
    assert full.padded_vocab % 256 == 0


def test_int8_kv_cache_decode_close_to_bf16():
    """int8 KV (per-slot scales): decode agrees with full forward to
    quantization noise, and cache leaves are actually int8."""
    import jax
    from repro.configs.base import ShapeConfig
    from repro.models import api, model as M

    cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(),
                              kv_cache_dtype="int8")
    params = M.init_params(cfg, KEY, jnp.float32)
    B, S = 2, 37
    full = api.make_train_batch(cfg, ShapeConfig("x", S + 1, B, "prefill"), KEY)
    full.pop("targets", None)
    toks = full["tokens"]
    pre = dict(full)
    pre["tokens"] = toks[:, :S]
    cache, _ = M.prefill(cfg, params, pre, cache_len=64)
    dtypes = {str(l.dtype) for l in jax.tree.leaves(cache)}
    assert "int8" in dtypes
    lg_dec, _ = M.decode_step(cfg, params, cache, toks[:, S],
                              jnp.full((B,), S, jnp.int32))
    _, lg_full = M.prefill(cfg, params, full, cache_len=64)
    a = np.asarray(lg_dec, np.float32)
    b = np.asarray(lg_full, np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)
    assert rel < 5e-2, rel
