"""Premodel subsystem: streaming quantiles, the input classifier,
conditional profiles with shrinkage, the classed fused kernel, and the
engine/scenario wiring — including the RNG-neutrality guarantee that
premodel-off runs are bit-identical with the new columns materialized.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.netmodel import NetworkModel
from repro.core.policy import ModiPick
from repro.core.profiles import ModelProfile
from repro.core.zoo import TABLE2, make_store
from repro.kernels import policy_select
from repro.premodel import (ConditionalProfileStore, NearestCentroidClassifier,
                            OracleClassifier, P2Quantile,
                            QuantileProfileStore, make_classifier)
from repro.premodel.quantile import z_score
from repro.scenario import PolicySpec, Scenario, WorkloadSpec
from repro.scenario.spec import InputClassSpec
from repro.sim import PoissonArrivals, ServingSimulator, per_model_replicas

NET = NetworkModel(50.0, 25.0)


def _profiles():
    return [ModelProfile(name=e.name, accuracy=e.top1 / 100.0)
            for e in TABLE2]


def _warm(store):
    for e in TABLE2:
        p = store[e.name]
        p.mu = e.mu_ms
        p.var = e.sigma_ms ** 2
        p.n_obs = 1000
    store.invalidate()
    return store


# ----------------------------------------------------------------------
# P² streaming quantiles
# ----------------------------------------------------------------------

def test_p2_quantile_tracks_numpy_percentile():
    rng = np.random.default_rng(0)
    for q, data in [(0.95, rng.normal(100.0, 20.0, 4000)),
                    (0.99, rng.normal(100.0, 20.0, 4000)),
                    (0.95, rng.exponential(50.0, 4000))]:
        t = P2Quantile(q)
        for v in data:
            t.observe(float(v))
        ref = float(np.percentile(data, 100.0 * q))
        assert abs(t.value() - ref) / ref < 0.05, (q, t.value(), ref)


def test_p2_quantile_small_n_is_exact_nearest_rank():
    t = P2Quantile(0.5)
    assert t.value() is None
    for v in (5.0, 1.0, 3.0):
        t.observe(v)
    assert t.value() == 3.0


def test_z_score_matches_normal_inverse_cdf():
    assert z_score(0.5) == pytest.approx(0.0)
    assert z_score(0.95) == pytest.approx(1.6448536, abs=1e-5)


# ----------------------------------------------------------------------
# quantile-presenting store
# ----------------------------------------------------------------------

def test_quantile_store_presents_gaussian_fallback_then_tracker():
    store = _warm(QuantileProfileStore(_profiles(), q=0.95, min_obs=8))
    t = store.table()
    i = t.index["InceptionV3"]
    e = next(x for x in TABLE2 if x.name == "InceptionV3")
    # Cold trackers: the seeded Gaussian mu + z_q * sigma, with sigma
    # presented as 0 (the quantile already carries the pessimism).
    assert t.mu[i] == pytest.approx(e.mu_ms + z_score(0.95) * e.sigma_ms)
    assert t.sigma[i] == 0.0
    # 20% spikes at 4x: the streaming p95 lands in the spike region,
    # far above the EWMA mean the raw profile keeps for load charging.
    rng = np.random.default_rng(1)
    for k in range(400):
        lat = e.mu_ms * (4.0 if rng.random() < 0.2 else 1.0)
        store.observe("InceptionV3", lat)
    t = store.table()
    assert t.mu[i] == pytest.approx(4.0 * e.mu_ms, rel=0.1)
    assert store["InceptionV3"].mu < 2.0 * e.mu_ms   # raw EWMA stays mean


# ----------------------------------------------------------------------
# the premodel classifiers
# ----------------------------------------------------------------------

def test_centroid_classifier_recovers_planted_clusters():
    rng = np.random.default_rng(2)
    centers = np.array([[0.0, 0.0], [3.0, 3.0]])
    clf = NearestCentroidClassifier(2, 2)
    for k in range(400):
        true = k % 2
        x = centers[true] + 0.3 * rng.standard_normal(2)
        clf.update(x)
    # Alternating feed seeds centroid k from cluster k, so labels align.
    hits = 0
    for k in range(200):
        true = k % 2
        x = centers[true] + 0.3 * rng.standard_normal(2)
        hits += clf.classify(x) == true
    assert hits >= 190


def test_oracle_classifier_is_frozen_nearest_center():
    clf = OracleClassifier([(0.0,), (1.0,)])
    assert clf.classify((0.1,)) == 0
    assert clf.classify((0.9,)) == 1
    before = clf.classify((0.4,))
    for _ in range(50):
        clf.update((0.9,))
    assert clf.classify((0.4,)) == before


def test_make_classifier_dispatch():
    assert make_classifier("none", 2, 1) is None
    assert isinstance(make_classifier("centroid", 2, 1),
                      NearestCentroidClassifier)
    assert isinstance(make_classifier("oracle", 2, 1,
                                      centers=((0.0,), (1.0,))),
                      OracleClassifier)
    with pytest.raises(ValueError):
        make_classifier("bogus", 2, 1)


# ----------------------------------------------------------------------
# conditional profiles + shrinkage
# ----------------------------------------------------------------------

def test_cold_class_is_exactly_the_pooled_view():
    store = _warm(ConditionalProfileStore(_profiles(), n_classes=3))
    pooled = store.table()
    for cls in range(3):
        ct = store.class_table(cls)
        np.testing.assert_array_equal(ct.mu, pooled.mu)
        np.testing.assert_array_equal(ct.sigma, pooled.sigma)


def test_shrinkage_converges_to_class_truth():
    store = _warm(ConditionalProfileStore(_profiles(), n_classes=2,
                                          tau=16.0))
    e = next(x for x in TABLE2 if x.name == "InceptionV3")
    for _ in range(400):
        store.observe_class(0, "InceptionV3", 3.0 * e.mu_ms)
    mu0, _ = store.shrunk(0, "InceptionV3")
    mu1, _ = store.shrunk(1, "InceptionV3")
    assert mu0 == pytest.approx(3.0 * e.mu_ms, rel=0.05)
    # The untouched class tracks the pooled estimate (which the class-0
    # observations also fed — pooled telemetry never stops).
    assert mu1 == pytest.approx(store["InceptionV3"].mu, rel=1e-9)


def test_set_class_flips_table_and_pooled_table_restores_cursor():
    store = _warm(ConditionalProfileStore(_profiles(), n_classes=2))
    e = next(x for x in TABLE2 if x.name == "InceptionV3")
    for _ in range(200):
        store.observe_class(1, "InceptionV3", 2.0 * e.mu_ms)
    store.set_class(1)
    i = store.table().index["InceptionV3"]
    assert store.table().mu[i] > 1.5 * e.mu_ms
    assert store.pooled_table().mu[i] < store.table().mu[i]
    assert store.active == 1                 # cursor survives the helper
    store.set_class(-1)
    with pytest.raises(ValueError):
        store.set_class(2)
    with pytest.raises(ValueError):
        store.set_class(-2)


def test_stacked_pool_caches_against_version():
    store = _warm(ConditionalProfileStore(_profiles(), n_classes=2))
    s1 = store.stacked_pool()
    assert store.stacked_pool() is s1        # no telemetry -> cached
    store.observe_class(0, "InceptionV3", 40.0)
    s2 = store.stacked_pool()
    assert s2 is not s1
    assert s2.k == 2 and s2.n == len(TABLE2)


# ----------------------------------------------------------------------
# the classed fused kernel
# ----------------------------------------------------------------------

def test_select_classed_matches_select_fused_on_identical_classes():
    """K identical class views + any class ids == the unconditional
    fused kernel (same seed, same draws)."""
    store = _warm(ConditionalProfileStore(_profiles(), n_classes=3))
    table = store.pooled_table()
    stacked = store.stacked_pool()
    rng = np.random.default_rng(3)
    B = 64
    t_u = rng.uniform(60.0, 400.0, B)
    t_l = t_u - 20.0
    cls = rng.integers(0, 3, B).astype(np.int32)
    idx_c, has_c = policy_select.select_classed(stacked, cls, t_u, t_l,
                                                seed=11)
    idx_f, has_f = policy_select.select_fused(table.device_pool(), t_u, t_l,
                                              seed=11)
    np.testing.assert_array_equal(has_c, has_f)
    np.testing.assert_array_equal(idx_c[has_c], idx_f[has_f])


def test_select_classed_routes_each_row_through_its_class_view():
    """Warm both classes with inverted latency truths: for class 0 only
    NasNet-Large is eligible, for class 1 everything but.  Eligibility
    then forces every row's pick through its own class view."""
    store = _warm(ConditionalProfileStore(_profiles(), n_classes=2,
                                          tau=1.0))
    for e in TABLE2:
        fast0 = e.name == "NasNet-Large"
        for _ in range(300):
            store.observe_class(0, e.name, 10.0 if fast0 else 500.0)
            store.observe_class(1, e.name, 500.0 if fast0 else 30.0)
    nl = store.table().index["NasNet-Large"]
    stacked = store.stacked_pool()
    B = 32
    t_u = np.full(B, 100.0)
    cls = (np.arange(B) % 2).astype(np.int32)
    idx, has = policy_select.select_classed(stacked, cls, t_u, t_u - 20.0,
                                            seed=5)
    assert has.all()
    assert (idx[cls == 0] == nl).all()
    assert (idx[cls == 1] != nl).all()


# ----------------------------------------------------------------------
# engine wiring
# ----------------------------------------------------------------------

def _sim(seed=3):
    return ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2),
                            seed=seed, queue_aware=True)


def test_premodel_off_with_features_is_bit_identical():
    """Materializing features and all-ones service scales must not
    perturb a premodel-off run by a single bit."""
    base = _sim().run(ModiPick(t_threshold=20.0), 250.0, 400,
                      arrivals=PoissonArrivals(30.0))
    wired = _sim().run(ModiPick(t_threshold=20.0), 250.0, 400,
                       arrivals=PoissonArrivals(30.0),
                       feature_for=lambda i: (float(i % 2),),
                       service_scale_for=lambda i: 1.0)
    assert base == wired


def test_premodel_run_feeds_class_telemetry_and_orders_percentiles():
    store = _warm(ConditionalProfileStore(_profiles(), n_classes=2))
    centers = [(0.0,), (1.0,)]
    res = _sim().run(ModiPick(t_threshold=20.0), 250.0, 500,
                     arrivals=PoissonArrivals(30.0), store=store,
                     feature_for=lambda i: centers[i % 2],
                     premodel=OracleClassifier(centers),
                     service_scale_for=lambda i: 0.5 if i % 2 == 0 else 1.5)
    assert res.n_completed > 0
    assert store.class_obs(0) > 0 and store.class_obs(1) > 0
    assert store.active == -1          # cursor always restored
    assert res.p50_latency <= res.p95_latency <= res.p99_latency
    assert res.p95_queue_wait <= res.p99_queue_wait


def test_premodel_batched_and_singleton_paths_agree_roughly():
    """Lookahead batching rides route_batch_classed; the headline
    numbers must stay in the same regime as the singleton path."""
    def run(window):
        sim = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2),
                               seed=3, queue_aware=True,
                               batch_window_ms=window)
        store = _warm(ConditionalProfileStore(_profiles(), n_classes=2))
        centers = [(0.0,), (1.0,)]
        return sim.run(ModiPick(t_threshold=20.0), 250.0, 600,
                       arrivals=PoissonArrivals(40.0), store=store,
                       feature_for=lambda i: centers[i % 2],
                       premodel=OracleClassifier(centers),
                       service_scale_for=lambda i:
                           0.5 if i % 2 == 0 else 1.5)
    single, batched = run(0.0), run(5.0)
    assert batched.n_completed > 0
    assert abs(single.sla_attainment - batched.sla_attainment) < 0.1
    assert abs(single.mean_accuracy - batched.mean_accuracy) < 0.05


def test_engine_validates_premodel_prerequisites():
    sim = _sim()
    with pytest.raises(ValueError, match="feature_for"):
        sim.run(ModiPick(t_threshold=20.0), 250.0, 10,
                arrivals=PoissonArrivals(30.0),
                premodel=OracleClassifier([(0.0,), (1.0,)]))
    with pytest.raises(ValueError, match="ConditionalProfileStore"):
        sim.run(ModiPick(t_threshold=20.0), 250.0, 10,
                arrivals=PoissonArrivals(30.0),
                store=make_store(TABLE2),
                feature_for=lambda i: (0.0,),
                premodel=OracleClassifier([(0.0,), (1.0,)]))


# ----------------------------------------------------------------------
# scenario layer
# ----------------------------------------------------------------------

def test_premodel_scenario_end_to_end_smoke():
    from repro.scenario.registry import premodel_scenario
    sc = premodel_scenario(n_requests=300, name="premodel_smoke")
    r = sc.build().run()
    assert r.result.n_completed > 0
    assert r.sla_attainment > 0.8


def test_tail_scenario_end_to_end_smoke():
    from repro.scenario.registry import tail_sla_scenario
    sc = tail_sla_scenario(n_requests=300, name="tail_smoke")
    r = sc.build().run()
    assert r.result.n_completed > 0
    assert r.sla_attainment > 0.8


def test_spec_validates_premodel_fields():
    with pytest.raises(ValueError, match="latency_quantile"):
        PolicySpec(latency_quantile=1.5)
    with pytest.raises(ValueError, match="premodel"):
        PolicySpec(premodel="bogus")
    with pytest.raises(ValueError, match="feature_center"):
        InputClassSpec("easy")
    with pytest.raises(ValueError, match="input_classes"):
        Scenario(name="x", policy=PolicySpec(premodel="centroid"))
    with pytest.raises(ValueError, match="feature dim"):
        WorkloadSpec(input_classes=(
            InputClassSpec("a", feature_center=(0.0,)),
            InputClassSpec("b", feature_center=(1.0, 1.0))))


def test_quantile_scenario_store_is_quantile_presenting():
    from repro.scenario.registry import tail_sla_scenario
    h = tail_sla_scenario(name="tq_store").build()
    store = h.store()
    assert isinstance(store, QuantileProfileStore)
    e = next(x for x in TABLE2 if x.name == "InceptionV3")
    i = store.table().index["InceptionV3"]
    assert store.table().mu[i] == pytest.approx(
        e.mu_ms + z_score(0.95) * e.sigma_ms)
    h_mean = tail_sla_scenario(quantile=None, name="tq_mean").build()
    assert not isinstance(h_mean.store(), QuantileProfileStore)
