"""Elastic replica lifecycle: WARMING cold starts, drain-based
scale-in, mid-run controllers (unit + engine + harness), cost
accounting identities, ``Router.window_stats()`` deltas, the
autoscaler's live-utilization scale-in guard, the fleet+autoscaler
rejection pin, and ``attainment_timeline`` edge cases."""
import numpy as np
import pytest

from repro.core.netmodel import NetworkModel
from repro.core.policy import ModiPick
from repro.core.profiles import ModelProfile, ProfileStore
from repro.core.zoo import TABLE2
from repro.fleet.spec import CellSpec, FleetSpec
from repro.router import InferenceRequest, Router, SlaAwareAdmission
from repro.scenario import (AutoscalerSpec, DeploymentSpec, NetworkSpec,
                            PolicySpec, QueueTargetAutoscaler, Scenario,
                            WorkloadSpec, build)
from repro.scenario.registry import elastic_scenario
from repro.sim import (DOWN, UP, WARMING, ControlReading, ElasticConfig,
                       PoissonArrivals, Replica, ReplicaFault,
                       ServingSimulator, TraceArrivals, make_controller,
                       shared_replicas)
from repro.sim.elastic import (CostWeightedController,
                               ProportionalController, StepController)

NET = NetworkModel(40.0, 10.0)
INF = float("inf")

# Controller-unit knobs: target 50 ms, step 2, pool bounds [1, 8].
CFG = dict(control_interval_ms=100.0, target_queue_ms=50.0,
           max_shed_rate=0.02, max_fallback_rate=0.25,
           min_replicas=1, max_replicas=8, step=2, low_utilization=0.3)


def _cfg(**kw):
    return ElasticConfig(**{**CFG, **kw})


def _r(wait=0.0, shed=0.0, fb=0.0, util=0.5):
    return ControlReading(mean_queue_wait_ms=wait, shed_rate=shed,
                          fallback_rate=fb, utilization=util, n_routed=10)


def _bound(pool, names=("a", "b"), mus=(10.0, 20.0)):
    model_of = np.zeros(64, dtype=np.int64)
    pool.bind(tuple(names), model_of, list(mus))
    return pool


# ----------------------------------------------------------------------
# config validation
# ----------------------------------------------------------------------

def test_elastic_config_validation():
    with pytest.raises(ValueError, match="kind"):
        _cfg(kind="bogus")
    with pytest.raises(ValueError, match="control_interval_ms"):
        _cfg(control_interval_ms=0.0)
    with pytest.raises(ValueError, match="cold_start_ms"):
        _cfg(cold_start_ms=-1.0)
    with pytest.raises(ValueError, match="confirm_windows"):
        _cfg(confirm_windows=0)
    with pytest.raises(ValueError, match="cost_per_replica_s"):
        _cfg(cost_per_replica_s=-0.1)
    with pytest.raises(ValueError, match="step"):
        _cfg(step=0)
    with pytest.raises(ValueError, match="min_replicas"):
        _cfg(min_replicas=9, max_replicas=8)


def test_autoscaler_spec_mid_run_constraints():
    # The epoch-boundary degenerate path IS the step policy; a non-step
    # kind or a cold start without a mid-run tick is a config error.
    with pytest.raises(ValueError, match="mid-run tick"):
        AutoscalerSpec(kind="proportional")
    with pytest.raises(ValueError, match="cold_start_ms"):
        AutoscalerSpec(cold_start_ms=500.0)
    AutoscalerSpec(kind="proportional", control_interval_ms=500.0,
                   cold_start_ms=500.0)        # armed: both are fine


def test_mid_run_controller_requires_shared_topology():
    with pytest.raises(ValueError, match="shared topology"):
        Scenario(
            name="bad",
            workload=WorkloadSpec(arrival="poisson", rate_rps=5.0,
                                  n_requests=100, t_sla_ms=250.0),
            network=NetworkSpec(mean_ms=40.0, std_ms=10.0),
            deployment=DeploymentSpec(
                topology="per_model",
                autoscaler=AutoscalerSpec(control_interval_ms=500.0)),
            policy=PolicySpec(policy="modipick",
                              kwargs={"t_threshold": 20.0}))


def test_fleet_autoscaler_rejection_names_per_cell_workaround():
    """The fleet+autoscaler rejection must point at the supported
    composition: one elastic (mid-run controller) scenario per cell."""
    with pytest.raises(ValueError,
                       match="run one elastic scenario per cell"):
        Scenario(
            name="bad",
            workload=WorkloadSpec(arrival="poisson", rate_rps=5.0,
                                  n_requests=100, t_sla_ms=250.0),
            network=NetworkSpec(mean_ms=40.0, std_ms=10.0),
            deployment=DeploymentSpec(
                topology="shared",
                autoscaler=AutoscalerSpec(control_interval_ms=500.0),
                fleet=FleetSpec(cells=(CellSpec("a"), CellSpec("b")))),
            policy=PolicySpec(policy="modipick",
                              kwargs={"t_threshold": 20.0}))


# ----------------------------------------------------------------------
# controllers (unit)
# ----------------------------------------------------------------------

def test_make_controller_kinds():
    assert isinstance(make_controller(_cfg(kind="step")), StepController)
    assert isinstance(make_controller(_cfg(kind="proportional")),
                      ProportionalController)
    assert isinstance(make_controller(_cfg(kind="cost_weighted")),
                      CostWeightedController)


def test_confirm_windows_gates_scale_up():
    c = make_controller(_cfg(kind="step", confirm_windows=2))
    hot = _r(wait=100.0)
    assert c.target(1, hot) == 1          # first hot window: held
    assert c.target(1, hot) == 3          # confirmed: +step
    assert c.target(3, _r(wait=0.0, util=0.9)) == 3   # cool resets...
    assert c.target(3, hot) == 3          # ...so the streak restarts
    assert c.target(3, hot) == 5


def test_step_controller_idle_hysteresis_and_floor():
    c = make_controller(_cfg(kind="step", confirm_windows=1))
    # comfortable: wait < target/4, no shed, util under the low bar
    assert c.target(5, _r(wait=1.0, util=0.1)) == 3
    assert c.target(1, _r(wait=1.0, util=0.0)) == 1   # min_replicas floor
    # low wait but still busy: hold, don't flap
    assert c.target(5, _r(wait=1.0, util=0.9)) == 5
    # shedding is pressure, not idleness: +step even with wait/util low
    assert c.target(5, _r(wait=1.0, shed=0.5, util=0.1)) == 7


def test_proportional_answers_overshoot_in_one_confirmed_tick():
    p = make_controller(_cfg(kind="proportional", confirm_windows=1))
    assert p.target(2, _r(wait=500.0)) == 8   # ceil(2*10) clamped to max
    assert p.target(2, _r(wait=55.0)) == 3    # ceil(2*1.1)
    # shed pressure with no wait signal still forces one step up (a
    # shed request never queued, so it left no wait behind)
    assert p.target(2, _r(wait=0.0, shed=0.5)) == 3
    assert p.target(4, _r(wait=5.0, util=0.1)) == 3   # -1 per idle tick


def test_cost_weighted_patience_ramp_cap_and_relaxed_idle():
    cw = make_controller(_cfg(kind="cost_weighted", confirm_windows=1,
                              cost_per_replica_s=1.0))
    hot = _r(wait=500.0)
    assert cw.target(2, hot) == 2     # priced capacity: one window is
    assert cw.target(2, hot) == 4     # not enough; ramp capped at +step
    # idle bar relaxed with the price: util 0.5 < 0.3*(1+1)
    assert cw.target(4, _r(wait=20.0, util=0.5)) == 2
    free = make_controller(_cfg(kind="cost_weighted", confirm_windows=1))
    assert free.target(2, hot) == 4   # zero price: acts first window


# ----------------------------------------------------------------------
# WARMING semantics
# ----------------------------------------------------------------------

def test_warming_not_accepting_until_ready():
    pool = _bound(shared_replicas(2))
    r = pool.replicas[1]
    r.start_warming(100.0)
    assert r.health == WARMING and not r.accepting
    assert r.commission_ms == 100.0
    assert pool.wait_columns(now=100.0)[1] == INF
    assert pool.best_for("a", 100.0, None) is pool.replicas[0]
    r.warm_ready()
    assert r.health == UP and r.accepting
    assert pool.wait_columns(now=200.0)[1] == 0.0


def test_cancelled_while_warming_never_flips_up():
    r = Replica(name="e0")
    r.start_warming(0.0)
    r.gen += 1                  # scale-in cancels the cold start
    r.decommission(50.0)
    r.warm_ready()              # the orphaned ready event is a no-op
    assert r.health == DOWN and not r.accepting
    assert r.decommission_ms == 50.0


def test_decommission_asserts_idle():
    r = Replica(name="r0")
    r.current = 7
    with pytest.raises(AssertionError, match="non-idle"):
        r.decommission(10.0)


def test_alive_ms_cost_windows():
    r = Replica(name="r0")                      # static: whole horizon
    assert r.alive_ms(0.0, 1000.0) == 1000.0
    r.start_warming(400.0)                      # commissioned mid-run
    assert r.alive_ms(0.0, 1000.0) == 600.0
    r.warm_ready()
    r.decommission(900.0)                       # ... and decommissioned
    assert r.alive_ms(0.0, 1000.0) == 500.0

    k = Replica(name="r1")                      # mid-run dead time
    k.kill(200.0)
    k.recover(500.0)
    assert k.alive_ms(0.0, 1000.0) == 700.0
    k.kill(800.0)                               # still down at run end
    assert k.alive_ms(0.0, 1000.0) == 500.0


# ----------------------------------------------------------------------
# Router.window_stats(): per-window deltas without zeroing
# ----------------------------------------------------------------------

def _router():
    profiles = [ModelProfile(name="a", accuracy=0.6, mu=10.0, n_obs=100),
                ModelProfile(name="b", accuracy=0.7, mu=20.0, n_obs=100)]
    return Router(ProfileStore(profiles), ModiPick(t_threshold=20.0))


def test_window_stats_deltas_leave_lifetime_counters_alone():
    router = _router()
    rng = np.random.default_rng(0)
    req = InferenceRequest(t_sla_ms=400.0, t_input_ms=40.0)
    for _ in range(2):
        router.route(req, rng)
    w1 = router.window_stats()
    assert w1["n_routed"] == 2
    for _ in range(3):
        router.route(req, rng)
    w2 = router.window_stats()
    assert w2["n_routed"] == 3            # the delta, not the lifetime
    assert w2["mean_batch"] == pytest.approx(1.0)
    assert router.stats()["n_routed"] == 5   # lifetime: untouched
    assert router.window_stats()["n_routed"] == 0
    router.reset()
    router.route(req, rng)
    assert router.window_stats()["n_routed"] == 1   # base cleared too


# ----------------------------------------------------------------------
# autoscaler scale-in guard: dead replicas dilute the raw mean DOWNWARD
# ----------------------------------------------------------------------

class _FakeResult:
    def __init__(self, utils, live=None):
        self.mean_queue_wait = 1.0
        self.replica_utilization = utils
        if live is not None:
            self.mean_live_utilization = live


def test_autoscaler_live_utilization_blocks_spurious_scale_in():
    """Two dead replicas at ~0 busy fraction drag the raw mean under
    ``low_utilization`` while the lone survivor is saturated; the
    alive-window read sees 0.85 and holds.  (The dilution direction is
    DOWNWARD — it *promotes* scale-in, it does not block it.)"""
    asc = QueueTargetAutoscaler(AutoscalerSpec(
        target_queue_ms=50.0, min_replicas=1, max_replicas=8, step=1,
        low_utilization=0.3))
    stats = {"n_routed": 100, "n_shed": 0, "n_fallback": 0}
    utils = {"r0": 0.85, "r1": 0.0, "r2": 0.0}      # raw mean ~0.28
    held = asc.decide(3, stats, _FakeResult(utils, live=0.85))
    assert held == 3
    # Legacy results without the field fall back to the raw mean — and
    # reproduce the pre-fix spurious scale-in this test documents.
    legacy = asc.decide(3, stats, _FakeResult(utils))
    assert legacy == 2
    # A genuinely idle pool scales in under either read.
    idle = {"r0": 0.05, "r1": 0.05, "r2": 0.05}
    assert asc.decide(3, stats, _FakeResult(idle, live=0.05)) == 2


# ----------------------------------------------------------------------
# engine integration
# ----------------------------------------------------------------------

# A flash crowd (150 requests over 2 s) followed by a quiet tail
# (60 requests over 15 s): the controller must ramp up through cold
# starts, then drain-decommission its way back down.
_BURST_TIMES = np.concatenate([np.linspace(0.0, 2_000.0, 150),
                               np.linspace(20_000.0, 35_000.0, 60)])


def _elastic_sim(**kw):
    cfg = _cfg(**{**dict(kind="proportional", control_interval_ms=250.0,
                         cold_start_ms=100.0, target_queue_ms=25.0),
                  **kw})
    return ServingSimulator(TABLE2, NET, shared_replicas(1), seed=3,
                            queue_aware=True, elastic=cfg)


def _elastic_run(sim):
    return sim.run(ModiPick(t_threshold=20.0), 250.0, len(_BURST_TIMES),
                   arrivals=TraceArrivals(_BURST_TIMES))


def test_elastic_run_scales_up_then_drains_down_losing_nothing():
    sim = _elastic_sim()
    res = _elastic_run(sim)
    assert res.n_provisioned > 0 and res.n_decommissioned > 0
    # the zero-loss drain guarantee: every arrival is accounted for
    assert res.n_completed + res.n_rejected == res.n_arrived
    # provisioned capacity actually served the burst
    assert any(r.name.startswith("e") and r.n_served > 0
               for r in sim.pool.replicas)
    # every decommissioned replica left idle — drain finished its queue
    for r in sim.pool.replicas:
        if r.decommission_ms is not None:
            assert r.current is None and not r.queue
    # cost sits strictly between always-1 and always-max
    h = res.horizon_ms / 1000.0
    assert h < res.replica_seconds < 8 * h


def test_warming_replicas_never_serve_before_cold_start_completes():
    """With a cold start longer than the run, provisioned replicas must
    stay WARMING (or be cancelled) and serve exactly nothing."""
    sim = _elastic_sim(cold_start_ms=10_000_000.0)
    res = _elastic_run(sim)
    assert res.n_provisioned > 0
    elastic = [r for r in sim.pool.replicas if r.name.startswith("e")]
    assert elastic
    for r in elastic:
        assert r.n_served == 0 and r.busy_ms == 0.0
        assert r.health in (WARMING, DOWN)
    assert res.n_completed + res.n_rejected == res.n_arrived


def test_elastic_run_is_deterministic_and_pool_does_not_leak():
    sim = _elastic_sim()
    r1 = _elastic_run(sim)
    n_after_first = len(sim.pool.replicas)
    r2 = _elastic_run(sim)          # same sim: truncates, reruns
    assert len(sim.pool.replicas) == n_after_first
    assert r1.mean_latency == r2.mean_latency
    assert r1.sla_attainment == r2.sla_attainment
    assert r1.n_provisioned == r2.n_provisioned
    assert r1.n_decommissioned == r2.n_decommissioned
    assert r1.replica_seconds == r2.replica_seconds


def test_static_pool_cost_identities():
    """Fault-free static pools pin the cost model: replica-seconds is
    exactly n x horizon, and the live-window utilization is the plain
    replica_utilization mean — which is why the autoscaler's preferred
    read preserves every epoch-boundary golden."""
    sim = ServingSimulator(TABLE2, NET, shared_replicas(3), seed=11,
                           queue_aware=True)
    res = sim.run(ModiPick(t_threshold=20.0), 250.0, 200,
                  arrivals=PoissonArrivals(20.0))
    assert res.n_provisioned == 0 and res.n_decommissioned == 0
    assert res.replica_seconds == pytest.approx(
        3 * res.horizon_ms / 1000.0)
    assert res.mean_live_utilization == pytest.approx(
        float(np.mean(list(res.replica_utilization.values()))))


# ----------------------------------------------------------------------
# harness integration: the committed count carries across epochs
# ----------------------------------------------------------------------

def test_elastic_scenario_carries_committed_count_across_epochs():
    sc = elastic_scenario(kind="proportional", control_interval_ms=200.0,
                          cold_start_ms=100.0, n_requests=400,
                          name="elastic_test")
    out = build(sc).run()
    assert out.replica_history[0] == 1
    assert max(out.replica_history) > 1     # mid-run growth carried over
    assert all(1 <= n <= 8 for n in out.replica_history)
    assert sum(e.result.n_provisioned for e in out.epochs) > 0
    lost = sum(e.result.n_arrived - e.result.n_completed
               - e.result.n_rejected for e in out.epochs)
    assert lost == 0


# ----------------------------------------------------------------------
# attainment_timeline edge cases
# ----------------------------------------------------------------------

def test_timeline_skips_empty_mid_run_buckets():
    times = np.concatenate([np.arange(5) * 10.0,
                            25_000.0 + np.arange(5) * 10.0])
    sim = ServingSimulator(TABLE2, NET, shared_replicas(2), seed=1,
                           queue_aware=True)
    sim.run(ModiPick(t_threshold=20.0), 250.0, 10,
            arrivals=TraceArrivals(times))
    rows = sim.attainment_timeline(bucket_ms=1_000.0)
    assert {r["t_ms"] for r in rows} == {0.0, 25_000.0}
    assert all(r["n"] == 5 for r in rows)       # no zero-n filler rows


def test_timeline_shed_only_bucket():
    """A bucket whose every request was shed reports attainment 0,
    shed_rate 1, and accuracy 0.0 (no completions to average)."""
    times = np.concatenate([np.arange(5) * 10.0,
                            25_000.0 + np.arange(5) * 10.0])
    sim = ServingSimulator(TABLE2, NET, shared_replicas(2), seed=1,
                           queue_aware=True, admission=SlaAwareAdmission())
    # late arrivals get a 1 ms SLA the network alone exceeds: all shed
    sim.run(ModiPick(t_threshold=20.0), 250.0, 10,
            arrivals=TraceArrivals(times),
            sla_for=lambda rid: 100_000.0 if rid < 5 else 1.0)
    rows = {r["t_ms"]: r for r in sim.attainment_timeline(1_000.0)}
    shed = rows[25_000.0]
    assert shed["n"] == 5 and shed["shed_rate"] == 1.0
    assert shed["attainment"] == 0.0 and shed["accuracy"] == 0.0
    assert rows[0.0]["shed_rate"] == 0.0


def test_timeline_conserves_counts_with_boundary_aligned_events():
    """FAULT/CONTROL/PROVISION events landing exactly on a bucket
    boundary neither lose nor double-count requests."""
    sim = ServingSimulator(
        TABLE2, NET, shared_replicas(2), seed=7, queue_aware=True,
        faults=[ReplicaFault(at_ms=10_000.0, kind="kill", replica="r0"),
                ReplicaFault(at_ms=20_000.0, kind="recover",
                             replica="r0")])
    res = sim.run(ModiPick(t_threshold=20.0), 250.0, 300,
                  arrivals=PoissonArrivals(15.0))
    rows = sim.attainment_timeline(bucket_ms=10_000.0)
    assert sum(r["n"] for r in rows) == res.n_arrived
    assert all(r["n"] > 0 and 0.0 <= r["attainment"] <= 1.0 for r in rows)

    esim = _elastic_sim(control_interval_ms=1_000.0)   # ticks on 1 s edges
    eres = _elastic_run(esim)
    erows = esim.attainment_timeline(bucket_ms=1_000.0)
    assert sum(r["n"] for r in erows) == eres.n_arrived
