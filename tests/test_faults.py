"""Fault injection & drift resilience: replica health states, inf wait
columns, charged-state churn, profile-store sample hardening, the
self-healing windowed store, retry/hedged-fallback, seeded fault
determinism, and scenario fault/drift round trips (dict, JSON file,
TOML file)."""
import json
import math

import numpy as np
import pytest

from repro.core.netmodel import NetworkModel
from repro.core.policy import ModiPick
from repro.core.profiles import (FrozenProfileStore, ModelProfile,
                                 ProfileStore, WindowedProfileStore)
from repro.core.zoo import TABLE2
from repro.router import (ChargedWaits, InferenceRequest, RetryPolicy,
                          Router, cheapest_viable)
from repro.scenario import Scenario, build_faults, build_retry
from repro.scenario.registry import drift_scenario, faulty_scenario
from repro.sim import (DEGRADED, DOWN, DRAINING, FAULT, UP, EventQueue,
                       LatencyDrift, NetworkDrift, PoissonArrivals, Replica,
                       ReplicaFault, ServingSimulator, per_model_replicas,
                       schedule_faults, shared_replicas)

NET = NetworkModel(40.0, 10.0)
INF = float("inf")


def _store(entries=("a", "b"), mus=(10.0, 20.0), cls=ProfileStore, **kw):
    profiles = [ModelProfile(name=n, accuracy=0.5 + 0.1 * i, mu=m,
                             n_obs=100)
                for i, (n, m) in enumerate(zip(entries, mus))]
    return cls(profiles, **kw)


def _bound(pool, names=("a", "b"), mus=(10.0, 20.0)):
    model_of = np.zeros(64, dtype=np.int64)
    pool.bind(tuple(names), model_of, list(mus))
    return pool


# ----------------------------------------------------------------------
# replica health states
# ----------------------------------------------------------------------

def test_health_transitions():
    r = Replica(name="r0", speed=2.0)
    assert r.health == UP and r.accepting and r.gen == 0

    r.degrade(2.0)
    assert r.health == DEGRADED and r.accepting
    assert r.speed == 1.0
    r.degrade(4.0)          # compounds against base speed, not itself
    assert r.speed == 0.5

    r.drain()
    assert r.health == DRAINING and not r.accepting

    r.recover()
    assert r.health == UP and r.accepting and r.speed == 2.0

    r.current = object()
    r.kill()
    assert r.health == DOWN and not r.accepting
    assert r.gen == 1 and r.current is None

    r.recover()
    assert r.health == UP and r.accepting
    assert r.gen == 1       # incarnation tokens never rewind


def test_reset_restores_health():
    r = Replica(name="r0", speed=3.0)
    r.degrade(3.0)
    r.kill()
    r.reset()
    assert r.health == UP and r.accepting and r.gen == 0
    assert r.speed == 3.0 and r.base_speed is None


def test_wait_columns_inf_for_non_accepting():
    pool = _bound(shared_replicas(3))
    pool.replicas[1].kill()
    pool.replicas[2].drain()
    ws = pool.wait_columns(now=0.0)
    assert ws[0] == 0.0
    assert ws[1] == INF and ws[2] == INF


def test_best_for_skips_down_and_returns_none_when_all_dead():
    pool = _bound(shared_replicas(3))
    pool.replicas[0].kill()
    r = pool.best_for("a", 0.0, None)
    assert r is pool.replicas[1]          # pool-order tie-break survives
    pool.replicas[1].drain()
    pool.replicas[2].kill()
    assert pool.best_for("a", 0.0, None) is None


def test_best_for_single_candidate_down():
    pool = _bound(per_model_replicas(TABLE2[:2], replicas_per_model=1),
                  names=tuple(e.name for e in TABLE2[:2]),
                  mus=[e.mu_ms for e in TABLE2[:2]])
    pool.replicas[0].kill()
    assert pool.best_for(TABLE2[0].name, 0.0, None) is None
    assert pool.best_for(TABLE2[1].name, 0.0, None) is not None


# ----------------------------------------------------------------------
# satellite: charged-state under churn
# ----------------------------------------------------------------------

def test_charged_state_killed_replica_mid_batch():
    """A replica killed between batches surfaces an inf column; every
    charge of the rest of the batch lands on a survivor."""
    pool = _bound(shared_replicas(3))
    pool.replicas[0].kill()
    cs = pool.charged_state(now=0.0)
    assert cs.rep_wait[0] == INF
    picks = {cs.charge(0) for _ in range(6)}
    assert picks <= {1, 2} and 0 not in picks


def test_charged_state_single_survivor():
    pool = _bound(shared_replicas(3))
    pool.replicas[0].kill()
    pool.replicas[2].drain()
    cs = pool.charged_state(now=0.0)
    assert [cs.charge(1) for _ in range(4)] == [1, 1, 1, 1]
    # charges still accrue on the survivor (model b: mu 20)
    assert cs.rep_wait[1] == pytest.approx(80.0)


def test_charged_waits_empty_candidate_set_rejected():
    with pytest.raises(ValueError, match="no replica serves"):
        ChargedWaits([0.0], [[]], [1.0], [10.0], ["a"])


def test_model_waits_inf_propagates_to_router_maps():
    """A model whose only replica is down presents an inf wait — the
    recovery pick can never choose it."""
    pool = _bound(per_model_replicas(TABLE2[:2], replicas_per_model=1),
                  names=tuple(e.name for e in TABLE2[:2]),
                  mus=[e.mu_ms for e in TABLE2[:2]])
    pool.replicas[0].kill()
    cs = pool.charged_state(now=0.0)
    m = cs.as_map()
    assert m[TABLE2[0].name] == INF
    assert math.isfinite(m[TABLE2[1].name])


# ----------------------------------------------------------------------
# satellite: profile-store sample hardening
# ----------------------------------------------------------------------

@pytest.mark.parametrize("bad", [float("nan"), float("inf"),
                                 float("-inf"), -1.0])
def test_observe_rejects_invalid_samples(bad):
    st = _store()
    mu0, n0 = st["a"].mu, st["a"].n_obs
    st.observe("a", bad)
    st.observe_queue("a", bad)
    assert st["a"].mu == mu0 and st["a"].n_obs == n0
    assert st["a"].queue_obs == 0
    assert st.n_rejected_samples == 2


def test_observe_accepts_valid_after_rejects():
    st = _store()
    st.observe("a", float("nan"))
    st.observe("a", 12.0)
    assert st["a"].n_obs == 101
    assert st.n_rejected_samples == 1


def test_frozen_store_drops_everything_but_counts_rejects():
    st = _store(cls=FrozenProfileStore)
    st.observe("a", 999.0)
    st.observe_queue("a", 5.0)
    assert st["a"].mu == 10.0 and st["a"].n_obs == 100
    assert st["a"].queue_obs == 0
    assert st.n_rejected_samples == 0
    st.observe("a", float("inf"))
    assert st.n_rejected_samples == 1
    assert st.cold_models() == []     # no re-probing in the ablation arm


# ----------------------------------------------------------------------
# the self-healing windowed store
# ----------------------------------------------------------------------

def _windowed(**kw):
    kw.setdefault("window", 8)
    kw.setdefault("stale_after", 10)
    kw.setdefault("explore_bonus", 0.9)
    st = _store(cls=WindowedProfileStore, **kw)
    st.warm_seed("a", 100.0, 4.0)
    st.warm_seed("b", 20.0, 1.0)
    return st


def test_windowed_tracks_step_change_within_one_window():
    st = _windowed()
    for _ in range(8):
        st.mark_selected("a")
        st.observe("a", 200.0)
    assert st["a"].mu == pytest.approx(200.0)
    assert st["a"].var == pytest.approx(0.0)


def test_windowed_clears_stale_window_on_return_from_exile():
    st = _windowed()
    st.mark_selected("a")
    st.observe("a", 50.0)
    for _ in range(12):                  # > stale_after selections away
        st.mark_selected("b")
        st.observe("b", 20.0)
    st.observe("a", 300.0)
    # not a mixture of the pre-exile sample and the fresh one
    assert st["a"].mu == pytest.approx(300.0)


def test_windowed_staleness_decay_invites_reprobe():
    st = _windowed()                     # stale_after=10, ramp=10
    for k in range(25):
        st.mark_selected("b")
        st.observe("b", 20.0)
        if k == 14:                      # age 15: half-way down the ramp
            assert st["a"].mu == pytest.approx(
                100.0 * (1.0 - 0.9 * 0.5))
    # age 25 >= stale_after + ramp: the full optimism floor
    assert st["a"].mu == pytest.approx(10.0)
    assert st.staleness("a") == 25
    # one real observation snaps the profile back to measured truth
    st.observe("a", 220.0)
    assert st["a"].mu == pytest.approx(220.0)


def test_warm_seed_installs_belief_without_window_samples():
    st = _windowed()
    assert st["a"].mu == 100.0 and st["a"].n_obs == 1000
    st.mark_selected("a")
    st.observe("a", 7.0)
    # first live sample speaks alone — no synthetic history dilutes it
    assert st["a"].mu == pytest.approx(7.0)


def test_windowed_rejects_invalid_samples_too():
    st = _windowed()
    st.observe("a", float("nan"))
    assert st["a"].mu == 100.0
    assert st.n_rejected_samples == 1


# ----------------------------------------------------------------------
# retry / hedged-fallback
# ----------------------------------------------------------------------

def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(overrun_margin_ms=-1.0)


def test_cheapest_viable_picks_smallest_total_within_budget():
    tab = _store(entries=("a", "b", "c"), mus=(100.0, 20.0, 5.0)).table()
    assert cheapest_viable(tab, None, 30.0) == 2
    assert cheapest_viable(tab, {"c": 40.0}, 30.0) == 1
    assert cheapest_viable(tab, None, 4.0) == -1
    # a dead model's inf wait can never win
    assert cheapest_viable(tab, {"c": INF, "b": INF}, 150.0) == 0


def test_reroute_records_attempts_and_fallback_chain():
    st = _store(entries=("a", "b"), mus=(100.0, 10.0))
    router = Router(st, ModiPick(t_threshold=20.0))
    req = InferenceRequest(t_sla_ms=400.0, t_input_ms=40.0)
    d = router.route(req, np.random.default_rng(0))
    assert d.admitted and d.attempts == 1 and d.fallback_chain == ()

    d2 = router.reroute(d, remaining_budget_ms=15.0)
    assert d2.admitted and d2.variant == "b"
    assert d2.attempts == 2 and d2.fallback_chain == (d.variant,)

    d3 = router.reroute(d2, remaining_budget_ms=1.0)
    assert not d3.admitted and d3.attempts == 3
    assert d3.fallback_chain == (d.variant, "b")
    assert "remaining budget" in d3.reject_reason

    s = router.stats()
    assert s["n_retries"] == 2
    assert s["n_retry_routed"] == 1 and s["n_retry_exhausted"] == 1


# ----------------------------------------------------------------------
# fault records + the engine
# ----------------------------------------------------------------------

def test_schedule_faults_orders_on_the_event_queue():
    evq = EventQueue()
    faults = (ReplicaFault(at_ms=50.0, kind="kill", replica="r0"),
              LatencyDrift(at_ms=10.0, model="a", mu_mult=2.0),
              NetworkDrift(at_ms=30.0, rtt_mult=1.5))
    assert schedule_faults(evq, faults) == 3
    times = []
    while evq:
        ev = evq.pop()
        assert ev.kind == FAULT
        times.append(ev.time)
    assert times == [10.0, 30.0, 50.0]


def test_replica_fault_kind_validated():
    with pytest.raises(ValueError):
        ReplicaFault(at_ms=0.0, kind="explode", replica="r0")


def test_engine_validates_fault_targets():
    sim = ServingSimulator(
        TABLE2, NET, shared_replicas(2), seed=1,
        faults=[ReplicaFault(at_ms=10.0, kind="kill", replica="nope")])
    with pytest.raises(ValueError, match="nope"):
        sim.run(ModiPick(t_threshold=20.0), 250.0, 10,
                arrivals=PoissonArrivals(5.0))
    sim = ServingSimulator(
        TABLE2, NET, shared_replicas(2), seed=1,
        faults=[LatencyDrift(at_ms=10.0, model="nope", mu_mult=2.0)])
    with pytest.raises(ValueError, match="nope"):
        sim.run(ModiPick(t_threshold=20.0), 250.0, 10,
                arrivals=PoissonArrivals(5.0))


def _faulty_run(retry):
    sim = ServingSimulator(
        TABLE2, NET, shared_replicas(2), seed=7, queue_aware=True,
        faults=[ReplicaFault(at_ms=3_000.0, kind="kill", replica="r0"),
                ReplicaFault(at_ms=12_000.0, kind="recover",
                             replica="r0")],
        retry=retry)
    res = sim.run(ModiPick(t_threshold=20.0), 250.0, 300,
                  arrivals=PoissonArrivals(20.0))
    return sim, res


def test_kill_reroutes_victims_and_counts_them():
    sim, res = _faulty_run(RetryPolicy(max_attempts=3))
    s = sim.router.stats()
    assert res.n_retries > 0
    assert s["n_retry_routed"] == res.n_retries
    assert res.n_completed + res.n_rejected == res.n_arrived


def test_fault_run_is_seed_deterministic():
    _, r1 = _faulty_run(RetryPolicy(max_attempts=3))
    _, r2 = _faulty_run(RetryPolicy(max_attempts=3))
    assert r1.mean_latency == r2.mean_latency
    assert r1.sla_attainment == r2.sla_attainment
    assert r1.n_retries == r2.n_retries


def test_drift_changes_the_run():
    def run(faults):
        sim = ServingSimulator(TABLE2, NET,
                               per_model_replicas(TABLE2,
                                                  replicas_per_model=2),
                               seed=5, queue_aware=True, faults=faults)
        return sim.run(ModiPick(t_threshold=20.0), 250.0, 300,
                       arrivals=PoissonArrivals(10.0))
    clean = run(())
    drifted = run([LatencyDrift(at_ms=5_000.0, model="NasNet-Large",
                                mu_mult=3.0)])
    assert drifted.mean_latency != clean.mean_latency
    assert clean.sla_attainment >= drifted.sla_attainment


def test_network_drift_scales_transfers():
    def run(faults):
        sim = ServingSimulator(TABLE2, NET, shared_replicas(2), seed=5,
                               faults=faults)
        return sim.run(ModiPick(t_threshold=20.0), 400.0, 200,
                       arrivals=PoissonArrivals(10.0))
    clean = run(())
    shifted = run([NetworkDrift(at_ms=2_000.0, rtt_mult=3.0)])
    assert shifted.mean_latency > clean.mean_latency


# ----------------------------------------------------------------------
# satellite: scenario fault/drift specs round-trip (dict, JSON, TOML)
# ----------------------------------------------------------------------

def test_fault_scenarios_round_trip_dict():
    for sc in (drift_scenario(), faulty_scenario(),
               faulty_scenario(retry=False)):
        again = Scenario.from_dict(json.loads(json.dumps(sc.to_dict())))
        assert again == sc


def test_scenario_from_json_file(tmp_path):
    sc = faulty_scenario()
    p = tmp_path / "faulty.json"
    p.write_text(json.dumps(sc.to_dict()))
    assert Scenario.from_file(p) == sc


def test_scenario_from_toml_file():
    sc = Scenario.from_file("examples/drift.toml")
    assert sc.name == "drift_demo"
    assert len(sc.deployment.drifts) == 2
    assert sc.deployment.drifts[0].model == "NasNet-Large"
    assert sc.deployment.retry is not None
    assert sc.deployment.retry.max_attempts == 2
    assert sc.policy.profile == "window"
    # the compiled engine inputs match the specs
    faults = build_faults(sc)
    assert [type(f).__name__ for f in faults] == ["LatencyDrift",
                                                  "LatencyDrift"]
    assert build_retry(sc).max_attempts == 2


def test_fault_scenarios_require_single_epoch():
    from repro.scenario import DeploymentSpec, FaultSpec, NetworkSpec, \
        PolicySpec, WorkloadSpec
    with pytest.raises(ValueError, match="epoch"):
        Scenario(
            name="bad",
            workload=WorkloadSpec(arrival="poisson", rate_rps=5.0,
                                  n_requests=100, t_sla_ms=250.0,
                                  epochs=2,
                                  rate_schedule=(5.0, 10.0)),
            network=NetworkSpec(mean_ms=40.0, std_ms=10.0),
            deployment=DeploymentSpec(
                topology="shared",
                faults=(FaultSpec(kind="kill", replica="r0",
                                  at_ms=10.0),)),
            policy=PolicySpec(policy="modipick",
                              kwargs={"t_threshold": 20.0}))
