"""Unified Router API: request/decision schema, SLA-aware admission,
queue-aware equivalence with the shifted-store + scalar path, batched
event-loop selection (counting spy), heterogeneous per-request SLA mixes
through both the simulator and the executor, trace arrival validation,
batched-trace equivalence, and the --smoke benchmark harness."""
import json
import os
import subprocess
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Callable

import numpy as np
import pytest
from conftest import given, settings, st

import repro.sim.engine as engine_mod
from repro.core import policy_vec
from repro.core.netmodel import NetworkModel
from repro.core.policy import (DynamicGreedy, ModiPick, PureRandom,
                               RelatedAccurate, RelatedRandom, StaticGreedy,
                               budget)
from repro.core.profiles import ModelProfile, ProfileStore
from repro.core.simulate import Simulator
from repro.core.zoo import TABLE2, make_store, true_profiles
from repro.router import (AdmitAll, DepthCapAdmission, InferenceRequest,
                          Router, SlaAwareAdmission, make_admission,
                          shifted_store)
from repro.serving.executor import PoolExecutor
from repro.sim import (PoissonArrivals, ServingSimulator, TraceArrivals,
                       per_model_replicas, shared_replicas)

REPO = Path(__file__).resolve().parent.parent
NET = NetworkModel(50.0, 25.0)
TRUTH = true_profiles(TABLE2)


def store_from(specs):
    profiles = []
    for i, (acc, mu, sigma) in enumerate(specs):
        p = ModelProfile(name=f"m{i}", accuracy=acc)
        p.mu, p.var, p.n_obs = mu, sigma ** 2, 100
        profiles.append(p)
    return ProfileStore(profiles)


pool_strategy = st.lists(
    st.tuples(st.floats(0.05, 1.0), st.floats(1.0, 200.0),
              st.floats(0.0, 20.0)),
    min_size=1, max_size=12)

waits_strategy = st.lists(st.floats(0.0, 300.0), min_size=12, max_size=12)


# ----------------------------------------------------------------------
# schema / budget breakdown
# ----------------------------------------------------------------------

def test_decision_budget_breakdown():
    store = store_from([(0.9, 50.0, 1.0), (0.5, 10.0, 1.0)])
    waits = {"m0": 30.0, "m1": 0.0}
    router = Router(store, DynamicGreedy(), queue_aware=True)
    req = InferenceRequest(t_sla_ms=250.0, t_input_ms=40.0, rid=7,
                           sla_class="interactive")
    dec = router.route(req, np.random.default_rng(0),
                       w_queue_fn=waits.__getitem__)
    assert dec.admitted
    assert dec.request is req
    assert dec.budget.t_network_ms == 80.0
    assert dec.budget.t_budget_ms == 250.0 - 80.0           # Eq. 1
    assert dec.budget.w_queue_ms == waits[dec.variant]
    assert dec.budget.t_effective_ms == \
        dec.budget.t_budget_ms - dec.budget.w_queue_ms
    # shifted-store selection: m0 (mu 50 + 30 wait = 80) still fits 170
    assert dec.variant == "m0"
    assert not dec.fallback


def test_router_stats_counters():
    store = store_from([(0.9, 50.0, 1.0), (0.5, 10.0, 1.0)])
    router = Router(store, DynamicGreedy())
    rng = np.random.default_rng(0)
    router.route_batch([InferenceRequest(t_sla_ms=300.0, t_input_ms=10.0,
                                         rid=i) for i in range(5)], rng)
    router.route(InferenceRequest(t_sla_ms=300.0, t_input_ms=10.0), rng)
    s = router.stats()
    assert s["n_routed"] == 6 and s["n_admitted"] == 6
    assert s["n_batches"] == 2 and s["n_shed"] == 0
    assert s["mean_batch"] == 3.0


# ----------------------------------------------------------------------
# satellite: queue-aware Router == shifted_store + scalar select_traced
# ----------------------------------------------------------------------

@given(pool_strategy, st.floats(10.0, 500.0), st.floats(0.0, 50.0),
       waits_strategy, st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_router_matches_shifted_store_scalar_path(pool, t_budget, threshold,
                                                  waits, seed):
    """For every policy, a queue-aware Router decision with injected
    W_queue is the same trace the scalar ``select_traced`` produces on
    the equivalent shifted store view."""
    waits = {f"m{i}": w for i, w in enumerate(waits[:len(pool)])}
    for make_policy in (lambda: ModiPick(t_threshold=threshold),
                        lambda: DynamicGreedy(),
                        lambda: RelatedRandom(threshold),
                        lambda: RelatedAccurate(threshold),
                        lambda: PureRandom(),
                        lambda: StaticGreedy(t_sla=t_budget + threshold)):
        store = store_from(pool)
        router = Router(store, make_policy(), queue_aware=True)
        dec = router.route(
            InferenceRequest(t_sla_ms=t_budget, t_input_ms=0.0),
            np.random.default_rng(seed), w_queue_fn=waits.__getitem__)
        ref_store = store_from(pool)
        expect = make_policy().select_traced(
            shifted_store(ref_store, waits.__getitem__), t_budget,
            np.random.default_rng(seed))
        assert dec.variant == expect.chosen
        assert dec.trace == expect


# ----------------------------------------------------------------------
# admission control
# ----------------------------------------------------------------------

def test_sla_aware_admission_sheds_when_no_model_viable():
    store = store_from([(0.9, 50.0, 1.0), (0.5, 10.0, 1.0)])
    tab = store.table()
    adm = SlaAwareAdmission()
    req = InferenceRequest(t_sla_ms=200.0, t_input_ms=25.0)  # budget 150
    ok, _ = adm.admit(req, 150.0, tab, {"m0": 149.0, "m1": 200.0}.__getitem__)
    assert ok                                   # m0's wait still fits
    ok, reason = adm.admit(req, 150.0, tab,
                           {"m0": 150.0, "m1": 400.0}.__getitem__)
    assert not ok and "budget" in reason
    # a non-positive budget can never be met: always shed
    ok, _ = adm.admit(req, -5.0, tab, {"m0": 0.0, "m1": 0.0}.__getitem__)
    assert not ok
    # no telemetry -> nothing to shed against
    assert adm.admit(req, -5.0, tab, None) == (True, "")


def test_sla_aware_admission_include_service_time():
    store = store_from([(0.9, 120.0, 1.0), (0.5, 10.0, 1.0)])
    tab = store.table()
    waits = {"m0": 0.0, "m1": 50.0}.__getitem__
    assert SlaAwareAdmission().admit(
        InferenceRequest(100.0, 0.0), 100.0, tab, waits)[0]
    # charging mu(m): m0 needs 120, m1 needs 60 -> m1 still viable at 100
    assert SlaAwareAdmission(include_service_time=True).admit(
        InferenceRequest(100.0, 0.0), 100.0, tab, waits)[0]
    ok, _ = SlaAwareAdmission(include_service_time=True).admit(
        InferenceRequest(55.0, 0.0), 55.0, tab, waits)
    assert not ok


def test_depth_cap_admission():
    store = store_from([(0.9, 50.0, 1.0), (0.5, 10.0, 1.0)])
    tab = store.table()
    adm = DepthCapAdmission(max_depth=2)
    req = InferenceRequest(t_sla_ms=200.0, t_input_ms=0.0)
    assert adm.admit(req, 200.0, tab, None, {"m0": 2, "m1": 1}.__getitem__)[0]
    ok, reason = adm.admit(req, 200.0, tab, None,
                           {"m0": 2, "m1": 5}.__getitem__)
    assert not ok and "depth" in reason
    assert adm.admit(req, 200.0, tab, None, None)[0]  # no depth telemetry


def test_admission_only_router_uses_store_telemetry():
    """A Router with SLA-aware admission but queue-blind selection must
    still fall back to the store's own queue telemetry when no estimator
    is injected — the controller cannot silently become a no-op."""
    store = store_from([(0.9, 50.0, 1.0), (0.5, 10.0, 1.0)])
    router = Router(store, DynamicGreedy(), admission=SlaAwareAdmission())
    rng = np.random.default_rng(0)
    req = InferenceRequest(t_sla_ms=100.0, t_input_ms=0.0)
    assert router.route(req, rng).admitted  # no telemetry yet: waits are 0
    for name in ("m0", "m1"):
        for _ in range(50):
            store.observe_queue(name, 500.0)  # both queues deeply backed up
    dec = router.route(req, rng)
    assert not dec.admitted and "budget" in dec.reject_reason
    assert dec.budget.w_queue_ms > 100.0
    assert isinstance(make_admission("none"), AdmitAll)
    assert isinstance(make_admission("sla_aware", slack_ms=5.0),
                      SlaAwareAdmission)
    assert isinstance(make_admission("depth_cap", max_depth=3),
                      DepthCapAdmission)
    with pytest.raises(ValueError):
        make_admission("bogus")


def test_engine_sla_aware_admission_sheds_under_overload():
    """Queue-blind ModiPick over one overloaded shared replica (every
    model behind the same FIFO, so no idle endpoint keeps requests
    viable): without admission every request completes (late); with
    SLA-aware admission the router sheds doomed requests before
    selection and the survivors' queue waits stay bounded."""
    def run(admission):
        sim = ServingSimulator(TABLE2, NET, shared_replicas(1),
                               seed=9, admission=admission)
        return sim, sim.run(ModiPick(t_threshold=20.0), 250.0, 500,
                            arrivals=PoissonArrivals(60.0))

    _, plain = run(None)
    sim, shed = run(SlaAwareAdmission())
    assert plain.n_rejected == 0
    assert shed.n_rejected > 0
    assert shed.n_completed + shed.n_rejected == 500
    assert all("budget" in r.reject_reason for r in sim.rejected_requests)
    assert shed.mean_queue_wait < plain.mean_queue_wait
    # router telemetry agrees with the engine's accounting
    assert sim.router.n_shed == shed.n_rejected
    assert sim.router.n_admitted == shed.n_completed


def test_executor_sla_aware_admission_sheds():
    rng = np.random.default_rng(0)
    pool = [_FakeVariant("small", 0.5, lambda: rng.normal(10, 1)),
            _FakeVariant("large", 0.9, lambda: rng.normal(80, 4))]
    waits = {"small": 1e6, "large": 1e6}
    ex = PoolExecutor(pool, NetworkModel(15.0, 0.0), DynamicGreedy(),
                      seed=1, admission=SlaAwareAdmission(),
                      w_queue_fn=lambda n: waits[n])
    ex.warm_up(np.zeros((1, 4), np.int32))
    res = ex.execute(np.zeros((1, 4), np.int32), t_sla=200.0)
    assert not res.admitted and not res.met_sla and res.variant == ""
    waits["small"] = 0.0
    res2 = ex.execute(np.zeros((1, 4), np.int32), t_sla=200.0)
    assert res2.admitted
    s = ex.summary()
    assert s["shed"] == 1 and s["n"] == 2


# ----------------------------------------------------------------------
# batched event-loop selection: <= one routing call per event-batch
# ----------------------------------------------------------------------

def _spy_route_batch(monkeypatch):
    """Spy both routing entry points: the array-native batch call and
    the scalar ``route_one`` fast path the engine takes for singleton
    event-batches (``route_batch`` delegates to the batch call too, so
    object-path calls are counted as well)."""
    calls = []
    orig_batch = Router.route_batch_arrays
    orig_one = Router.route_one

    def spy_batch(self, t_sla_ms, t_input_ms, rng, **kw):
        calls.append(len(t_sla_ms))
        return orig_batch(self, t_sla_ms, t_input_ms, rng, **kw)

    def spy_one(self, t_sla_ms, t_input_ms, rng, **kw):
        calls.append(1)
        return orig_one(self, t_sla_ms, t_input_ms, rng, **kw)

    monkeypatch.setattr(Router, "route_batch_arrays", spy_batch)
    monkeypatch.setattr(Router, "route_one", spy_one)
    return calls


def test_simultaneous_arrivals_route_in_one_batch(monkeypatch):
    """50 simultaneous arrivals over a zero-jitter network produce 50
    same-timestamp ENQUEUEs — the engine must issue ONE route_batch call
    for the whole event-batch, not 50 scalar selections."""
    calls = _spy_route_batch(monkeypatch)
    n = 50
    sim = ServingSimulator(TABLE2, NetworkModel(50.0, 0.0),
                           per_model_replicas(TABLE2), seed=2)
    r = sim.run(ModiPick(t_threshold=20.0), 250.0, n,
                arrivals=TraceArrivals([0.0] * n))
    assert calls == [n]
    assert r.n_completed == n
    assert sim.router.stats()["mean_batch"] == n
    assert set(r.model_usage) <= {e.name for e in TABLE2}


def test_staggered_arrivals_route_one_call_per_event_batch(monkeypatch):
    """Continuous arrival times never collide: every event-batch is a
    singleton and the engine issues exactly one route_batch per request
    (the scalar, draw-for-draw-identical path)."""
    calls = _spy_route_batch(monkeypatch)
    n = 40
    sim = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2), seed=3)
    r = sim.run(ModiPick(t_threshold=20.0), 250.0, n,
                arrivals=PoissonArrivals(20.0))
    assert calls == [1] * n
    assert r.n_completed == n


def test_lookahead_window_groups_nearby_enqueues(monkeypatch):
    """A non-zero batch window speculatively groups ENQUEUEs that land
    within it, cutting the number of route_batch calls below n."""
    calls = _spy_route_batch(monkeypatch)
    n = 60
    sim = ServingSimulator(TABLE2, NetworkModel(50.0, 0.0),
                           per_model_replicas(TABLE2), seed=4,
                           batch_window_ms=5.0)
    r = sim.run(DynamicGreedy(), 400.0, n,
                arrivals=TraceArrivals([0.5 * i for i in range(n)]))
    assert sum(calls) == n
    assert len(calls) < n          # some grouping happened
    assert max(calls) > 1
    assert r.n_completed == n
    # speculative routing must not start service before the uplink lands
    assert all(q.service_start_ms >= q.enqueue_ms - 1e-9
               for q in sim.completed_requests)
    assert all(q.queue_wait_ms >= 0.0 for q in sim.completed_requests)


# ----------------------------------------------------------------------
# heterogeneous per-request SLAs, end to end
# ----------------------------------------------------------------------

def test_heterogeneous_sla_mix_through_simulator():
    """Interactive (120ms) and batch (400ms) requests interleave through
    one engine run: the tight class rides fast models, the loose class
    reaches the accurate heavyweights, and attainment is scored against
    each request's own SLA."""
    sim = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2), seed=11)
    sla_of = lambda rid: 120.0 if rid % 2 == 0 else 400.0
    r = sim.run(ModiPick(t_threshold=20.0), 250.0, 600,
                arrivals=PoissonArrivals(10.0), sla_for=sla_of)
    assert r.n_completed == 600
    reqs = sim.completed_requests
    assert {q.t_sla_ms for q in reqs} == {120.0, 400.0}
    mu = lambda qs: np.mean([TRUTH[q.model].mu_ms for q in qs])
    acc = lambda qs: np.mean([TRUTH[q.model].top1 for q in qs])
    tight = [q for q in reqs if q.t_sla_ms == 120.0]
    loose = [q for q in reqs if q.t_sla_ms == 400.0]
    assert mu(tight) < mu(loose)
    assert acc(tight) < acc(loose)
    # attainment was scored per-request, not against the run-level label
    met = sum(q.e2e_ms <= q.t_sla_ms for q in reqs)
    assert r.sla_attainment == met / r.n_arrived


def test_heterogeneous_sla_mix_through_simultaneous_batch():
    """The same mix arriving simultaneously: heterogeneous budgets form
    one batched route_batch call and still split by class."""
    n = 200
    sim = ServingSimulator(TABLE2, NetworkModel(50.0, 0.0),
                           per_model_replicas(TABLE2), seed=12)
    r = sim.run(ModiPick(t_threshold=20.0), 250.0, n,
                arrivals=TraceArrivals([0.0] * n),
                sla_for=lambda rid: 120.0 if rid % 2 == 0 else 400.0)
    assert sim.router.stats()["n_batches"] == 1
    assert r.n_completed == n
    reqs = sim.completed_requests
    tight = [q for q in reqs if q.t_sla_ms == 120.0]
    loose = [q for q in reqs if q.t_sla_ms == 400.0]
    mu = lambda qs: np.mean([TRUTH[q.model].mu_ms for q in qs])
    assert mu(tight) < mu(loose)


@dataclass
class _FakeVariant:
    name: str
    quality: float
    latency_fn: Callable[[], float]

    def run(self, tokens, n_decode=2) -> float:
        return float(self.latency_fn())


def test_heterogeneous_sla_mix_through_executor():
    """The live executor serves an alternating 45ms/300ms SLA stream:
    per-request budgets steer tight requests to the small variant and
    loose ones to the large, and met_sla is scored per request."""
    rng = np.random.default_rng(1)
    pool = [_FakeVariant("small", 0.5, lambda: rng.normal(10, 1)),
            _FakeVariant("medium", 0.7, lambda: rng.normal(30, 2)),
            _FakeVariant("large", 0.9, lambda: rng.normal(80, 4))]
    ex = PoolExecutor(pool, NetworkModel(15.0, 7.0),
                      ModiPick(t_threshold=10.0), seed=1)
    ex.warm_up(np.zeros((1, 4), np.int32))
    toks = np.zeros((1, 4), np.int32)
    for i in range(300):
        ex.execute(toks, t_sla=45.0 if i % 2 == 0 else 300.0)
    rs = ex.results
    tight = [r for r in rs if r.t_sla_ms == 45.0]
    loose = [r for r in rs if r.t_sla_ms == 300.0]
    small_share = sum(r.variant == "small" for r in tight) / len(tight)
    large_share = sum(r.variant == "large" for r in loose) / len(loose)
    assert small_share > 0.5
    assert large_share > 0.3
    # per-request scoring: loose requests overwhelmingly meet their SLA
    assert np.mean([r.met_sla for r in loose]) > 0.9


# ----------------------------------------------------------------------
# route_batch standalone (no engine): vectorized heterogeneous budgets
# ----------------------------------------------------------------------

def test_route_batch_vectorized_heterogeneous_budgets():
    store = make_store(TABLE2)
    router = Router(store, ModiPick(t_threshold=20.0))
    rng = np.random.default_rng(5)
    slas = np.where(np.arange(400) % 2 == 0, 120.0, 400.0)
    reqs = [InferenceRequest(t_sla_ms=float(s), t_input_ms=50.0, rid=i)
            for i, s in enumerate(slas)]
    decs = router.route_batch(reqs, rng)
    assert len(decs) == 400 and all(d.admitted for d in decs)
    tab = store.table()
    mu_of = lambda ds: np.mean([tab.mu[tab.index[d.variant]] for d in ds])
    assert mu_of(decs[0::2]) < mu_of(decs[1::2])
    # batched traces carry the stage decomposition
    assert all(d.trace is not None for d in decs)
    assert any(d.base is not None and len(d.probs) >= 1 for d in decs)


# ----------------------------------------------------------------------
# select_batch_traced: batched traces match the scalar stages
# ----------------------------------------------------------------------

@given(pool_strategy, st.lists(st.floats(-20.0, 500.0), min_size=1,
                               max_size=24),
       st.floats(0.0, 50.0), st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_select_batch_traced_matches_scalar_stages(pool, budgets, threshold,
                                                   seed):
    store = store_from(pool)
    budgets = np.asarray(budgets)
    mp = ModiPick(t_threshold=threshold)
    traces = policy_vec.select_batch_traced(
        mp, store, budgets, np.random.default_rng(seed), backend="numpy")
    picks = mp.select_batch(store, budgets, np.random.default_rng(seed),
                            backend="numpy")
    assert [t.chosen for t in traces] == picks
    for b, tb in enumerate(budgets):
        scalar = mp.select_traced(store, float(tb),
                                  np.random.default_rng(0))
        assert traces[b].fallback == scalar.fallback
        if scalar.fallback:
            continue
        assert traces[b].base == scalar.base
        assert set(traces[b].eligible) == set(scalar.eligible)
        batched = dict(zip(traces[b].eligible, traces[b].probs))
        for name, p in zip(scalar.eligible, scalar.probs):
            assert abs(batched[name] - p) < 1e-9
    # deterministic policies: fallback flag matches the scalar trace
    dg_traces = policy_vec.select_batch_traced(
        DynamicGreedy(), store, budgets, np.random.default_rng(seed))
    for b, tb in enumerate(budgets):
        scalar = DynamicGreedy().select_traced(store, float(tb),
                                               np.random.default_rng(0))
        assert dg_traces[b].chosen == scalar.chosen
        assert dg_traces[b].fallback == scalar.fallback


# ----------------------------------------------------------------------
# satellite: TraceArrivals validation
# ----------------------------------------------------------------------

def test_trace_arrivals_validation():
    with pytest.raises(ValueError, match="non-negative"):
        TraceArrivals([-1.0, 2.0])
    with pytest.raises(ValueError, match="sorted"):
        TraceArrivals([3.0, 2.0])
    with pytest.raises(ValueError, match="finite"):
        TraceArrivals([0.0, float("nan")])
    with pytest.raises(ValueError, match="finite"):
        TraceArrivals([0.0, float("inf")])
    with pytest.raises(ValueError, match="at least one"):
        TraceArrivals([])
    # duplicates are legal: simultaneous arrivals
    assert len(TraceArrivals([0.0, 0.0, 5.0])) == 3


# ----------------------------------------------------------------------
# satellite: backend env validation lists the valid values
# ----------------------------------------------------------------------

def test_unknown_env_backend_message_lists_valid_values(monkeypatch):
    monkeypatch.setenv("REPRO_POLICY_BACKEND", "bogus")
    store = make_store(TABLE2)
    with pytest.raises(ValueError) as e:
        ModiPick(20.0).select_batch(store, np.full(4, 200.0),
                                    np.random.default_rng(0))
    assert "REPRO_POLICY_BACKEND" in str(e.value)
    assert "auto, numpy, jax" in str(e.value)
    with pytest.raises(ValueError, match="auto, numpy, jax"):
        ModiPick(20.0).select_batch(store, np.full(4, 200.0),
                                    np.random.default_rng(0),
                                    backend="tpu")


# ----------------------------------------------------------------------
# closed-loop driver rides the same Router
# ----------------------------------------------------------------------

def test_closed_loop_simulator_exposes_router():
    sim = Simulator(entries=TABLE2, network=NET, seed=1)
    r = sim.run(ModiPick(t_threshold=20.0), 200.0, 50)
    assert isinstance(sim.router, Router)
    assert sim.router.stats()["n_routed"] == 50
    assert r.n == 50


# ----------------------------------------------------------------------
# satellite: benchmark --smoke harness (CI bit-rot guard)
# ----------------------------------------------------------------------

def test_benchmarks_smoke_mode(tmp_path):
    """`benchmarks/run.py --smoke` runs every registered benchmark at
    toy scale — including the admission-policy axis — so a benchmark
    that stopped importing or running fails tier-1, not sweep time."""
    env = dict(os.environ,
               PYTHONPATH=f"{REPO / 'src'}{os.pathsep}{REPO}")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke", "--json",
         "--fail-fast"],
        cwd=tmp_path, env=env, capture_output=True, text=True, timeout=570)
    assert out.returncode == 0, out.stderr
    for marker in ("table2/", "fig6/sla_100,", "threshold/thr_0,",
                   "load_sweep/modipick/rate_5,",
                   "load_sweep/admission_sla_aware/rate_40,",
                   "sla_frontier/modipick/sla_250,",
                   "policy_throughput/numpy/batch_1000,",
                   "scenario_suite/steady,",
                   "scenario_suite/class_mix/class_interactive,",
                   "scenario_suite/scale_up/epoch_4,",
                   "drift_resilience/drift_mu2_window,",
                   "drift_resilience/faulty_retry,",
                   "fleet_throughput/scale_4cell,",
                   "fleet_throughput/frontier_rate_540,",
                   "fleet_throughput/window_5ms,",
                   "live_pool/modipick,"):
        assert marker in out.stdout, marker
    # smoke writes suffixed records so toy-scale rows can never clobber
    # the tracked full-scale BENCH_<name>.json artifacts
    assert not (tmp_path / "BENCH_load_sweep.json").exists()
    data = json.loads((tmp_path / "BENCH_load_sweep_smoke.json").read_text())
    names = [r["name"] for r in data["rows"]]
    assert any(n.startswith("load_sweep/admission_") for n in names)
