"""Training substrate: optimizer, checkpoint/restart, data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import TokenStream
from repro.training import checkpoint as ckpt
from repro.training.loop import TrainLoop
from repro.training.optimizer import adamw_update, init_opt_state, lr_schedule


def test_adamw_decreases_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                       weight_decay=0.0, schedule="constant", grad_clip=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(tcfg, params, g, opt)
    assert float(loss(params)) < 1e-3


def test_lr_schedule_shapes():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                       schedule="cosine")
    lrs = [float(lr_schedule(tcfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[1] == pytest.approx(1.0)          # end of warmup
    assert lrs[-1] == pytest.approx(0.0, abs=1e-6)  # decayed out
    assert all(l >= 0 for l in lrs)


def test_grad_accumulation_matches_full_batch():
    cfg = get_config("qwen2-1.5b").reduced()
    from repro.training.train_step import init_train_state, make_train_step
    tcfg1 = TrainConfig(grad_accum=1, learning_rate=1e-3, warmup_steps=0,
                        schedule="constant")
    tcfg4 = TrainConfig(grad_accum=4, learning_rate=1e-3, warmup_steps=0,
                        schedule="constant")
    key = jax.random.PRNGKey(0)
    stream = TokenStream(cfg.vocab_size, 8, 32, seed=1)
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    p1, o1 = init_train_state(cfg, key, jnp.float32)
    p4, o4 = init_train_state(cfg, key, jnp.float32)
    p1, _, m1 = make_train_step(cfg, tcfg1)(p1, o1, batch)
    p4, _, m4 = make_train_step(cfg, tcfg4)(p4, o4, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        # summation-order noise between the fused and microbatched paths;
        # near-zero-grad elements see eps-scaled Adam noise — a broken
        # accumulation would diverge on most elements, not O(1) of them
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-4)


def test_checkpoint_roundtrip_and_prune():
    cfg = get_config("whisper-tiny").reduced()
    from repro.models import model as M
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40):
            ckpt.save(d, s, params, opt, extra={"data": {"step": s, "seed": 0}},
                      keep_last=2)
        assert ckpt.latest_step(d) == 40
        dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(dirs) == 2  # pruned to keep_last
        p2, o2, extra = ckpt.restore(d, 40, params, opt)
        assert extra["data"]["step"] == 40
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_resume_bitwise_identical():
    cfg = get_config("qwen2-1.5b").reduced()
    tcfg = TrainConfig(total_steps=10, warmup_steps=2, learning_rate=1e-3)
    mk = lambda: TokenStream(cfg.vocab_size, 4, 32, seed=7)

    ref_loop = TrainLoop(cfg, tcfg)
    ref_loop.run(mk(), 10)
    with tempfile.TemporaryDirectory() as d:
        crash = TrainLoop(cfg, tcfg, ckpt_dir=d, ckpt_every=4, fail_at_step=7)
        with pytest.raises(RuntimeError):
            crash.run(mk(), 10)
        resume = TrainLoop(cfg, tcfg, ckpt_dir=d)
        resume.run(mk(), 10)
    for a, b in zip(jax.tree.leaves(ref_loop._final_params),
                    jax.tree.leaves(resume._final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 1000), st.sampled_from([1, 2, 4, 8]),
       st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_data_pipeline_deterministic_and_elastic(step, n_hosts, seed):
    """The GLOBAL batch at a step is invariant to the host topology —
    concatenating host shards from any topology reproduces the 1-host
    stream exactly (elastic restart guarantee)."""
    gb, seq, vocab = 8, 16, 1000
    full = TokenStream(vocab, gb, seq, seed=seed, host_id=0, n_hosts=1)
    ref_batch = full.batch_at(step)
    got = np.concatenate(
        [TokenStream(vocab, gb, seq, seed=seed, host_id=h,
                     n_hosts=n_hosts).batch_at(step)["tokens"]
         for h in range(n_hosts)], axis=0)
    np.testing.assert_array_equal(got, ref_batch["tokens"])
    assert ref_batch["tokens"].shape == (gb, seq)
    np.testing.assert_array_equal(ref_batch["targets"][:, :-1],
                                  ref_batch["tokens"][:, 1:])


def test_stream_state_restore():
    s = TokenStream(100, 4, 8, seed=3)
    b0, b1 = next(s), next(s)
    s2 = TokenStream(100, 4, 8, seed=3)
    s2.restore({"step": 1, "seed": 3})
    np.testing.assert_array_equal(next(s2)["tokens"], b1["tokens"])


def test_int8_adam_moments_match_fp32():
    """8-bit Adam (linear m, log-space v): loss trajectory matches fp32 to
    high precision on a small model; state leaves actually int8."""
    from repro.configs.base import TrainConfig
    from repro.training.train_step import init_train_state, make_train_step

    cfg = get_config("qwen2-1.5b").reduced()
    losses = {}
    for moments in ("fp32", "int8"):
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2,
                           total_steps=30, opt_moments=moments)
        params, opt = init_train_state(cfg, jax.random.PRNGKey(0),
                                       jnp.float32, tcfg)
        if moments == "int8":
            dtypes = {str(l.dtype) for l in jax.tree.leaves(opt.mu)}
            assert "int8" in dtypes
        step = jax.jit(make_train_step(cfg, tcfg))
        stream = TokenStream(cfg.vocab_size, 4, 32, seed=7)
        for _ in range(15):
            batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
            params, opt, m = step(params, opt, batch)
        losses[moments] = float(m["loss"])
    assert abs(losses["int8"] - losses["fp32"]) < 0.05, losses
