"""SoA engine hot path: bit-identity against pre-refactor seeded runs,
replica fast-path semantics (int queues, wait estimates, per-batch
snapshots), the lazy SimRequest materialization, and the slow-marked
performance acceptance gates.

The two goldens below were captured by running the PR-4 (pre-SoA)
engine verbatim; every float is pinned exactly — the refactor swapped
the data representation, not the simulation."""
import time

import numpy as np
import pytest

from repro.core.netmodel import NetworkModel
from repro.core.policy import DynamicGreedy, ModiPick
from repro.core.profiles import ModelProfile, ProfileStore
from repro.core.zoo import TABLE2
from repro.router.queueaware import shifted_store
from repro.sim import (PoissonArrivals, ServingSimulator,
                       per_model_replicas, shared_replicas)
from repro.sim.replica import EXACT_WALK_MAX

NET = NetworkModel(50.0, 25.0)

# Best-of-3 requests/sec of the PR-4 event loop on this host, measured
# from a pristine PR-4 worktree immediately before the SoA refactor:
# ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2), seed=3),
# ModiPick(t_threshold=20), 2000 requests, PoissonArrivals(40).
PR4_RATE40_QA_RPS = 3427.0       # queue_aware=True
PR4_RATE40_PLAIN_RPS = 4013.0    # queue_aware=False


# ----------------------------------------------------------------------
# bit-identical goldens through the SoA refactor
# ----------------------------------------------------------------------

def test_golden_soa_classes_window_sla_mix_unchanged():
    """Queue-aware run exercising every new column at once — lookahead
    batching, per-request SLA mix, class labels — pinned bit-for-bit to
    the pre-refactor engine.  ``charge_batches=False``: the golden was
    captured under the historical one-snapshot batch semantics, which
    is exactly what the knob preserves (intra-batch charging routes
    lookahead batches sequentially, a deliberate behaviour change)."""
    eng = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2), seed=7,
                           queue_aware=True, batch_window_ms=5.0,
                           charge_batches=False)
    r = eng.run(ModiPick(t_threshold=20.0), 250.0, 500,
                arrivals=PoissonArrivals(40.0),
                sla_for=lambda i: 150.0 if i % 3 == 0 else 300.0,
                class_for=lambda i: "interactive" if i % 3 == 0 else "batch")
    assert (r.n_arrived, r.n_completed, r.n_rejected) == (500, 500, 0)
    assert r.sla_attainment == 0.918
    assert r.mean_accuracy == 0.7644200000000001
    assert r.mean_latency == 195.7473904291624
    assert r.p99_latency == 315.2542742867032
    assert r.mean_queue_wait == 36.03014440619576
    assert r.horizon_ms == 12595.728078284552
    assert r.per_class["batch"]["n_arrived"] == 333
    assert r.per_class["batch"]["attainment"] == 0.972972972972973
    assert r.per_class["batch"]["accuracy"] == 0.8045255255255257
    assert r.per_class["batch"]["mean_latency"] == 228.88442811973565
    assert r.per_class["interactive"]["attainment"] == 0.8083832335329342
    assert r.per_class["interactive"]["accuracy"] == 0.6844491017964072
    assert r.per_class["interactive"]["mean_latency"] == 129.67174042340864


def test_golden_soa_shedding_shared_pool_unchanged():
    """Hard-capped shared pool under overload (deep shedding exercises
    the reject/depart columns and the rejected-inclusive horizon)."""
    eng = ServingSimulator(TABLE2, NET, shared_replicas(3, max_queue_depth=4),
                           seed=13)
    r = eng.run(DynamicGreedy(), 250.0, 400, arrivals=PoissonArrivals(50.0))
    assert (r.n_arrived, r.n_completed, r.n_rejected) == (400, 257, 143)
    assert r.sla_attainment == 0.0175
    assert r.mean_accuracy == 0.818591439688716
    assert r.mean_latency == 433.22467826000116
    assert r.p99_queue_wait == 336.09236612235816
    assert r.replica_utilization == {'r0': 0.9925038681183374,
                                     'r1': 0.9743912120965644,
                                     'r2': 0.9725285189388206}
    assert r.model_usage == {
        'InceptionV3': 0.023346303501945526,
        'InceptionV4': 0.17120622568093385,
        'MobileNetV1-1.0': 0.011673151750972763,
        'NasNet-Large': 0.7859922178988327,
        'NasNet-Mobile': 0.007782101167315175}


# ----------------------------------------------------------------------
# lazy SimRequest materialization from the record columns
# ----------------------------------------------------------------------

def test_request_views_materialize_from_columns():
    eng = ServingSimulator(TABLE2, NET, shared_replicas(1, max_queue_depth=2),
                           seed=5)
    r = eng.run(ModiPick(t_threshold=20.0), 250.0, 300,
                arrivals=PoissonArrivals(60.0),
                class_for=lambda i: "gold" if i % 2 else "bronze")
    done = eng.completed_requests
    shed = eng.rejected_requests
    assert eng.completed_requests is done      # cached, built once
    assert len(done) == r.n_completed and len(shed) == r.n_rejected
    assert all(q.model and q.replica == "r0" and not q.rejected
               for q in done)
    assert all(q.rejected and q.reject_reason == "replica queue full"
               and q.model for q in shed)
    assert {q.sla_class for q in done} <= {"gold", "bronze"}
    # e2e/queue-wait derived fields reproduce the summary statistics
    met = sum(q.e2e_ms <= q.t_sla_ms for q in done)
    assert r.sla_attainment == met / r.n_arrived
    assert r.mean_latency == float(np.mean([q.e2e_ms for q in done]))
    assert all(q.queue_wait_ms >= 0.0 for q in done)


# ----------------------------------------------------------------------
# replica fast path: int queues, wait estimates, per-batch snapshot
# ----------------------------------------------------------------------

def _bound_pool(n_replicas, queue_depths, mu_now):
    """Pool bound to synthetic SoA state: request i has model id
    ``i % len(mu_now)``."""
    pool = shared_replicas(n_replicas)
    total = sum(queue_depths)
    model_of = [i % len(mu_now) for i in range(total)]
    pool.bind([f"m{j}" for j in range(len(mu_now))], model_of, list(mu_now))
    rid = 0
    for r, depth in zip(pool.replicas, queue_depths):
        for _ in range(depth):
            r.enqueue(rid, model_of[rid])
            rid += 1
    return pool


def test_waits_by_name_matches_per_model_queue_wait():
    store = None  # bound fast path never touches the store
    mu_now = [10.0, 35.0, 3.5]
    pool = _bound_pool(4, [3, 0, 7, 1], mu_now)
    pool.replicas[2].current = 99
    pool.replicas[2].busy_until = 12.5
    snap = pool.waits_by_name(now=2.0, store=store)
    for name in ("m0", "m1", "m2"):
        assert snap[name] == pool.queue_wait(name, 2.0, store)
    assert set(snap) == {"m0", "m1", "m2"}


def test_deep_queue_closed_form_matches_walk():
    """Beyond EXACT_WALK_MAX the wait estimate switches to the
    per-model-count closed form: same sum up to float associativity,
    O(n_models) instead of O(depth)."""
    mu_now = [12.0, 48.0]
    deep = EXACT_WALK_MAX * 3
    pool = _bound_pool(1, [deep], mu_now)
    r = pool.replicas[0]
    est = r.estimated_wait(0.0, None)
    exact = sum(mu_now[i % 2] for i in range(deep))
    assert est == pytest.approx(exact, rel=1e-12)
    # and the exact element walk is still used at the threshold
    while len(r.queue) > EXACT_WALK_MAX:
        r.pop_request()
    est_small = r.estimated_wait(0.0, None)
    assert est_small == pytest.approx(
        sum(mu_now[r._model_of[rid] % 2] for rid in r.queue), rel=1e-12)


def test_unbound_replica_keeps_legacy_object_walk():
    """Pools built outside the engine (no bind()) still estimate waits
    by walking request objects against the live store."""
    from repro.sim.engine import SimRequest
    pool = shared_replicas(1)
    store = ProfileStore([ModelProfile(name="m0", accuracy=0.9)])
    store.profiles["m0"].mu = 25.0
    req = SimRequest(rid=0, arrival_ms=0.0, model="m0")
    pool.replicas[0].queue.append(req)
    assert pool.replicas[0].estimated_wait(0.0, store) == 25.0
    assert pool.queue_wait("m0", 0.0, store) == 25.0


def test_shifted_view_matches_eager_shifted_table():
    """The lazy shifted view assembles the same snapshot
    ``ProfileTable.shifted`` would build, field for field, and only
    materializes per-profile objects on demand."""
    ps = []
    rng = np.random.default_rng(3)
    for i in range(6):
        p = ModelProfile(name=f"m{i}", accuracy=float(rng.uniform(0.1, 1)))
        p.mu, p.var, p.n_obs = float(rng.uniform(5, 80)), 4.0, 10
        ps.append(p)
    store = ProfileStore(ps)
    waits = {f"m{i}": float(rng.uniform(0, 30)) for i in range(6)}
    view = store.table() and shifted_store(store, waits.__getitem__)
    eager = store.table().shifted(
        np.array([waits[n] for n in store.table().names]))
    tab = view.table()
    np.testing.assert_array_equal(tab.mu, eager.mu)
    np.testing.assert_array_equal(tab.sigma, eager.sigma)
    np.testing.assert_array_equal(tab.queue_mu, eager.queue_mu)
    np.testing.assert_array_equal(tab.acc_order, eager.acc_order)
    assert tab.fastest == eager.fastest
    assert tab.names == eager.names
    # scalar-path cache mirrors the arrays exactly
    mu_l, sig_l, musig_l, *_ = tab.scalar_cache()
    np.testing.assert_array_equal(mu_l, tab.mu)
    np.testing.assert_array_equal(musig_l, tab.mu + tab.sigma)
    # per-profile objects only on demand, shifted like the eager view
    assert view["m2"].mu == store["m2"].mu + waits["m2"]
    assert view["m2"].accuracy == store["m2"].accuracy
    # identity root survives wrapping (StaticGreedy's freeze contract)
    assert view.base is store


def test_observe_on_shifted_view_stays_view_local():
    """Regression: feeding telemetry into a shifted view must neither
    corrupt the base store's cached snapshot (the view shares the base
    sigma array) nor crash on the view's read-only zeros queue_mu — it
    updates the view's own lazy profile copies, like the historical
    eager-copy view did."""
    ps = []
    for i, mu in enumerate((40.0, 9.0)):
        p = ModelProfile(name=f"m{i}", accuracy=0.9 - 0.3 * i)
        p.mu, p.var, p.n_obs = mu, 4.0, 10
        ps.append(p)
    store = ProfileStore(ps)
    base_tab = store.table()
    sigma_before = base_tab.sigma.copy()
    view = shifted_store(store, lambda n: 10.0)
    view.observe("m0", 60.0)            # must not raise
    view.observe_queue("m0", 5.0)
    np.testing.assert_array_equal(base_tab.sigma, sigma_before)
    assert store["m0"].mu == 40.0       # base profiles untouched
    assert view["m0"].mu != 40.0 + 10.0  # view's copy absorbed the obs
    assert view.table().mu[0] == view["m0"].mu  # rebuilt view snapshot


def test_batch_of_one_still_validates_backend():
    """Regression: the scalar shortcut must not bypass backend
    validation — an invalid name raises exactly like it does for larger
    batches."""
    store = ProfileStore([ModelProfile(name="m0", accuracy=0.9)])
    store.profiles["m0"].mu, store.profiles["m0"].n_obs = 10.0, 5
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="unknown policy backend"):
        ModiPick(t_threshold=20.0).select_batch(store, [100.0], rng,
                                                backend="bogus")


def test_select_lean_equivalence_fuzz():
    """select_lean == select_traced: same pick, same fallback, same RNG
    stream — over randomized pools, thresholds and budgets."""
    rng = np.random.default_rng(17)
    for _ in range(400):
        n = int(rng.integers(1, 13))
        ps = []
        for i in range(n):
            p = ModelProfile(name=f"m{i}",
                             accuracy=float(rng.uniform(0.05, 1.0)))
            p.mu = float(rng.uniform(1, 200))
            p.var = float(rng.uniform(0, 20)) ** 2
            p.n_obs = 50
            ps.append(p)
        store = ProfileStore(ps)
        policy = ModiPick(t_threshold=float(rng.uniform(0, 50)),
                          gamma=float(rng.choice([1.0, 4.0])))
        b = float(rng.uniform(-20, 500))
        seed = int(rng.integers(1 << 30))
        r1, r2 = np.random.default_rng(seed), np.random.default_rng(seed)
        a = policy.select_traced(store, b, r1)
        lean = policy.select_lean(store, b, r2)
        assert a.chosen == lean.chosen
        assert a.fallback == lean.fallback
        assert r1.random() == r2.random()      # identical stream state


# ----------------------------------------------------------------------
# slow acceptance gates (opt-in, pyproject slow marker)
# ----------------------------------------------------------------------

def _engine_rps(queue_aware: bool, repeats: int = 3) -> float:
    best = 0.0
    for _ in range(repeats):
        eng = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2),
                               seed=3, queue_aware=queue_aware)
        t0 = time.perf_counter()
        eng.run(ModiPick(t_threshold=20.0), 250.0, 2000,
                arrivals=PoissonArrivals(40.0))
        best = max(best, 2000.0 / (time.perf_counter() - t0))
    return best


@pytest.mark.slow
def test_soa_engine_3x_pr4_loop_on_rate40_sweep():
    """Acceptance: the SoA engine runs the rate-40 sweep point (plain +
    queue-aware ModiPick, the load_sweep workhorses) at >= 3x the PR-4
    event loop measured on this host."""
    pr4_s = 2000.0 / PR4_RATE40_QA_RPS + 2000.0 / PR4_RATE40_PLAIN_RPS
    new_s = 2000.0 / _engine_rps(True) + 2000.0 / _engine_rps(False)
    assert pr4_s / new_s >= 3.0, \
        f"rate-40 sweep point speedup {pr4_s / new_s:.2f}x < 3x"


@pytest.mark.slow
def test_jax_backend_not_slower_than_numpy_at_4096():
    """Acceptance: with stages 1-3 device-resident, the jax backend must
    match or beat numpy from JAX_MIN_BATCH up on this host."""
    from repro.core.zoo import make_store
    store = make_store(TABLE2)
    policy = ModiPick(t_threshold=20.0)
    rng = np.random.default_rng(23)
    t_input = np.clip(rng.normal(50.0, 25.0, size=4096), 0.0, None)
    budgets = np.maximum(250.0 - 2.0 * t_input, 5.0)

    def best_rate(backend):
        brng = np.random.default_rng(1)
        run = lambda: policy.select_batch(store, budgets, brng,
                                          backend=backend)
        run()                                   # warm-up / jit compile
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            run()
            best = min(best, time.perf_counter() - t0)
        return 4096.0 / best

    assert best_rate("jax") >= best_rate("numpy")
