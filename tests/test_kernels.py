"""Per-kernel allclose vs the pure-jnp oracles: shape/dtype sweeps in
interpret mode (the kernel bodies execute on CPU through the JAX
interpreter)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,hd,window", [
    (1, 4, 4, 128, 64, 0),      # MHA causal
    (2, 4, 2, 256, 64, 0),      # GQA causal
    (2, 4, 1, 256, 32, 64),     # MQA sliding window
    (1, 8, 4, 512, 128, 128),   # GQA window, MXU-aligned head dim
])
def test_flash_attention(dtype, B, H, KV, S, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KV,G,S,hd,window", [
    (2, 2, 2, 256, 64, 0),
    (1, 4, 1, 128, 128, 0),
    (3, 2, 4, 256, 32, 96),
])
def test_decode_attention(dtype, B, KV, G, S, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32).astype(dtype)
    pos = jnp.asarray(np.random.default_rng(0).integers(1, S, B), jnp.int32)
    out = ops.decode_attention(q, k, v, pos, window=window, block_k=64)
    expect = ref.decode_attention_ref(q, k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,G,S,hd,N,chunk", [
    (1, 2, 1, 128, 16, 16, 32),
    (2, 4, 2, 256, 32, 64, 64),
    (1, 4, 4, 128, 64, 128, 128),
])
def test_ssd_scan(dtype, B, H, G, S, hd, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = (jax.random.normal(ks[0], (B, H, S, hd), jnp.float32) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B_ = (jax.random.normal(ks[3], (B, G, S, N), jnp.float32) * 0.3).astype(dtype)
    C_ = (jax.random.normal(ks[4], (B, G, S, N), jnp.float32) * 0.3).astype(dtype)
    out = ops.ssd_scan(x, dt.astype(dtype), A, B_, C_, chunk=chunk)
    expect = ref.ssd_scan_ref(x, dt.astype(dtype), A, B_, C_)
    scale = np.maximum(np.abs(np.asarray(expect, np.float32)).max(), 1.0)
    np.testing.assert_allclose(np.asarray(out, np.float32) / scale,
                               np.asarray(expect, np.float32) / scale,
                               **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,W,block", [
    (1, 128, 64, 32),
    (2, 256, 128, 64),
    (2, 512, 256, 256),
])
def test_rglru_scan(dtype, B, S, W, block):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W), jnp.float32)) * 0.98).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, W), jnp.float32) * 0.1).astype(dtype)
    out = ops.rglru_scan(a, b, block_s=block)
    expect = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,n,gamma", [
    (8, 11, 1.0),      # Table-2 pool, one batch block
    (300, 12, 4.0),    # ragged batch (padding) + sharpened accuracy
    (64, 200, 1.0),    # pool wider than one lane tile
])
def test_policy_select_probs(B, n, gamma):
    """Fused ModiPick stage-3 kernel vs the pure-jnp oracle, including
    all-ineligible (fallback) rows."""
    rng = np.random.default_rng(42)
    mu = jnp.asarray(rng.uniform(1.0, 200.0, n), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.0, 20.0, n), jnp.float32)
    acc = jnp.asarray(rng.uniform(0.05, 1.0, n), jnp.float32)
    t_u = jnp.asarray(rng.uniform(5.0, 300.0, B), jnp.float32)
    t_l = t_u - 20.0
    elig = jnp.asarray(
        (rng.random((B, n)) < 0.4)
        & (np.asarray(mu + sigma)[None, :] < np.asarray(t_u)[:, None]))
    elig = elig.at[0].set(False)  # guaranteed fallback row
    out = ops.modipick_probs(mu, sigma, acc, t_u, t_l, elig, gamma=gamma)
    expect = ref.policy_probs_ref(mu, sigma, acc, t_u, t_l, elig,
                                  gamma=gamma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)
    rows = np.asarray(out).sum(axis=1)
    active = np.asarray(elig).any(axis=1)
    np.testing.assert_allclose(rows[active], 1.0, rtol=1e-5)
    np.testing.assert_allclose(rows[~active], 0.0, atol=1e-7)


def test_flash_vs_model_xla_path():
    """The model's chunked XLA attention and the Pallas kernel agree."""
    from repro.models.attention import attention_full
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, H, KV, S, hd = 2, 4, 2, 256, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    xla_out = attention_full(q, k, v, pos, pos, causal=True, q_chunk=64)
    pl_out = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(xla_out, np.float32),
                               np.asarray(pl_out.transpose(0, 2, 1, 3), np.float32),
                               rtol=2e-5, atol=2e-5)
