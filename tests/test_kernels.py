"""Per-kernel allclose vs the pure-jnp oracles: shape/dtype sweeps in
interpret mode (the kernel bodies execute on CPU through the JAX
interpreter)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,hd,window", [
    (1, 4, 4, 128, 64, 0),      # MHA causal
    (2, 4, 2, 256, 64, 0),      # GQA causal
    (2, 4, 1, 256, 32, 64),     # MQA sliding window
    (1, 8, 4, 512, 128, 128),   # GQA window, MXU-aligned head dim
])
def test_flash_attention(dtype, B, H, KV, S, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KV,G,S,hd,window", [
    (2, 2, 2, 256, 64, 0),
    (1, 4, 1, 128, 128, 0),
    (3, 2, 4, 256, 32, 96),
])
def test_decode_attention(dtype, B, KV, G, S, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32).astype(dtype)
    pos = jnp.asarray(np.random.default_rng(0).integers(1, S, B), jnp.int32)
    out = ops.decode_attention(q, k, v, pos, window=window, block_k=64)
    expect = ref.decode_attention_ref(q, k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,G,S,hd,N,chunk", [
    (1, 2, 1, 128, 16, 16, 32),
    (2, 4, 2, 256, 32, 64, 64),
    (1, 4, 4, 128, 64, 128, 128),
])
def test_ssd_scan(dtype, B, H, G, S, hd, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = (jax.random.normal(ks[0], (B, H, S, hd), jnp.float32) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B_ = (jax.random.normal(ks[3], (B, G, S, N), jnp.float32) * 0.3).astype(dtype)
    C_ = (jax.random.normal(ks[4], (B, G, S, N), jnp.float32) * 0.3).astype(dtype)
    out = ops.ssd_scan(x, dt.astype(dtype), A, B_, C_, chunk=chunk)
    expect = ref.ssd_scan_ref(x, dt.astype(dtype), A, B_, C_)
    scale = np.maximum(np.abs(np.asarray(expect, np.float32)).max(), 1.0)
    np.testing.assert_allclose(np.asarray(out, np.float32) / scale,
                               np.asarray(expect, np.float32) / scale,
                               **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,W,block", [
    (1, 128, 64, 32),
    (2, 256, 128, 64),
    (2, 512, 256, 256),
])
def test_rglru_scan(dtype, B, S, W, block):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W), jnp.float32)) * 0.98).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, W), jnp.float32) * 0.1).astype(dtype)
    out = ops.rglru_scan(a, b, block_s=block)
    expect = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,n,gamma", [
    (8, 11, 1.0),      # Table-2 pool, one batch block
    (300, 12, 4.0),    # ragged batch (padding) + sharpened accuracy
    (64, 200, 1.0),    # pool wider than one lane tile
])
def test_policy_select_probs(B, n, gamma):
    """Fused ModiPick stage-3 kernel vs the pure-jnp oracle, including
    all-ineligible (fallback) rows."""
    rng = np.random.default_rng(42)
    mu = jnp.asarray(rng.uniform(1.0, 200.0, n), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.0, 20.0, n), jnp.float32)
    acc = jnp.asarray(rng.uniform(0.05, 1.0, n), jnp.float32)
    t_u = jnp.asarray(rng.uniform(5.0, 300.0, B), jnp.float32)
    t_l = t_u - 20.0
    elig = jnp.asarray(
        (rng.random((B, n)) < 0.4)
        & (np.asarray(mu + sigma)[None, :] < np.asarray(t_u)[:, None]))
    elig = elig.at[0].set(False)  # guaranteed fallback row
    out = ops.modipick_probs(mu, sigma, acc, t_u, t_l, elig, gamma=gamma)
    expect = ref.policy_probs_ref(mu, sigma, acc, t_u, t_l, elig,
                                  gamma=gamma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)
    rows = np.asarray(out).sum(axis=1)
    active = np.asarray(elig).any(axis=1)
    np.testing.assert_allclose(rows[active], 1.0, rtol=1e-5)
    np.testing.assert_allclose(rows[~active], 0.0, atol=1e-7)


# ----------------------------------------------------------------------
# device-resident stages 1–2: fused masks vs the numpy reference
# ----------------------------------------------------------------------

def _grid_pool_and_budgets(seed, n, B):
    """Random pool + budgets quantized to a 0.25 grid in [0, 512]: every
    value — and every sum/difference stages 1–2 form from them — is
    exactly representable in BOTH float32 and float64, so the device
    masks must equal the f64 numpy reference bit for bit (no
    precision-boundary flakes by construction)."""
    rng = np.random.default_rng(seed)
    q = lambda x: np.round(np.asarray(x) * 4.0) / 4.0
    mu = q(rng.uniform(1.0, 200.0, n))
    sigma = q(rng.uniform(0.0, 20.0, n))
    acc = rng.uniform(0.05, 1.0, n)          # not used by stages 1–2
    t_u = q(rng.uniform(-20.0, 400.0, B))
    t_l = t_u - q(rng.uniform(0.0, 50.0))
    return mu, sigma, acc, t_u, t_l


@pytest.mark.parametrize("seed,n,B", [
    (0, 11, 64),     # Table-2-sized pool
    (1, 1, 16),      # singleton pool
    (2, 12, 256),    # one full batch block
    (3, 7, 1000),    # ragged batch
])
def test_device_masks_match_numpy_reference(seed, n, B):
    """Property: the fused pipeline's stage 1–2 masks and base indices
    (computed in jitted jnp through ``masks_device``) equal the
    ``policy_vec.modipick_masks`` numpy reference over randomized pools
    and budgets — including fallback rows."""
    from repro.core.policy_vec import modipick_masks
    from repro.core.profiles import ProfileTable
    from repro.kernels import policy_select

    mu, sigma, acc, t_u, t_l = _grid_pool_and_budgets(seed, n, B)
    tab = ProfileTable(names=tuple(f"m{i}" for i in range(n)),
                       accuracy=acc, mu=mu, sigma=sigma,
                       queue_mu=np.zeros(n))
    base, has_base, eligible, _ = modipick_masks(tab, t_u, t_l)
    d_base, d_has, d_elig = policy_select.masks_device(
        tab.device_pool(), t_u, t_l)
    np.testing.assert_array_equal(has_base, d_has)
    np.testing.assert_array_equal(base[has_base], d_base[has_base])
    np.testing.assert_array_equal(eligible, d_elig)
    # the pure-jnp oracle in kernels.ref agrees with the traced stages
    rank = np.empty(n, np.float32)
    rank[tab.acc_order] = np.arange(n, dtype=np.float32)
    r_base, r_has, r_elig = ref.modipick_masks_ref(
        jnp.asarray(mu, jnp.float32), jnp.asarray(sigma, jnp.float32),
        jnp.asarray(rank), jnp.asarray(t_u, jnp.float32),
        jnp.asarray(t_l, jnp.float32))
    np.testing.assert_array_equal(np.asarray(r_has), has_base)
    np.testing.assert_array_equal(np.asarray(r_base)[has_base],
                                  base[has_base])
    np.testing.assert_array_equal(np.asarray(r_elig), eligible)


def test_select_fused_device_resident_picks():
    """``select_fused`` goes from raw pool operands to sampled indices
    in one jit: every pick must land inside the request's stage-2
    eligible set, fallback rows must route to the fastest model, and a
    degenerate single-model pool must pick it always."""
    from repro.core.policy_vec import modipick_masks
    from repro.core.profiles import ProfileTable
    from repro.kernels import policy_select

    mu, sigma, acc, t_u, t_l = _grid_pool_and_budgets(7, 11, 512)
    tab = ProfileTable(names=tuple(f"m{i}" for i in range(11)),
                       accuracy=acc, mu=mu, sigma=sigma,
                       queue_mu=np.zeros(11))
    _, has_base, eligible, _ = modipick_masks(tab, t_u, t_l)
    idx, d_has = policy_select.select_fused(tab.device_pool(), t_u, t_l,
                                            gamma=1.0, seed=5)
    np.testing.assert_array_equal(has_base, d_has)
    assert all(eligible[b, idx[b]] for b in np.flatnonzero(has_base))
    assert (idx[~has_base] == tab.fastest).all()
    # distribution sanity on a repeated budget row: empirical frequency
    # tracks the reference probability vector
    from repro.core.policy_vec import modipick_probs
    t1 = np.full(20000, 150.0)
    tl1 = t1 - 20.0
    _, _, e1, _ = modipick_masks(tab, t1, tl1)
    p_ref = modipick_probs(tab, t1, tl1, e1, 1.0)[0]
    picks, _ = policy_select.select_fused(tab.device_pool(), t1, tl1,
                                          gamma=1.0, seed=11)
    emp = np.bincount(picks, minlength=11) / len(picks)
    np.testing.assert_allclose(emp, p_ref, atol=0.015)


def test_fused_jit_cache_reused_across_calls():
    """The compiled selection is cached per (pool_size, gamma,
    batch_block): repeated calls must hit the same callable, and
    distinct gammas must not collide."""
    from repro.kernels import policy_select
    a = policy_select._fused_jit(128, 1.0, 256, False)
    assert policy_select._fused_jit(128, 1.0, 256, False) is a
    assert policy_select._fused_jit(128, 4.0, 256, False) is not a


def test_flash_vs_model_xla_path():
    """The model's chunked XLA attention and the Pallas kernel agree."""
    from repro.models.attention import attention_full
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, H, KV, S, hd = 2, 4, 2, 256, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    xla_out = attention_full(q, k, v, pos, pos, causal=True, q_chunk=64)
    pl_out = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(xla_out, np.float32),
                               np.asarray(pl_out.transpose(0, 2, 1, 3), np.float32),
                               rtol=2e-5, atol=2e-5)
