"""Continuous-batching engine: slot isolation + equivalence with
standalone generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serving.batcher import ContinuousBatcher, GenRequest

KEY = jax.random.PRNGKey(0)


def standalone_generate(cfg, params, prompt, max_new, cache_len=96):
    cache, logits = M.prefill(cfg, params, {"tokens": jnp.asarray(prompt[None])},
                              cache_len)
    out = [int(jnp.argmax(logits[0]))]
    pos = jnp.asarray([len(prompt)], jnp.int32)
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(max_new - 1):
        lg, cache = M.decode_step(cfg, params, cache, tok, pos)
        out.append(int(jnp.argmax(lg[0])))
        tok = jnp.asarray([out[-1]], jnp.int32)
        pos = pos + 1
    return out


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma3-4b", "mamba2-1.3b"])
def test_batched_equals_standalone(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY, jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (9, 17, 5)]
    max_new = 6

    expected = [standalone_generate(cfg, params, p, max_new) for p in prompts]

    engine = ContinuousBatcher(cfg, params, max_slots=2, cache_len=96)
    reqs = [GenRequest(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion()

    for r, exp in zip(reqs, expected):
        assert r.done
        assert r.generated == exp, (r.rid, r.generated, exp)


def test_slots_reused_and_throughput_counted():
    cfg = get_config("qwen2-1.5b").reduced()
    params = M.init_params(cfg, KEY, jnp.float32)
    rng = np.random.default_rng(1)
    engine = ContinuousBatcher(cfg, params, max_slots=2, cache_len=64)
    for i in range(5):
        engine.submit(GenRequest(
            rid=i, prompt=rng.integers(0, 100, size=6, dtype=np.int32),
            max_new=3))
    engine.run_to_completion()
    assert engine.n_steps > 0
    assert all(not s for s in engine.slots)


def test_queue_telemetry_feeds_profile_store():
    """Queue waits observed at slot insertion flow into the profile
    store's W_queue estimate (the queue-aware routing signal)."""
    from repro.core.profiles import ModelProfile, ProfileStore

    cfg = get_config("qwen2-1.5b").reduced()
    params = M.init_params(cfg, KEY, jnp.float32)
    store = ProfileStore([ModelProfile(name="qwen", accuracy=0.9)])
    rng = np.random.default_rng(2)
    engine = ContinuousBatcher(cfg, params, max_slots=1, cache_len=64,
                               store=store, model_name="qwen")
    for i in range(3):  # 1 slot + 3 requests => real queueing
        engine.submit(GenRequest(
            rid=i, prompt=rng.integers(0, 100, size=6, dtype=np.int32),
            max_new=3))
    assert engine.queue_depth() == 3
    engine.run_to_completion()
    assert engine.queue_depth() == 0
    assert store["qwen"].queue_obs == 3
    assert store.queue_wait("qwen") > 0.0
    tel = engine.telemetry()
    assert tel["model"] == "qwen" and tel["queue_depth"] == 0
