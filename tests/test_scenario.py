"""Scenario API: spec validation + dict round trips, golden-pinned
build() equivalence with the historical kwargs paths, the from_scenario
adapters, diurnal/burst synthesizers, class-aware admission (unit and
end-to-end protection), the queue-target autoscaler loop, and the
Router.stats()/reset() + deprecation-shim satellites."""
import dataclasses
import json
from dataclasses import dataclass
from typing import Callable

import numpy as np
import pytest

from repro.core.netmodel import NetworkModel
from repro.core.policy import ModiPick, make_policy
from repro.core.profiles import ModelProfile, ProfileStore
from repro.core.simulate import Simulator
from repro.core.zoo import TABLE2
from repro.router import (ClassAwareAdmission, ClassPolicy, DepthCapAdmission,
                          InferenceRequest, Router, SlaAwareAdmission,
                          make_admission)
from repro.scenario import (AutoscalerSpec, DeploymentSpec, NetworkSpec,
                            PolicySpec, QueueTargetAutoscaler, Scenario,
                            SlaClass, WorkloadSpec, build, get_scenario,
                            list_scenarios, register)
from repro.serving.executor import PoolExecutor
from repro.sim import (PoissonArrivals, ServingSimulator, burst_trace,
                       diurnal_trace, per_model_replicas, shared_replicas)

NET = NetworkModel(50.0, 25.0)


# ----------------------------------------------------------------------
# spec: validation + serialization round trip
# ----------------------------------------------------------------------

def test_round_trip_every_registered_scenario():
    """Acceptance: Scenario.from_dict(s.to_dict()) == s for every
    registered scenario, through actual JSON text."""
    names = list_scenarios()
    assert {"steady", "diurnal", "burst", "class_mix", "scale_up",
            "fleet_steady", "fleet_diurnal", "premodel_mix", "tail_sla",
            "tail_sla_mean", "elastic_step", "elastic_proportional",
            "elastic_cost_weighted"} <= set(names)
    for name in names:
        s = get_scenario(name)
        d = s.to_dict()
        via_json = json.loads(json.dumps(d))    # plain data, JSON-clean
        assert Scenario.from_dict(via_json) == s
        assert Scenario.from_dict(d) == s


def test_from_file_round_trip_and_error_paths(tmp_path):
    s = get_scenario("premodel_mix")
    jpath = tmp_path / "scenario.json"
    jpath.write_text(json.dumps(s.to_dict()), encoding="utf-8")
    assert Scenario.from_file(jpath) == s

    tpath = tmp_path / "scenario.toml"
    tpath.write_text(
        'name = "tiny"\n'
        "[workload]\n"
        "n_requests = 10\n"
        "[policy]\n"
        "queue_aware = true\n", encoding="utf-8")
    t = Scenario.from_file(tpath)
    assert t.name == "tiny" and t.workload.n_requests == 10

    bad_json = tmp_path / "bad.json"
    bad_json.write_text("{not json", encoding="utf-8")
    with pytest.raises(json.JSONDecodeError):
        Scenario.from_file(bad_json)

    try:
        import tomllib
    except ImportError:
        import tomli as tomllib
    bad_toml = tmp_path / "bad.toml"
    bad_toml.write_text("name = ", encoding="utf-8")
    with pytest.raises(tomllib.TOMLDecodeError):
        Scenario.from_file(bad_toml)

    typo = tmp_path / "typo.json"
    typo.write_text(json.dumps({**s.to_dict(), "wrokload": {}}),
                    encoding="utf-8")
    with pytest.raises(ValueError, match="unknown scenario keys"):
        Scenario.from_file(typo)

    with pytest.raises(FileNotFoundError):
        Scenario.from_file(tmp_path / "missing.json")


def test_spec_validation_rejects_malformed_configs():
    with pytest.raises(ValueError, match="arrival"):
        WorkloadSpec(arrival="bogus")
    with pytest.raises(ValueError, match="rate_rps"):
        WorkloadSpec(arrival="poisson", rate_rps=0.0)
    with pytest.raises(ValueError, match="times_ms"):
        WorkloadSpec(arrival="trace")
    with pytest.raises(ValueError, match="rate_schedule"):
        WorkloadSpec(arrival="poisson", rate_schedule=(5.0, 10.0), epochs=3)
    with pytest.raises(ValueError, match="burst_rate_rps"):
        WorkloadSpec(arrival="burst", rate_rps=10.0, burst_rate_rps=5.0)
    with pytest.raises(ValueError, match="amplitude"):
        WorkloadSpec(arrival="diurnal", rate_rps=5.0, amplitude=1.5)
    with pytest.raises(ValueError, match="burst_len_ms"):
        WorkloadSpec(arrival="burst", rate_rps=4.0, burst_rate_rps=8.0,
                     burst_len_ms=0.0)
    with pytest.raises(ValueError, match="backend"):
        PolicySpec(backend="garbage")
    with pytest.raises(ValueError, match="every epoch"):
        WorkloadSpec(arrival="poisson", rate_rps=5.0, n_requests=3,
                     epochs=4)
    with pytest.raises(ValueError, match="every epoch"):
        # trace n_requests derives from the trace: 3 points, 4 epochs
        WorkloadSpec(arrival="trace", times_ms=(0.0, 1.0, 2.0), epochs=4)
    with pytest.raises(ValueError, match="duplicate"):
        WorkloadSpec(classes=(SlaClass("a", 100.0), SlaClass("a", 200.0)))
    with pytest.raises(ValueError, match="topology"):
        DeploymentSpec(topology="mesh")
    with pytest.raises(ValueError, match="speeds"):
        DeploymentSpec(topology="shared", replicas=2, speeds=(1.0,))
    with pytest.raises(ValueError, match="admission"):
        DeploymentSpec(admission="bogus")
    with pytest.raises(ValueError, match="policy"):
        PolicySpec(policy="bogus")
    with pytest.raises(ValueError, match="min_replicas"):
        AutoscalerSpec(min_replicas=5, max_replicas=2)
    with pytest.raises(ValueError, match="epochs"):
        Scenario(name="x",
                 deployment=DeploymentSpec(autoscaler=AutoscalerSpec()))
    with pytest.raises(ValueError, match="subset"):
        build(Scenario(name="x", deployment=DeploymentSpec(
            subset=("NotAModel",)))).engine()


def test_registry_rejects_silent_shadowing():
    s = get_scenario("steady")
    with pytest.raises(ValueError, match="already registered"):
        register(dataclasses.replace(s))
    register(dataclasses.replace(s), replace=True)   # explicit is fine


# ----------------------------------------------------------------------
# acceptance: build() reproduces the seeded engine goldens bit-identically
# ----------------------------------------------------------------------

def test_steady_scenario_reproduces_engine_golden_bit_identical():
    """The registered steady scenario IS the seeded queue-aware golden
    config; the Scenario path must reproduce it bit for bit."""
    r = build(get_scenario("steady")).run().result
    assert r.sla_attainment == 0.9983333333333333
    assert r.mean_accuracy == 0.7975266666666666
    assert r.mean_latency == 191.67831081440173
    assert r.mean_queue_wait == 23.493148434870164
    # and it equals a fresh hand-wired engine run, field for field
    eng = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2), seed=3,
                           queue_aware=True)
    ref = eng.run(ModiPick(t_threshold=20.0), 250.0, 600,
                  arrivals=PoissonArrivals(30.0))
    assert r == ref


def test_closed_loop_scenario_reproduces_paper_golden():
    sc = Scenario(
        name="paper_loop",
        workload=WorkloadSpec(arrival="closed_loop", n_requests=800,
                              t_sla_ms=200.0),
        network=NetworkSpec(50.0, 25.0),
        deployment=DeploymentSpec(topology="shared", replicas=1),
        policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0}),
        seed=1)
    sim = Simulator.from_scenario(sc)
    r = sim.run(ModiPick(t_threshold=20.0), 200.0, 800)
    assert r.sla_attainment == 0.9775            # pinned golden
    assert r.mean_accuracy == 0.7813437499999999
    # the harness's engine path agrees on the same numbers
    h = build(sc).run().result
    assert h.sla_attainment == 0.9775
    assert h.mean_accuracy == 0.7813437499999999


def test_from_scenario_adapters_match_builder():
    sc = get_scenario("steady")
    eng = ServingSimulator.from_scenario(sc)
    assert isinstance(eng, ServingSimulator)
    assert eng.seed == 3 and eng.queue_aware
    assert len(eng.pool.replicas) == len(TABLE2)
    with pytest.raises(ValueError, match="closed loop"):
        Simulator.from_scenario(sc)              # steady is open-loop


@dataclass
class _FakeVariant:
    name: str
    quality: float
    latency_fn: Callable[[], float]

    def run(self, tokens, n_decode=2) -> float:
        return float(self.latency_fn())


def test_executor_from_scenario():
    sc = Scenario(
        name="exec", workload=WorkloadSpec(arrival="poisson", rate_rps=5.0,
                                           n_requests=10, t_sla_ms=200.0),
        network=NetworkSpec(15.0, 0.0),
        deployment=DeploymentSpec(admission="sla_aware"),
        policy=PolicySpec(policy="dynamic_greedy", queue_aware=True),
        seed=1)
    rng = np.random.default_rng(0)
    pool = [_FakeVariant("small", 0.5, lambda: rng.normal(10, 1)),
            _FakeVariant("large", 0.9, lambda: rng.normal(80, 4))]
    ex = PoolExecutor.from_scenario(sc, pool)
    assert isinstance(ex.router.admission, SlaAwareAdmission)
    assert ex.queue_aware and ex.seed == 1
    ex.warm_up(np.zeros((1, 4), np.int32))
    res = ex.execute(np.zeros((1, 4), np.int32), t_sla=200.0)
    assert res.admitted and res.variant in {"small", "large"}


# ----------------------------------------------------------------------
# diurnal / burst synthesizers
# ----------------------------------------------------------------------

def test_synthesized_trace_stream_decorrelated_from_engine_seed():
    """The thinning sampler must not share the engine's PCG64 stream:
    build_arrival_times salts the scenario seed."""
    from repro.scenario.build import build_arrival_times
    sc = get_scenario("diurnal")
    wl = sc.workload
    salted = build_arrival_times(sc)
    unsalted = np.asarray(diurnal_trace(
        wl.n_requests, wl.rate_rps, period_ms=wl.period_ms,
        amplitude=wl.amplitude, seed=sc.seed).times_ms)
    assert not np.array_equal(salted, unsalted)
    np.testing.assert_array_equal(salted, build_arrival_times(sc))


def test_diurnal_trace_shape_and_determinism():
    tr = diurnal_trace(2000, 20.0, period_ms=10_000.0, amplitude=0.9,
                       seed=4)
    t = np.asarray(tr.times_ms)
    assert len(t) == 2000 and (np.diff(t) > 0).all() and t[0] >= 0.0
    again = diurnal_trace(2000, 20.0, period_ms=10_000.0, amplitude=0.9,
                          seed=4)
    np.testing.assert_array_equal(t, np.asarray(again.times_ms))
    # peak half-cycles (sin > 0) must hold more arrivals than troughs
    phase = (t % 10_000.0) < 5_000.0
    assert phase.sum() > 1.5 * (~phase).sum()
    with pytest.raises(ValueError, match="amplitude"):
        diurnal_trace(10, 5.0, amplitude=1.0)


def test_burst_trace_concentrates_arrivals_in_bursts():
    tr = burst_trace(2000, 2.0, burst_rate_rps=100.0,
                     burst_every_ms=5_000.0, burst_len_ms=500.0, seed=4)
    t = np.asarray(tr.times_ms)
    assert len(t) == 2000 and (np.diff(t) > 0).all()
    in_burst = (t % 5_000.0) < 500.0
    # burst windows are 10% of the time but 100/2 = 50x the rate
    assert in_burst.mean() > 0.7
    with pytest.raises(ValueError, match="burst_len_ms"):
        burst_trace(10, 2.0, burst_rate_rps=10.0, burst_every_ms=100.0,
                    burst_len_ms=200.0)


# ----------------------------------------------------------------------
# class-aware admission: unit semantics
# ----------------------------------------------------------------------

def _table2_store():
    profiles = [ModelProfile(name=f"m{i}", accuracy=a)
                for i, a in enumerate((0.5, 0.9))]
    for p, (mu, s) in zip(profiles, ((10.0, 1.0), (80.0, 2.0))):
        p.mu, p.var, p.n_obs = mu, s ** 2, 100
    return ProfileStore(profiles)


def test_class_policy_validation():
    with pytest.raises(ValueError, match="protect"):
        ClassPolicy(protect=0.0)
    with pytest.raises(ValueError, match="max_share"):
        ClassPolicy(max_share=1.5)


def test_class_aware_protect_one_matches_sla_aware():
    """protect=1.0 is exactly SlaAwareAdmission viability."""
    tab = _table2_store().table()
    adm = ClassAwareAdmission(default=ClassPolicy(protect=1.0))
    ref = SlaAwareAdmission()
    req = InferenceRequest(t_sla_ms=200.0, t_input_ms=25.0)
    for waits in ({"m0": 149.0, "m1": 200.0}, {"m0": 150.0, "m1": 400.0},
                  {"m0": 0.0, "m1": 0.0}):
        for budget in (150.0, -5.0):
            assert adm.admit(req, budget, tab, waits.__getitem__)[0] == \
                ref.admit(req, budget, tab, waits.__getitem__)[0]
    assert adm.admit(req, -5.0, tab, None) == (True, "")   # no telemetry


def test_class_aware_weighted_shedding_orders_classes():
    """With queues eating 40% of the budget, a protect=0.35 class sheds
    while protect=1.0 still admits — batch drains before interactive."""
    tab = _table2_store().table()
    adm = ClassAwareAdmission(classes={
        "interactive": ClassPolicy(protect=1.0),
        "batch": {"protect": 0.35},      # dict form coerces
    })
    waits = {"m0": 80.0, "m1": 80.0}.__getitem__
    inter = InferenceRequest(t_sla_ms=200.0, t_input_ms=0.0,
                             sla_class="interactive")
    batch = InferenceRequest(t_sla_ms=200.0, t_input_ms=0.0,
                             sla_class="batch")
    assert adm.admit(inter, 200.0, tab, waits)[0]
    ok, reason = adm.admit(batch, 200.0, tab, waits)
    assert not ok and "batch" in reason and "0.35" in reason
    # unknown classes ride the default policy (protect=1.0 here)
    other = InferenceRequest(t_sla_ms=200.0, t_input_ms=0.0,
                             sla_class="mystery")
    assert adm.admit(other, 200.0, tab, waits)[0]


def test_class_aware_share_quota_under_pressure():
    tab = _table2_store().table()
    adm = ClassAwareAdmission(
        classes={"batch": ClassPolicy(protect=1.0, max_share=0.5)},
        pressure_ms=5.0)
    quiet = {"m0": 0.0, "m1": 0.0}.__getitem__
    busy = {"m0": 50.0, "m1": 60.0}.__getitem__
    batch = InferenceRequest(t_sla_ms=500.0, t_input_ms=0.0,
                             sla_class="batch")
    inter = InferenceRequest(t_sla_ms=500.0, t_input_ms=0.0,
                             sla_class="interactive")
    # no pressure: quota dormant, batch admits freely
    for _ in range(4):
        assert adm.admit(batch, 500.0, tab, quiet)[0]
    # under pressure batch is over its 50% share (4/4 admitted): shed
    ok, reason = adm.admit(batch, 500.0, tab, busy)
    assert not ok and "quota" in reason
    assert adm.admit(inter, 500.0, tab, busy)[0]   # unquotaed class fine
    # admitting interactive traffic dilutes batch's share below quota
    for _ in range(6):
        adm.admit(inter, 500.0, tab, busy)
    assert adm.admit(batch, 500.0, tab, busy)[0]
    # reset() clears the window: first-request guard admits again
    adm.reset()
    assert adm.n_admitted == 0 and adm.admitted_by_class == {}
    assert adm.admit(batch, 500.0, tab, busy)[0]
    assert isinstance(make_admission("class_aware"), ClassAwareAdmission)


def test_class_mix_scenario_protects_interactive_end_to_end():
    """Acceptance: under one saturated shared replica, class-aware
    admission sheds batch first and interactive keeps (much) more of its
    attainment than batch — and than it would under class-blind
    sla_aware admission."""
    sc = dataclasses.replace(
        get_scenario("class_mix"),
        workload=dataclasses.replace(get_scenario("class_mix").workload,
                                     n_requests=500))
    r = build(sc).run().result
    inter, batch = r.per_class["interactive"], r.per_class["batch"]
    assert batch["shed_rate"] > inter["shed_rate"] + 0.2
    assert inter["attainment"] > batch["attainment"] + 0.2
    # class-blind baseline: same load, sla_aware — interactive collapses
    blind = dataclasses.replace(
        sc, name="class_mix_blind",
        deployment=dataclasses.replace(sc.deployment, admission="sla_aware",
                                       admission_kwargs={}))
    rb = build(blind).run().result
    assert r.per_class["interactive"]["attainment"] > \
        rb.per_class["interactive"]["attainment"] + 0.2


def test_per_class_rows_do_not_perturb_the_run():
    """class_for labels must not touch the RNG: a labelled run is
    draw-for-draw identical to the unlabelled run, plus per_class rows
    whose totals reconcile with the run-level counters."""
    def run(class_for):
        eng = ServingSimulator(TABLE2, NET, per_model_replicas(TABLE2),
                               seed=6)
        return eng.run(ModiPick(t_threshold=20.0), 250.0, 300,
                       arrivals=PoissonArrivals(20.0), class_for=class_for)

    plain = run(None)
    labelled = run(lambda rid: "even" if rid % 2 == 0 else "odd")
    assert plain.per_class == {}
    assert set(labelled.per_class) == {"even", "odd"}
    for f in ("sla_attainment", "mean_accuracy", "mean_latency",
              "p99_latency", "mean_queue_wait"):
        assert getattr(plain, f) == getattr(labelled, f)
    total = sum(c["n_arrived"] for c in labelled.per_class.values())
    assert total == labelled.n_arrived


# ----------------------------------------------------------------------
# autoscaler
# ----------------------------------------------------------------------

def _stats(routed=100, shed=0, fallback=0):
    return {"n_routed": routed, "n_shed": shed, "n_fallback": fallback,
            "n_batches": 10, "mean_batch": routed / 10}


@dataclass
class _FakeResult:
    mean_queue_wait: float
    replica_utilization: dict


def test_queue_target_autoscaler_decisions():
    sc = QueueTargetAutoscaler(AutoscalerSpec(
        target_queue_ms=50.0, max_shed_rate=0.02, min_replicas=1,
        max_replicas=4, step=2, low_utilization=0.3))
    hot = _FakeResult(120.0, {"r0": 0.99})
    assert sc.decide(1, _stats(), hot) == 3
    assert sc.decide(3, _stats(), hot) == 4          # capped at max
    shedding = _FakeResult(10.0, {"r0": 0.8})
    assert sc.decide(2, _stats(shed=10), shedding) == 4
    steady = _FakeResult(20.0, {"r0": 0.6})
    assert sc.decide(2, _stats(), steady) == 2       # in band: hold
    idle = _FakeResult(1.0, {"r0": 0.05, "r1": 0.05})
    assert sc.decide(3, _stats(), idle) == 1
    assert sc.decide(1, _stats(), idle) == 1         # floored at min


def test_scale_up_scenario_recovers_attainment():
    """Acceptance: SLA attainment collapses at the 10x load step and
    recovers in later epochs purely through autoscaler replica adds."""
    full = get_scenario("scale_up")
    sc = dataclasses.replace(
        full, workload=dataclasses.replace(full.workload, n_requests=1000))
    out = build(sc).run()
    att, reps = out.attainment_history, out.replica_history
    assert reps[0] == reps[1] == 1                   # scaling acts *after*
    step_epoch, last = att[1], att[-1]
    assert step_epoch < 0.8                          # the step hurt
    assert reps[-1] > 1                              # it scaled up...
    assert last > step_epoch + 0.15                  # ...and recovered
    assert last > 0.9


# ----------------------------------------------------------------------
# satellites: Router.stats()/reset(), DepthCap edge case, shim warning
# ----------------------------------------------------------------------

def test_router_stats_after_mixed_admit_shed_batches_and_reset():
    """stats() semantics over batches that mix admits and sheds, then
    reset() for windowed (per-epoch) consumption."""
    profiles = [ModelProfile(name="m0", accuracy=0.9)]
    profiles[0].mu, profiles[0].var, profiles[0].n_obs = 50.0, 1.0, 100
    store = ProfileStore(profiles)
    router = Router(store, ModiPick(t_threshold=20.0),
                    admission=SlaAwareAdmission())
    rng = np.random.default_rng(0)
    # budget 300 admits; budget -100 (network ate the SLA) always sheds
    reqs = [InferenceRequest(t_sla_ms=300.0, t_input_ms=0.0, rid=0),
            InferenceRequest(t_sla_ms=100.0, t_input_ms=100.0, rid=1),
            InferenceRequest(t_sla_ms=300.0, t_input_ms=0.0, rid=2)]
    for _ in range(2):
        decs = router.route_batch(reqs, rng,
                                  w_queue_fn=lambda m: 0.0)
        assert [d.admitted for d in decs] == [True, False, True]
    s = router.stats()
    assert s["n_routed"] == 6 and s["n_admitted"] == 4 and s["n_shed"] == 2
    assert s["n_batches"] == 2 and s["mean_batch"] == 3.0
    router.reset()
    z = router.stats()
    assert all(z[k] == 0 for k in ("n_routed", "n_admitted", "n_shed",
                                   "n_fallback", "n_batches"))
    assert z["mean_batch"] == 0.0
    # windowed: post-reset stats cover only new traffic
    router.route_batch(reqs[:1], rng, w_queue_fn=lambda m: 0.0)
    assert router.stats()["n_routed"] == 1


def test_router_reset_clears_admission_window():
    store = _table2_store()
    adm = ClassAwareAdmission(default=ClassPolicy(max_share=0.5))
    router = Router(store, ModiPick(t_threshold=20.0), admission=adm)
    router.route(InferenceRequest(t_sla_ms=300.0, t_input_ms=0.0),
                 np.random.default_rng(0))
    assert adm.n_admitted == 1
    router.reset()
    assert adm.n_admitted == 0


def test_depth_cap_admission_without_w_queue_fn():
    """Regression pin: DepthCapAdmission never consumes W_queue — its
    verdict with w_queue_fn=None must equal the verdict with any
    estimator, and needs_w_queue stays False so the Router skips the
    telemetry snapshot entirely."""
    tab = _table2_store().table()
    adm = DepthCapAdmission(max_depth=2)
    assert adm.needs_w_queue is False
    req = InferenceRequest(t_sla_ms=200.0, t_input_ms=0.0)
    for depths in ({"m0": 0, "m1": 5}, {"m0": 2, "m1": 2}):
        with_fn = adm.admit(req, 200.0, tab, lambda m: 1e9,
                            depths.__getitem__)
        without = adm.admit(req, 200.0, tab, None, depths.__getitem__)
        assert with_fn == without
    # and with NEITHER telemetry source there is nothing to cap against
    assert adm.admit(req, 200.0, tab, None, None) == (True, "")
    # stateless: base-class reset() is a no-op that must exist (Router
    # calls it on every controller)
    adm.reset()


def test_make_policy_registry():
    assert isinstance(make_policy("modipick", t_threshold=5.0), ModiPick)
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("bogus")


# ----------------------------------------------------------------------
# harness slicing
# ----------------------------------------------------------------------

def test_epoch_slicing_and_rate_schedule():
    sc = Scenario(
        name="sliced",
        workload=WorkloadSpec(arrival="poisson", rate_rps=5.0,
                              rate_schedule=(5.0, 50.0, 50.0),
                              epochs=3, n_requests=100),
        deployment=DeploymentSpec(topology="shared", replicas=1))
    h = build(sc)
    assert h.epoch_sizes() == [34, 33, 33]
    assert [h.arrivals(e).rate_rps for e in range(3)] == [5.0, 50.0, 50.0]
    out = h.run()
    assert [e.result.n_arrived for e in out.epochs] == [34, 33, 33]


def test_trace_workload_derives_n_requests():
    """A trace IS the workload: n_requests always equals len(times_ms),
    so epoch slicing can never run off the end of the trace."""
    wl = WorkloadSpec(arrival="trace", times_ms=(0.0, 1.0, 2.0), epochs=2)
    assert wl.n_requests == 3
    sc = Scenario(name="tiny", workload=wl,
                  deployment=DeploymentSpec(topology="shared", replicas=1))
    out = build(sc).run()           # regression: used to IndexError
    assert sum(e.result.n_arrived for e in out.epochs) == 3


def test_policy_backend_reaches_the_router():
    sc = dataclasses.replace(
        get_scenario("steady"),
        policy=dataclasses.replace(get_scenario("steady").policy,
                                   backend="numpy"))
    eng = ServingSimulator.from_scenario(sc)
    assert eng.backend == "numpy"
    eng.run(ModiPick(t_threshold=20.0), 250.0, 5,
            arrivals=PoissonArrivals(5.0))
    assert eng.router.backend == "numpy"


def test_trace_scenario_epoch_slices_rebase_to_zero():
    times = tuple(float(10 * i) for i in range(40))
    sc = Scenario(
        name="tr",
        workload=WorkloadSpec(arrival="trace", times_ms=times, epochs=2,
                              n_requests=40),
        deployment=DeploymentSpec(topology="shared", replicas=1))
    h = build(sc)
    a0, a1 = h.arrivals(0), h.arrivals(1)
    assert len(a0) == 20 and len(a1) == 20
    assert a1.times_ms[0] == 0.0                  # rebased window
    np.testing.assert_allclose(np.diff(a1.times_ms), 10.0)
