"""Dry-run machinery smoke test: lower+compile one cheap cell on a tiny
fake-device mesh in a subprocess (so pytest's jax stays at 1 device)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [
    ("mamba2-1.3b", "long_500k"),      # ssm decode + context parallel
    ("whisper-tiny", "decode_32k"),    # enc-dec cross-attention cache
])
def test_dryrun_cell_compiles_on_debug_mesh(arch, shape, tmp_path):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "2x4", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=480, cwd=ROOT)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    with open(tmp_path / f"{arch}__{shape}__2x4.json") as fh:
        out = json.load(fh)
    assert out["status"] == "ok"
    assert out["roofline"]["hlo_flops"] > 0
    assert out["cost"]["bytes_accessed"] > 0
    assert out["roofline"]["dominant"] in ("compute", "memory", "collective")
