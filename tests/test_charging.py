"""Intra-batch load charging (the batched-routing staleness fix).

Covers, in order:

- the oracle property: a charged ``route_batch`` over a batch of B is
  pick-for-pick equal to B sequential singleton ``route`` calls with
  the queue waits updated between calls — the singleton path is the
  trusted scalar oracle, so the charged batch inherits its semantics;
- honest admission under bursts: the regression the bench exposed
  (``shed=0`` while attainment sat at 0.16) — ``SlaAwareAdmission``
  judged against charged waits sheds what cannot be served, and the
  engine's attainment recovers;
- the array-native ``route_batch_arrays`` column contract;
- the ``lax.scan`` charged kernel (forced jax backend) against the
  numpy sequential loop on a deterministic single-model pool, plus
  multi-model sanity.
"""
import numpy as np
import pytest

from repro.core.netmodel import NetworkModel
from repro.core.policy import ModiPick
from repro.core.profiles import ModelProfile, ProfileStore
from repro.core.zoo import TABLE2
from repro.router import (ChargedWaits, InferenceRequest, Router,
                          SlaAwareAdmission)
from repro.router.api import BatchDecisions
from repro.sim import ServingSimulator, TraceArrivals, per_model_replicas


def _random_store(rng, n):
    ps = []
    for i in range(n):
        p = ModelProfile(name=f"m{i}", accuracy=float(rng.uniform(0.05, 1.0)))
        p.mu = float(rng.uniform(5, 120))
        p.var = float(rng.uniform(0, 10)) ** 2
        p.n_obs = 50
        ps.append(p)
    return ProfileStore(ps)


def _burst(n, width, every_ms):
    bursts = -(-n // width)
    return TraceArrivals(np.repeat(np.arange(bursts) * every_ms, width)[:n])


# ----------------------------------------------------------------------
# the oracle property: charged batch == sequential singletons
# ----------------------------------------------------------------------

def test_charged_batch_equals_sequential_singleton_oracle():
    """Charged ``route_batch`` over B requests must be pick-for-pick
    (and shed-for-shed, and reported-wait-for-reported-wait) what B
    singleton ``route`` calls produce when the caller charges the queue
    waits between calls — over randomized pools, budgets, initial
    waits, and with/without SLA-aware admission."""
    meta = np.random.default_rng(99)
    for trial in range(25):
        n = int(meta.integers(2, 9))
        B = int(meta.integers(2, 17))
        seed = int(meta.integers(1 << 30))
        store_a = _random_store(np.random.default_rng(seed), n)
        store_b = _random_store(np.random.default_rng(seed), n)
        adm = SlaAwareAdmission() if trial % 2 else None
        policy = ModiPick(t_threshold=float(meta.uniform(0, 40)))
        kw = dict(admission=adm, queue_aware=True)
        router_a = Router(store_a, policy, **kw)
        router_b = Router(store_b, policy, **kw)
        waits0 = {f"m{i}": float(meta.uniform(0, 60)) for i in range(n)}
        reqs = [InferenceRequest(t_sla_ms=float(meta.uniform(40, 400)),
                                 t_input_ms=float(meta.uniform(0, 30)),
                                 rid=i)
                for i in range(B)]

        rng_a = np.random.default_rng(seed + 1)
        decs = router_a.route_batch(reqs, rng_a, w_queue_map=dict(waits0),
                                    charge=True)

        # The trusted oracle: singleton routes with the wait map charged
        # by the caller after every admitted pick (model-granularity
        # queues, μ from the table — exactly what per-model charging
        # models).
        rng_b = np.random.default_rng(seed + 1)
        tab = store_b.table()
        mu_of = dict(zip(tab.names, (float(m) for m in tab.mu)))
        waits = {k: max(0.0, v) for k, v in waits0.items()}
        for req, dec in zip(reqs, decs):
            ora = router_b.route(req, rng_b, w_queue_fn=waits.__getitem__)
            assert ora.admitted == dec.admitted, (trial, req.rid)
            assert ora.budget.w_queue_ms == dec.budget.w_queue_ms
            if not ora.admitted:
                assert ora.reject_reason == dec.reject_reason
                continue
            assert ora.variant == dec.variant, (trial, req.rid)
            assert ora.fallback == dec.fallback
            waits[ora.variant] += mu_of[ora.variant]
        # identical residual RNG state: same number and kind of draws
        assert rng_a.random() == rng_b.random()


def test_charge_false_keeps_one_snapshot_semantics():
    """``charge=False`` (the object-path default) must keep the
    historical contract: every request judged against the same frozen
    snapshot, batched vectorized selection."""
    store = _random_store(np.random.default_rng(5), 6)
    router = Router(store, ModiPick(t_threshold=20.0), queue_aware=True)
    reqs = [InferenceRequest(t_sla_ms=300.0, t_input_ms=10.0, rid=i)
            for i in range(8)]
    waits = {f"m{i}": 5.0 * i for i in range(6)}
    decs = router.route_batch(reqs, np.random.default_rng(0),
                              w_queue_map=waits)
    # all decisions report the wait of their chosen model from the ONE
    # snapshot — no charges appear anywhere
    for d in decs:
        assert d.admitted
        assert d.budget.w_queue_ms == waits[d.variant]


# ----------------------------------------------------------------------
# honest admission under bursts (the bench regression)
# ----------------------------------------------------------------------

def test_admission_sheds_honestly_under_burst():
    """The regression the throughput bench exposed: under 400-wide
    bursts on the per-model topology, snapshot routing reports shed=0
    while attainment collapses (every request is judged against the
    same idle-looking pool); charged routing both sheds the requests no
    model can serve in budget AND recovers attainment for the rest."""
    def run(charge):
        sim = ServingSimulator(
            TABLE2, NetworkModel(50.0, 0.0), per_model_replicas(TABLE2),
            seed=3, queue_aware=True, admission=SlaAwareAdmission(),
            charge_batches=charge)
        r = sim.run(ModiPick(t_threshold=20.0), 250.0, 800,
                    arrivals=_burst(800, 400, 2000.0))
        return r

    snap = run(False)
    assert snap.n_rejected == 0          # blind to intra-batch load
    assert snap.sla_attainment < 0.1     # ... and it collapses
    charged = run(True)
    assert charged.n_rejected > 0        # shedding is honest now
    assert charged.sla_attainment > 0.4
    assert charged.sla_attainment > 10 * snap.sla_attainment


def test_burst_attainment_recovers_without_admission():
    """At sustainable burst load (4 replicas/model, 200-wide bursts —
    the bench's ``batched`` config at toy scale) charging alone
    recovers attainment to the singleton regime; the snapshot ablation
    stays degenerate."""
    def run(charge):
        sim = ServingSimulator(
            TABLE2, NetworkModel(50.0, 0.0),
            per_model_replicas(TABLE2, replicas_per_model=4),
            seed=3, queue_aware=True, charge_batches=charge)
        return sim.run(ModiPick(t_threshold=20.0), 250.0, 2000,
                       arrivals=_burst(2000, 200, 400.0))

    assert run(False).sla_attainment < 0.3
    assert run(True).sla_attainment > 0.9


# ----------------------------------------------------------------------
# the array-native entry point
# ----------------------------------------------------------------------

def test_route_batch_arrays_column_contract():
    """Columns out of ``route_batch_arrays`` mirror the object path's
    decisions field for field (same RNG seed → same picks)."""
    store = _random_store(np.random.default_rng(11), 5)
    mk = lambda: Router(store, ModiPick(t_threshold=20.0),
                        admission=SlaAwareAdmission(), queue_aware=True)
    reqs = [InferenceRequest(t_sla_ms=float(s), t_input_ms=5.0, rid=i)
            for i, s in enumerate((300.0, 90.0, 250.0, 30.0))]
    waits = {f"m{i}": 12.5 * i for i in range(5)}
    decs = mk().route_batch(reqs, np.random.default_rng(7),
                            w_queue_map=dict(waits), charge=True)
    res = mk().route_batch_arrays(
        [r.t_sla_ms for r in reqs], [r.t_input_ms for r in reqs],
        np.random.default_rng(7), w_queue_map=dict(waits), charge=True)
    assert isinstance(res, BatchDecisions)
    assert len(res) == len(reqs)
    for i, d in enumerate(decs):
        assert bool(res.admitted[i]) == d.admitted
        if d.admitted:
            assert res.names[int(res.model_idx[i])] == d.variant
            assert bool(res.fallback[i]) == d.fallback
        else:
            assert int(res.model_idx[i]) == -1
            assert res.reason_of(i) == d.reject_reason
        assert float(res.w_queue_ms[i]) == d.budget.w_queue_ms
        # per-model pseudo charging exposes no real replica indices
        assert int(res.replica_idx[i]) == -1


def test_batch_of_one_is_bit_identical_scalar_path():
    """Charging must not perturb a singleton batch: same picks and RNG
    consumption as ``route`` whatever the ``charge`` flag says (there
    is nothing within the batch to charge against)."""
    store_a = _random_store(np.random.default_rng(3), 6)
    store_b = _random_store(np.random.default_rng(3), 6)
    pol = ModiPick(t_threshold=20.0)
    req = InferenceRequest(t_sla_ms=240.0, t_input_ms=20.0)
    waits = {f"m{i}": 3.0 * i for i in range(6)}
    ra, rb = np.random.default_rng(2), np.random.default_rng(2)
    d1 = Router(store_a, pol, queue_aware=True).route_batch(
        [req], ra, w_queue_map=waits, charge=True)[0]
    d2 = Router(store_b, pol, queue_aware=True).route(
        req, rb, w_queue_fn=waits.__getitem__)
    assert (d1.variant, d1.fallback) == (d2.variant, d2.fallback)
    assert d1.budget.w_queue_ms == d2.budget.w_queue_ms
    assert ra.random() == rb.random()


def test_route_one_matches_batch_of_one():
    """The engine's scalar fast path (``route_one`` tuple out) is
    pick-for-pick, float-for-float, draw-for-draw and counter-for-
    counter the same as a batch of one through the array entry point."""
    store_a = _random_store(np.random.default_rng(8), 5)
    store_b = _random_store(np.random.default_rng(8), 5)
    pol = ModiPick(t_threshold=20.0)
    router_a = Router(store_a, pol, admission=SlaAwareAdmission(),
                      queue_aware=True)
    router_b = Router(store_b, pol, admission=SlaAwareAdmission(),
                      queue_aware=True)
    ra, rb = np.random.default_rng(4), np.random.default_rng(4)
    waits = {f"m{i}": 4.0 * i for i in range(5)}
    for k in range(12):
        sla = 360.0 - 31.0 * k          # last rows: budget ≤ 0 → shed
        mid, fb, w_q, reason = router_a.route_one(
            sla, 10.0, ra, w_queue_map=waits)
        res = router_b.route_batch_arrays(
            [sla], [10.0], rb, w_queue_map=dict(waits))
        assert mid == int(res.model_idx[0])
        assert bool(res.admitted[0]) == (mid >= 0)
        if mid >= 0:
            assert fb == bool(res.fallback[0])
        else:
            assert reason == res.reason_of(0)
        assert w_q == float(res.w_queue_ms[0])
    assert router_a.stats() == router_b.stats()
    assert router_a.stats()["n_shed"] > 0
    assert ra.random() == rb.random()


def test_charged_waits_ledger():
    """ChargedWaits unit semantics: min-over-candidates waits,
    pool-order tie-break, μ/speed charge amounts."""
    st = ChargedWaits(rep_wait=[10.0, 0.0, 5.0],
                      cand=[[0, 1], [1, 2]],
                      speed=[1.0, 2.0, 1.0],
                      mu=[30.0, 8.0],
                      names=("a", "b"))
    assert st.model_waits().tolist() == [0.0, 0.0]
    assert st.charge(0) == 1             # least-loaded of {0, 1}
    assert st.rep_wait[1] == 15.0        # 30 / speed 2
    assert st.wait_of(0) == 10.0
    assert st.as_map() == {"a": 10.0, "b": 5.0}
    assert st.charge(1) == 2             # replica 2 now least of {1, 2}
    assert st.rep_wait[2] == 13.0
    with pytest.raises(ValueError, match="no replica serves"):
        ChargedWaits([0.0], [[]], [1.0], [1.0], ("a",))


# ----------------------------------------------------------------------
# the jax lax.scan charged kernel
# ----------------------------------------------------------------------

def _one_model_store(mu=50.0):
    p = ModelProfile(name="m0", accuracy=0.9)
    p.mu, p.var, p.n_obs = mu, 0.0, 100
    return ProfileStore([p])


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_charged_scan_deterministic_single_model(backend):
    """One model, two replicas, fixed budgets: the charged pass (numpy
    sequential loop AND the forced-jax ``lax.scan`` kernel) must admit
    exactly while ``min-replica wait < budget`` and alternate replicas
    — a closed-form trajectory with no sampling freedom, so both
    backends are exactly comparable."""
    store = _one_model_store(50.0)
    router = Router(store, ModiPick(t_threshold=20.0),
                    admission=SlaAwareAdmission(), queue_aware=True,
                    trace_detail=False, backend=backend)
    state = ChargedWaits(rep_wait=[0.0, 0.0], cand=[[0, 1]],
                         speed=[1.0, 1.0], mu=[50.0], names=("m0",))
    B = 12
    res = router.route_batch_arrays(
        np.full(B, 200.0), np.zeros(B), np.random.default_rng(0),
        charged=state, charge=True)
    # admits while min(waits) < 200: pairs of picks raise the min by 50
    # → 8 admitted (min wait 0,0,50,50,100,100,150,150), then shed.
    assert res.admitted.tolist() == [True] * 8 + [False] * 4
    assert res.model_idx[:8].tolist() == [0] * 8
    assert res.replica_idx[:8].tolist() == [0, 1] * 4
    assert res.w_queue_ms[:8].tolist() == [0.0, 0.0, 50.0, 50.0,
                                           100.0, 100.0, 150.0, 150.0]
    assert res.w_queue_ms[8:].tolist() == [200.0] * 4
    assert all("budget" in res.reason_of(i) for i in range(8, 12))
    s = router.stats()
    assert s["n_admitted"] == 8 and s["n_shed"] == 4


def test_charged_scan_multimodel_spreads_and_places():
    """Forced-jax charged scan over a real zoo: picks are valid pool
    indices, every admitted request lands on a replica that serves its
    model, and the burst spreads over more than one model (the whole
    point of charging)."""
    from repro.core.zoo import make_store
    store = make_store(TABLE2)
    router = Router(store, ModiPick(t_threshold=20.0), queue_aware=True,
                    trace_detail=False, backend="jax")
    tab = store.table()
    n = len(tab.names)
    # per-model topology, 2 replicas each: replica 2*m and 2*m+1 serve m
    state = ChargedWaits(rep_wait=[0.0] * (2 * n),
                         cand=[[2 * m, 2 * m + 1] for m in range(n)],
                         speed=[1.0] * (2 * n),
                         mu=tab.mu, names=tab.names)
    B = 256
    res = router.route_batch_arrays(
        np.full(B, 250.0), np.full(B, 50.0), np.random.default_rng(1),
        charged=state, charge=True)
    assert res.admitted.all()
    picks = res.model_idx
    assert ((0 <= picks) & (picks < n)).all()
    assert len(np.unique(picks)) > 1
    reps = res.replica_idx
    assert ((reps == 2 * picks) | (reps == 2 * picks + 1)).all()
    # the ledger really was charged: total charged mass == Σ μ(pick)
    expect = sum(float(tab.mu[m]) for m in picks)
    assert np.sum(state.rep_wait) == 0.0  # jax path never mutates state
    # and the same call on numpy charges the caller's ledger in place
    router_np = Router(store, ModiPick(t_threshold=20.0), queue_aware=True,
                       trace_detail=False, backend="numpy")
    state2 = ChargedWaits(rep_wait=[0.0] * (2 * n),
                          cand=[[2 * m, 2 * m + 1] for m in range(n)],
                          speed=[1.0] * (2 * n),
                          mu=tab.mu, names=tab.names)
    res2 = router_np.route_batch_arrays(
        np.full(B, 250.0), np.full(B, 50.0), np.random.default_rng(1),
        charged=state2, charge=True)
    got = float(np.sum(state2.rep_wait))
    want = sum(float(tab.mu[m]) for m in res2.model_idx)
    assert got == pytest.approx(want, rel=1e-12)
