"""Shared test plumbing.

Vendored property-sweep shim: the suite was written against
``hypothesis``, which is not available in the pinned container.  This
module exposes the tiny subset the tests use (``given``, ``settings``,
``st.floats/integers/lists/tuples/sampled_from``) backed by a seeded
numpy RNG: ``@given`` expands the test into ``max_examples`` randomized
calls with a per-test deterministic seed.  When the real ``hypothesis``
is importable it is re-exported unchanged, so nothing here diverges from
upstream semantics on machines that have it.

Test modules import via ``from conftest import given, settings, st``.

``PROPTEST_MAX_EXAMPLES`` (env) caps the per-test example count for
quick local iteration, e.g. ``PROPTEST_MAX_EXAMPLES=5 pytest -q``.
"""
import os
import zlib

try:  # pragma: no cover - container has no hypothesis; keep parity if added
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mimics ``hypothesis.strategies`` module name
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(
                lambda rng: tuple(e.example(rng) for e in elements))

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def sweep():
                # Resolve max_examples at call time so @settings works in
                # either decorator order (above or below @given).
                n = getattr(sweep, "_prop_max_examples",
                            getattr(fn, "_prop_max_examples",
                                    _DEFAULT_EXAMPLES))
                cap = os.environ.get("PROPTEST_MAX_EXAMPLES")
                if cap:
                    n = min(n, int(cap))
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    values = [s.example(rng) for s in strategies]
                    try:
                        fn(*values)
                    except Exception as e:
                        # plain Exception only: pytest.skip/xfail and
                        # KeyboardInterrupt must propagate untouched
                        raise AssertionError(
                            f"falsifying example (#{i + 1}/{n}) for "
                            f"{fn.__name__}: args={values!r}") from e

            sweep.__name__ = fn.__name__
            sweep.__doc__ = fn.__doc__
            sweep.__module__ = fn.__module__
            return sweep
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
