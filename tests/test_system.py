"""End-to-end behaviour tests: the paper's claims, reproduced.

These run the closed-loop simulator seeded with the paper's empirical
measurements (Table 2 zoo + campus-WiFi network) and assert the headline
results of §4; plus a live serving e2e with real JAX model executions.
"""
import numpy as np
import pytest

from repro.core.netmodel import NetworkModel, campus_wifi
from repro.core.policy import (DynamicGreedy, ModiPick, PureRandom,
                               RelatedAccurate, RelatedRandom, StaticGreedy)
from repro.core.simulate import Simulator
from repro.core.zoo import NASNET_FICTIONAL, TABLE2

N_REQ = 2000  # enough for stable estimates, fast in CI


@pytest.fixture(scope="module")
def sim():
    return Simulator(entries=TABLE2, network=campus_wifi(), seed=1)


def test_modipick_beats_static_greedy_attainment(sim):
    """§4.2: ModiPick vastly improves SLA attainment at mid SLAs while
    static greedy keeps violating until ~250ms."""
    for sla in (115.0, 150.0, 200.0):
        mp = sim.run(ModiPick(t_threshold=20.0), sla, N_REQ)
        sg = sim.run(StaticGreedy(sla), sla, N_REQ)
        assert mp.sla_attainment > sg.sla_attainment + 0.2, (
            sla, mp.sla_attainment, sg.sla_attainment)


def test_modipick_latency_reduction_up_to_42pct(sim):
    """§4.2: 'up to 42% lower end-to-end latency'."""
    mp = sim.run(ModiPick(t_threshold=20.0), 115.0, N_REQ)
    sg = sim.run(StaticGreedy(115.0), 115.0, N_REQ)
    reduction = 1.0 - mp.mean_latency / sg.mean_latency
    assert reduction > 0.30, reduction


def test_modipick_accuracy_converges_at_high_sla(sim):
    """§4.1/4.2: accuracy climbs with SLA and approaches the best model."""
    accs = [sim.run(ModiPick(t_threshold=20.0), s, N_REQ).mean_accuracy
            for s in (115.0, 200.0, 300.0)]
    assert accs[0] < accs[1] < accs[2]
    assert accs[2] > 0.80  # near NasNet-Large's 82.6%


def test_model_usage_diversifies_with_sla(sim):
    """§4.2 Fig 6b: more accurate models enter the mix as SLA grows."""
    low = sim.run(ModiPick(t_threshold=20.0), 110.0, N_REQ).model_usage
    high = sim.run(ModiPick(t_threshold=20.0), 300.0, N_REQ).model_usage
    assert high.get("NasNet-Large", 0.0) > low.get("NasNet-Large", 0.0)
    assert low.get("MobileNetV1-0.25", 0.0) > high.get("MobileNetV1-0.25", 0.0)


def test_cv_robustness():
    """§4.3: at a reasonable SLA, attainment stays high across network CV."""
    for cv in (0.0, 0.5, 1.0):
        s = Simulator(entries=TABLE2,
                      network=NetworkModel.from_cv(50.0, cv), seed=2)
        r = s.run(ModiPick(t_threshold=20.0), 250.0, N_REQ)
        assert r.sla_attainment > 0.75, (cv, r.sla_attainment)
        assert r.mean_accuracy > 0.70


def test_fictional_model_avoided_but_explored():
    """§4.4 Fig 9 (γ=4 variant, see EXPERIMENTS.md §Fig9 reproduction
    note): ModiPick nearly matches related-accurate accuracy by giving
    NasNet-Fictional low (but non-zero) probability; related-random cannot
    tell the two apart and degrades."""
    entries = TABLE2 + [NASNET_FICTIONAL]
    s = Simulator(entries=entries,
                  network=NetworkModel(mean_ms=50.0, std_ms=25.0), seed=3)
    mp = s.run(ModiPick(t_threshold=20.0, gamma=4.0), 250.0, N_REQ)
    rr = s.run(RelatedRandom(t_threshold=20.0), 250.0, N_REQ)
    ra = s.run(RelatedAccurate(t_threshold=20.0), 250.0, N_REQ)
    pr = s.run(PureRandom(), 250.0, N_REQ)
    assert mp.mean_accuracy > rr.mean_accuracy + 0.02
    assert abs(mp.mean_accuracy - ra.mean_accuracy) < 0.05
    assert mp.mean_accuracy > pr.mean_accuracy
    fict = mp.model_usage.get("NasNet-Fictional", 0.0)
    assert 0.0 < fict < 0.25  # avoided, yet still explored


def test_fictional_eq3_literal_reproduction_gap():
    """Documented gap: Eq. 3 as printed splits probability ∝ accuracy, so
    the fictional model (A=0.50 vs NasNet-Large 0.826) is picked ≈3/8 of
    the time when only those two are eligible — NOT the paper's 'low
    probability'.  This test pins the literal behaviour."""
    entries = TABLE2 + [NASNET_FICTIONAL]
    s = Simulator(entries=entries,
                  network=NetworkModel(mean_ms=50.0, std_ms=25.0), seed=3)
    mp = s.run(ModiPick(t_threshold=20.0, gamma=1.0), 250.0, N_REQ)
    fict = mp.model_usage.get("NasNet-Fictional", 0.0)
    assert 0.2 < fict < 0.5


def test_pure_random_flat_latency():
    """§4.4: pure random ignores the SLA entirely."""
    s = Simulator(entries=TABLE2,
                  network=NetworkModel(mean_ms=50.0, std_ms=25.0), seed=4)
    lats = [s.run(PureRandom(), sla, 1000).mean_latency
            for sla in (100.0, 200.0, 300.0)]
    assert max(lats) - min(lats) < 10.0


def test_exploration_recovers_from_latency_spike():
    """The explore/exploit motivation (§3.3.2): despite transient spikes
    polluting profiles, accurate slow models keep serving most requests."""
    s = Simulator(entries=TABLE2, network=NetworkModel(50.0, 10.0),
                  seed=5, spike_prob=0.01, spike_mult=8.0)
    r = s.run(ModiPick(t_threshold=25.0), 280.0, 4000)
    # σ-aware routing goes defensive under spikes but keeps serving
    # accurate mid-tier models and holds the SLA.
    heavy = sum(v for k, v in r.model_usage.items()
                if k in ("NasNet-Large", "InceptionV4", "InceptionV3",
                         "InceptionResNetV2"))
    assert heavy > 0.35
    assert r.mean_accuracy > 0.70
    assert r.sla_attainment > 0.9


def test_dynamic_greedy_between_static_and_modipick(sim):
    """§3.2: dynamic greedy fixes the network-blindness of static greedy;
    ModiPick matches its attainment while keeping exploration."""
    sla = 150.0
    dg = sim.run(DynamicGreedy(), sla, N_REQ)
    sg = sim.run(StaticGreedy(sla), sla, N_REQ)
    mp = sim.run(ModiPick(t_threshold=20.0), sla, N_REQ)
    assert dg.sla_attainment > sg.sla_attainment
    assert abs(mp.sla_attainment - dg.sla_attainment) < 0.05


# ----------------------------------------------------------------------
def test_live_serving_e2e():
    """Real JAX pool (width-scaled qwen2 family) behind ModiPick: the
    router must meet SLAs with real measured model latencies."""
    from repro.configs.registry import get_config
    from repro.serving.executor import PoolExecutor
    from repro.serving.pool import scaled_family

    variants = scaled_family(get_config("qwen2-1.5b"), widths=(0.5, 1.0),
                             cache_len=96)
    tokens = np.random.default_rng(0).integers(0, 500, (2, 64), dtype=np.int32)
    net = NetworkModel(mean_ms=15.0, std_ms=8.0)
    ex = PoolExecutor(variants, net, ModiPick(t_threshold=25.0), seed=3)
    ex.warm_up(tokens)
    for _ in range(30):
        ex.execute(tokens, t_sla=150.0)
    s = ex.summary()
    assert s["sla_attainment"] > 0.6
    assert len(s["usage"]) >= 1
