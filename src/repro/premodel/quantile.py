"""Streaming latency quantiles and the tail-presenting profile store.

Routing on mean latency is optimistic exactly when it matters: a model
whose μ fits the budget but whose p95 does not will miss tail-tight
SLAs on every spike.  MDInference's answer is to route on a predicted
*duration quantile*.  Two pieces implement that here:

- :class:`P2Quantile` — the Jain & Chlamtac (1985) P² algorithm: a
  constant-memory streaming estimate of one quantile from five markers,
  no sample buffer.  Exact (order-statistic) for the first five
  observations, piecewise-parabolic afterwards.
- :class:`QuantileProfileStore` — a :class:`~repro.core.profiles.
  ProfileStore` whose *presented* table μ is the tracked latency
  quantile instead of the EWMA mean.  Everything downstream — Eq. 2
  eligibility, the stage-2 window, ``T_budget`` checks,
  ``SlaAwareAdmission``'s ``W_queue + μ < T_budget`` viability test —
  reads ``table.mu`` and therefore becomes tail-aware with zero Router
  changes.  The underlying :class:`~repro.core.profiles.ModelProfile`
  EWMAs keep tracking the true mean (engine load charging and queue
  estimates read ``profiles[m].mu`` directly and must stay mean-based).

Until a model has ``min_obs`` accepted observations the presented value
falls back to the Gaussian approximation ``μ + z_q·σ`` from the
(possibly warm-seeded) EWMA state, so cold models are judged
pessimistically but sanely rather than on a five-sample order
statistic.
"""
from __future__ import annotations

import statistics
from typing import Dict, Iterable, Optional

from repro.core.profiles import (ModelProfile, ProfileStore, ProfileTable,
                                 _valid_sample)


class P2Quantile:
    """Jain & Chlamtac's P² streaming estimator for one quantile ``q``.

    Five markers track (min, q/2, q, (1+q)/2, max); desired positions
    advance by (0, q/2, q, (1+q)/2, 1) per observation and interior
    markers are nudged toward them with a piecewise-parabolic (fallback
    linear) height adjustment.  O(1) memory, O(1) per observation.
    """

    __slots__ = ("q", "n", "_heights", "_pos", "_desired", "_incr")

    def __init__(self, q: float) -> None:
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = float(q)
        self.n = 0
        self._heights: list = []          # marker heights (sorted)
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self._desired = [1.0, 1.0 + 2 * q, 1.0 + 4 * q, 3.0 + 2 * q, 5.0]
        self._incr = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    def observe(self, x: float) -> None:
        x = float(x)
        self.n += 1
        h = self._heights
        if self.n <= 5:
            h.append(x)
            h.sort()
            return
        # Locate the marker cell containing x; extremes clamp to it.
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while x >= h[k + 1]:
                k += 1
        pos = self._pos
        for i in range(k + 1, 5):
            pos[i] += 1.0
        des = self._desired
        inc = self._incr
        for i in range(5):
            des[i] += inc[i]
        # Nudge the three interior markers toward their desired spots.
        for i in (1, 2, 3):
            d = des[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or \
               (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                d = 1.0 if d >= 0 else -1.0
                hp = self._parabolic(i, d)
                if not h[i - 1] < hp < h[i + 1]:
                    hp = self._linear(i, d)
                h[i] = hp
                pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        h, pos = self._heights, self._pos
        return h[i] + d / (pos[i + 1] - pos[i - 1]) * (
            (pos[i] - pos[i - 1] + d) * (h[i + 1] - h[i]) /
            (pos[i + 1] - pos[i]) +
            (pos[i + 1] - pos[i] - d) * (h[i] - h[i - 1]) /
            (pos[i] - pos[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        h, pos = self._heights, self._pos
        j = i + (1 if d > 0 else -1)
        return h[i] + d * (h[j] - h[i]) / (pos[j] - pos[i])

    def value(self) -> Optional[float]:
        """Current estimate, or ``None`` before any observation.

        With five or fewer samples this is the nearest-rank order
        statistic of what has been seen."""
        h = self._heights
        if not h:
            return None
        if self.n <= 5:
            idx = min(len(h) - 1, max(0, round(self.q * (len(h) - 1))))
            return h[int(idx)]
        return h[2]


def z_score(q: float) -> float:
    """Standard-normal inverse CDF at ``q`` — the Gaussian ``μ + z·σ``
    fallback while a quantile tracker is cold (stdlib, no scipy)."""
    return statistics.NormalDist().inv_cdf(q)


class QuantileProfileStore(ProfileStore):
    """A ProfileStore that *presents* per-model latency as a quantile.

    ``observe`` feeds both the inherited EWMA (``ModelProfile.mu`` stays
    the true mean) and a per-model :class:`P2Quantile`.  The presented
    table carries the tracked quantile in the μ column and 0 in the σ
    column — the quantile already *is* the pessimism Eq. 2 adds via
    μ+σ — so eligibility becomes ``q_lat < T_U`` and SLA-aware
    admission's viability test becomes ``W_queue + q_lat < T_budget``:
    exactly the tail-SLA check, with no Router changes.
    """

    def __init__(self, models: Iterable[ModelProfile], *, q: float = 0.95,
                 min_obs: int = 8, alpha: float = 0.1,
                 cold_age: int = 500) -> None:
        super().__init__(models, alpha=alpha, cold_age=cold_age)
        if not 0.0 < q < 1.0:
            raise ValueError(f"latency quantile must be in (0, 1), got {q}")
        self.q = float(q)
        self.min_obs = int(min_obs)
        self._z = z_score(self.q)
        self.trackers: Dict[str, P2Quantile] = {
            name: P2Quantile(self.q) for name in self.profiles}

    def presented_mu(self, name: str) -> float:
        """The latency this store routes on for ``name``: the tracked
        quantile when warm, ``μ + z_q·σ`` from the EWMA otherwise."""
        tr = self.trackers[name]
        if tr.n >= self.min_obs:
            v = tr.value()
            if v is not None:
                return float(v)
        p = self.profiles[name]
        return float(p.mu + self._z * p.sigma)

    def observe(self, name: str, latency_ms: float) -> None:
        if name in self.trackers and _valid_sample(latency_ms):
            self.trackers[name].observe(float(latency_ms))
        super().observe(name, latency_ms)

    # -- presentation ---------------------------------------------------
    def _refresh(self, name: str, p: ModelProfile) -> None:
        t = self._table
        if t is not None:
            t.refresh(t.index[name], self.presented_mu(name), 0.0,
                      p.queue_mu)

    def table(self) -> ProfileTable:
        if self._table is None:
            t = ProfileTable.from_store(self)
            for i, name in enumerate(t.names):
                t.refresh(i, self.presented_mu(name), 0.0,
                          self.profiles[name].queue_mu)
            self._table = t
        return self._table
