"""The premodel: cheap request features → input-class id.

Taylor et al.'s premodel is a tiny model *in front of* model selection:
from features that cost microseconds to compute (input size, resolution
bucket, modality flags) it predicts which class of input is arriving,
and the router then selects against that class's conditional profiles.
Two implementations:

- :class:`NearestCentroidClassifier` — the online learner.  Sequential
  (MacQueen-style) k-means: the first ``n_classes`` observations seed
  the centroids, every later observation moves its nearest centroid
  toward it with a count-decaying learning rate.  Unsupervised on
  purpose: the classifier's job is to partition feature space into
  stable, self-consistent class ids; the
  :class:`~repro.premodel.conditional.ConditionalProfileStore` then
  *learns what each partition means* from observed latency outcomes.
  No ground-truth labels are ever consumed, so the premodel deploys on
  workloads where the easy/hard structure is latent.
- :class:`OracleClassifier` — the frozen ablation: nearest *true*
  feature center, known a priori, never updated.  The gap between the
  two isolates how much of the premodel win survives having to discover
  the classes online.

Both are deterministic given the feature stream (no internal RNG), so
premodel runs stay reproducible and the RNG-neutrality discipline of
the engine is preserved.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np


class NearestCentroidClassifier:
    """Online nearest-centroid (sequential k-means) input classifier.

    ``classify`` returns the nearest centroid's id (0 until the first
    observation seeds one); ``update`` folds the feature vector into
    the model.  Seeding takes the first ``n_classes`` observations
    verbatim — if two land in the same latent cluster, the
    count-decaying mean update lets the slightly-closer duplicate
    capture the unclaimed cluster and converge onto it.
    """

    def __init__(self, n_classes: int, n_features: int, *,
                 min_lr: float = 0.02) -> None:
        if n_classes < 1:
            raise ValueError("n_classes must be >= 1")
        if n_features < 1:
            raise ValueError("n_features must be >= 1")
        self.k = int(n_classes)
        self.d = int(n_features)
        self.min_lr = float(min_lr)
        self.centroids = np.zeros((self.k, self.d), dtype=np.float64)
        self.counts = np.zeros(self.k, dtype=np.int64)
        self.n_seeded = 0
        self.n_updates = 0

    def classify(self, features: Sequence[float]) -> int:
        if self.n_seeded == 0:
            return 0
        x = np.asarray(features, dtype=np.float64)
        d2 = ((self.centroids[:self.n_seeded] - x) ** 2).sum(axis=1)
        return int(np.argmin(d2))

    def update(self, features: Sequence[float]) -> int:
        """Fold one observed feature vector in; returns the class id it
        was assigned to (seeded centroids claim their own slot)."""
        x = np.asarray(features, dtype=np.float64)
        self.n_updates += 1
        if self.n_seeded < self.k:
            c = self.n_seeded
            self.centroids[c] = x
            self.counts[c] = 1
            self.n_seeded += 1
            return c
        c = self.classify(x)
        self.counts[c] += 1
        lr = max(1.0 / float(self.counts[c]), self.min_lr)
        self.centroids[c] += lr * (x - self.centroids[c])
        return c


class OracleClassifier:
    """Frozen nearest-true-center classifier — the premodel ablation.

    Knows the scenario's ground-truth feature centers and never learns;
    the online classifier is measured against it."""

    def __init__(self, centers: Iterable[Sequence[float]]) -> None:
        self.centers = np.asarray(list(centers), dtype=np.float64)
        if self.centers.ndim != 2 or len(self.centers) < 1:
            raise ValueError("centers must be a non-empty (K, d) array")
        self.k = len(self.centers)
        self.d = self.centers.shape[1]

    def classify(self, features: Sequence[float]) -> int:
        x = np.asarray(features, dtype=np.float64)
        return int(np.argmin(((self.centers - x) ** 2).sum(axis=1)))

    def update(self, features: Sequence[float]) -> int:
        return self.classify(features)


def make_classifier(kind: str, n_classes: int, n_features: int,
                    centers: Optional[Iterable[Sequence[float]]] = None):
    """``"centroid"`` → online learner, ``"oracle"`` → frozen ablation
    (requires the true ``centers``), ``"none"`` → ``None``."""
    if kind == "none":
        return None
    if kind == "centroid":
        return NearestCentroidClassifier(n_classes, n_features)
    if kind == "oracle":
        if centers is None:
            raise ValueError("oracle classifier needs the true feature "
                             "centers")
        return OracleClassifier(centers)
    raise ValueError(f"unknown premodel kind {kind!r} "
                     "(expected none|centroid|oracle)")
