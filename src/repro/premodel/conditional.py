"""Per-input-class conditional profiles with hierarchical shrinkage.

One unconditional latency profile per model is the wrong granularity
when the workload mixes easy and hard inputs: the mixture's μ and σ
describe *neither* class (the bimodal spread inflates σ until nothing
accurate is ever eligible).  :class:`ConditionalProfileStore` keeps K
per-class profile sets over the shared zoo alongside the pooled
(unconditional) set it inherits, and *presents* whichever the active
input class asks for:

- **Hierarchical shrinkage.**  A class with few observations should not
  route on noise.  The presented per-class estimate is the classic
  empirical-Bayes blend toward the pooled estimate,
  ``w = n_k / (n_k + tau)``; ``μ̂_k = w·μ_k + (1−w)·μ_pool`` (and the
  same for the variance).  A cold class (n_k = 0) is *exactly* the
  pooled, warm-seeded profile; a warm class converges to its own
  measured truth.
- **Active-class cursor.**  ``set_class(k)`` flips which table
  ``table()`` returns; −1 (the default, never set on premodel-off
  paths) returns the pooled table, so every existing consumer — the
  Router's scalar core, ``shifted_store`` views, admission — works
  unchanged and premodel-off runs are bit-identical to history.
- **Stacked device snapshot.**  ``stacked_pool()`` freezes all K class
  tables into ``(K × npad)`` device operands (the fleet-stacking trick
  from ``fleet.device.StackedPools``), so a premodel batch is judged in
  ONE device call: per-request class ids gather their class's pool row
  inside the fused jit (``kernels.policy_select.select_classed``).
- **Tail composition.**  With ``q`` set, per-(class, model)
  :class:`~repro.premodel.quantile.P2Quantile` trackers present the
  class-conditional latency quantile (falling back to the pooled
  tracker, then to the Gaussian ``μ̂ + z_q·σ̂`` of the shrunk estimate)
  — conditional and tail-aware routing compose.

Pooled telemetry keeps flowing no matter the class: ``observe_class``
feeds both the class profile and the pooled one, probes and queue
telemetry feed pooled only, and the engine's load charging keeps
reading the pooled EWMA means.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.profiles import (ModelProfile, ProfileStore, ProfileTable,
                                 _valid_sample)
from repro.premodel.quantile import P2Quantile, z_score


class StackedClassPools:
    """(K, npad) device operands over the class tables — the premodel
    analogue of ``fleet.device.StackedPools``.  Accuracy (and with it
    the stage-1 rank) never varies by class, so ``acc``/``rank`` stay
    (npad,) and broadcast inside the kernel."""

    __slots__ = ("k", "n", "npad", "mu", "sigma", "acc", "rank")

    def __init__(self, tables: List[ProfileTable]):
        import jax.numpy as jnp
        pools = [t.device_pool() for t in tables]
        self.k = len(pools)
        self.n = pools[0].n
        self.npad = pools[0].npad
        self.mu = jnp.stack([p.mu for p in pools])
        self.sigma = jnp.stack([p.sigma for p in pools])
        self.acc = pools[0].acc
        self.rank = pools[0].rank


class ConditionalProfileStore(ProfileStore):
    """K per-class profile sets + the pooled set, behind one store."""

    def __init__(self, models: Iterable[ModelProfile], *, n_classes: int,
                 tau: float = 16.0, q: Optional[float] = None,
                 min_obs: int = 8, alpha: float = 0.1,
                 cold_age: int = 500) -> None:
        super().__init__(models, alpha=alpha, cold_age=cold_age)
        if n_classes < 1:
            raise ValueError("n_classes must be >= 1")
        if tau < 0.0:
            raise ValueError("shrinkage tau must be >= 0")
        self.n_classes = int(n_classes)
        self.tau = float(tau)
        self.q = float(q) if q is not None else None
        self.min_obs = int(min_obs)
        self._z = z_score(self.q) if self.q is not None else 0.0
        self.class_profiles: List[Dict[str, ModelProfile]] = [
            {name: ModelProfile(name=name, accuracy=p.accuracy)
             for name, p in self.profiles.items()}
            for _ in range(self.n_classes)]
        if self.q is not None:
            self.pool_trackers: Optional[Dict[str, P2Quantile]] = {
                name: P2Quantile(self.q) for name in self.profiles}
            self.class_trackers: Optional[List[Dict[str, P2Quantile]]] = [
                {name: P2Quantile(self.q) for name in self.profiles}
                for _ in range(self.n_classes)]
        else:
            self.pool_trackers = None
            self.class_trackers = None
        self.active = -1
        self._class_tables: List[Optional[ProfileTable]] = (
            [None] * self.n_classes)
        self._class_ver = [-1] * self.n_classes
        self._stacked: Optional[StackedClassPools] = None
        self._stacked_ver = -1

    # -- the cursor -----------------------------------------------------
    def set_class(self, cls: int) -> None:
        """Select which class's table :meth:`table` presents; −1 is the
        pooled (historical) view.  Premodel-off paths never call this,
        which is what keeps them bit-identical."""
        if not -1 <= cls < self.n_classes:
            raise ValueError(f"class id {cls} out of range "
                             f"[-1, {self.n_classes})")
        self.active = int(cls)

    # -- estimates ------------------------------------------------------
    def shrunk(self, cls: int, name: str) -> Tuple[float, float]:
        """Shrinkage-blended ``(μ, var)`` for (class, model):
        ``w = n_k/(n_k + tau)`` toward the pooled estimate."""
        pp = self.profiles[name]
        cp = self.class_profiles[cls][name]
        if self.tau == 0.0:
            w = 1.0 if cp.n_obs > 0 else 0.0
        else:
            w = cp.n_obs / (cp.n_obs + self.tau)
        return (w * cp.mu + (1.0 - w) * pp.mu,
                w * cp.var + (1.0 - w) * pp.var)

    def presented_class(self, cls: int, name: str) -> Tuple[float, float]:
        """The ``(μ, σ)`` the class-``cls`` table carries for ``name``.
        Mean mode: the shrunk estimate.  Quantile mode: the warmest
        available tracker (class, then pooled), else the Gaussian
        ``μ̂ + z_q·σ̂`` of the shrunk estimate — always with σ = 0 (the
        quantile already carries the tail pessimism)."""
        mu, var = self.shrunk(cls, name)
        if self.q is None:
            return mu, math.sqrt(max(var, 0.0))
        tr = self.class_trackers[cls][name]
        if tr.n >= self.min_obs:
            v = tr.value()
            if v is not None:
                return float(v), 0.0
        ptr = self.pool_trackers[name]
        if ptr.n >= self.min_obs:
            v = ptr.value()
            if v is not None:
                return float(v), 0.0
        return mu + self._z * math.sqrt(max(var, 0.0)), 0.0

    def _pooled_presented(self, name: str) -> float:
        """Quantile-mode pooled μ (mirrors ``QuantileProfileStore``)."""
        ptr = self.pool_trackers[name]
        if ptr.n >= self.min_obs:
            v = ptr.value()
            if v is not None:
                return float(v)
        p = self.profiles[name]
        return float(p.mu + self._z * p.sigma)

    # -- telemetry ------------------------------------------------------
    def observe(self, name: str, latency_ms: float) -> None:
        """Pooled-only observation (probes, class-unattributed samples)."""
        if self.pool_trackers is not None and name in self.pool_trackers \
                and _valid_sample(latency_ms):
            self.pool_trackers[name].observe(float(latency_ms))
        super().observe(name, latency_ms)

    def observe_class(self, cls: int, name: str, latency_ms: float) -> None:
        """Class-attributed observation: feeds the class profile (and
        tracker), then the pooled set via :meth:`observe`."""
        if not _valid_sample(latency_ms):
            self.n_rejected_samples += 1
            return
        cp = self.class_profiles[cls][name]
        cp.update(latency_ms, self.alpha)
        if self.class_trackers is not None:
            self.class_trackers[cls][name].observe(float(latency_ms))
        self.observe(name, latency_ms)

    # -- presentation ---------------------------------------------------
    def _refresh(self, name: str, p: ModelProfile) -> None:
        if self._table is None:
            return
        if self.q is None:
            super()._refresh(name, p)
        else:
            self._table.refresh(self._table.index[name],
                                self._pooled_presented(name), 0.0,
                                p.queue_mu)

    def table(self) -> ProfileTable:
        if self.active >= 0:
            return self.class_table(self.active)
        if self.q is None:
            return super().table()
        if self._table is None:
            t = ProfileTable.from_store(self)
            for i, name in enumerate(t.names):
                t.refresh(i, self._pooled_presented(name), 0.0,
                          self.profiles[name].queue_mu)
            self._table = t
        return self._table

    def pooled_table(self) -> ProfileTable:
        """The unconditional view regardless of the cursor — batch
        admission judges against it (snapshot semantics)."""
        if self.active < 0:
            return self.table()
        active, self.active = self.active, -1
        try:
            return self.table()
        finally:
            self.active = active

    def class_table(self, cls: int) -> ProfileTable:
        """The class-``cls`` shrunk (or quantile-presented) snapshot,
        cached against the store's mutation ``version``."""
        if self._class_tables[cls] is not None \
                and self._class_ver[cls] == self.version:
            return self._class_tables[cls]
        names = tuple(self.profiles)
        mu = np.empty(len(names), dtype=np.float64)
        sigma = np.empty(len(names), dtype=np.float64)
        for i, name in enumerate(names):
            mu[i], sigma[i] = self.presented_class(cls, name)
        t = ProfileTable(
            names,
            np.array([p.accuracy for p in self.profiles.values()],
                     dtype=np.float64),
            mu, sigma,
            np.array([p.queue_mu for p in self.profiles.values()],
                     dtype=np.float64))
        self._class_tables[cls] = t
        self._class_ver[cls] = self.version
        return t

    def stacked_pool(self) -> StackedClassPools:
        """All K class tables as one (K × npad) device snapshot for the
        classed fused kernel, rebuilt only when telemetry moved."""
        if self._stacked is None or self._stacked_ver != self.version:
            self._stacked = StackedClassPools(
                [self.class_table(k) for k in range(self.n_classes)])
            self._stacked_ver = self.version
        return self._stacked

    def class_obs(self, cls: int) -> int:
        """Accepted class-attributed observations (diagnostics)."""
        return sum(p.n_obs for p in self.class_profiles[cls].values())
