"""Premodel: feature-conditioned, tail-aware profiles in front of the
Router.

ModiPick (the source paper) routes every request against ONE
unconditional latency/accuracy profile per model.  Two follow-up lines
of work motivate this package:

- **Premodel** (Taylor et al., "Adaptive Selection of Deep Learning
  Models on Embedded Systems"; Marco et al.): cheap, instantly
  computable request features — input size, resolution bucket, modality
  — predict *which* model suffices for a given input.  Easy inputs can
  ride a cheap model at no accuracy loss; hard inputs genuinely need
  the big one.  A tiny classifier in front of model selection converts
  that signal into per-input-class routing.
- **MDInference** (Ogden & Guo): the sequel framing is duration
  *prediction* — under tail-tight SLAs, routing on mean latency is
  systematically optimistic; the estimate that matters is p95/p99 of
  ``W_queue + inference``.

The three pieces map onto the existing architecture without touching
the Router's decision logic:

- :mod:`repro.premodel.classifier` — features → input-class id.
  :class:`~repro.premodel.classifier.NearestCentroidClassifier` learns
  online (sequential k-means); :class:`~repro.premodel.classifier.
  OracleClassifier` is the frozen ablation that knows the true class
  geometry.
- :mod:`repro.premodel.conditional` — :class:`~repro.premodel.
  conditional.ConditionalProfileStore`, K per-class profile sets over
  the shared zoo with hierarchical shrinkage toward the pooled
  unconditional estimate, an active-class cursor so the scalar route
  path works unchanged, and a stacked ``(K × pool)`` snapshot for the
  one-device-call batched path.
- :mod:`repro.premodel.quantile` — :class:`~repro.premodel.quantile.
  P2Quantile` streaming estimators and :class:`~repro.premodel.
  quantile.QuantileProfileStore`, which *presents* per-model latency as
  the tracked quantile (mean + z·σ Gaussian fallback until enough
  samples) so budget checks and ``SlaAwareAdmission`` judge tails, not
  means — with zero Router changes.

Everything here is opt-in: a run with no features and
``latency_quantile=None`` never constructs these objects and executes
the historical path op-for-op (all seeded goldens stay bit-identical).
"""
from repro.premodel.classifier import (NearestCentroidClassifier,
                                       OracleClassifier, make_classifier)
from repro.premodel.conditional import ConditionalProfileStore
from repro.premodel.quantile import P2Quantile, QuantileProfileStore

__all__ = [
    "NearestCentroidClassifier",
    "OracleClassifier",
    "make_classifier",
    "ConditionalProfileStore",
    "P2Quantile",
    "QuantileProfileStore",
]
