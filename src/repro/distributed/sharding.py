"""Logical-axis-rule sharding (MaxText-style).

Model code annotates activations/params with *logical* axis names; a rules
table (installed per run via :func:`axis_rules`) maps logical names to mesh
axes.  Outside any rules context every annotation is the identity, so the
same model code runs unsharded on CPU tests and fully sharded in the
dry-run / production launchers.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()


def current_rules() -> Optional[Dict[str, MeshAxes]]:
    return getattr(_STATE, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, MeshAxes], mesh: Optional[Mesh] = None):
    prev_rules = getattr(_STATE, "rules", None)
    prev_mesh = getattr(_STATE, "mesh", None)
    _STATE.rules = dict(rules)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.rules = prev_rules
        _STATE.mesh = prev_mesh


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    rules: Optional[Dict[str, MeshAxes]] = None,
    *,
    shape: Optional[Sequence[int]] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """Map logical axis names to a PartitionSpec.

    If ``shape``+``mesh`` are given, any mapping whose axis size does not
    divide the dim is dropped (divisibility-aware fallback) and duplicate
    mesh axes are dropped left-to-right.
    """
    rules = rules if rules is not None else (current_rules() or {})
    mesh = mesh if mesh is not None else current_mesh()
    used = set()
    out = []
    for i, name in enumerate(logical_axes):
        assignment = rules.get(name) if name else None
        if assignment is None:
            out.append(None)
            continue
        # Preserve the rule's spelling: tuple rules stay tuples even when
        # singleton — current jax PartitionSpec equality distinguishes
        # P('x') from P(('x',)) although they shard identically.
        as_tuple = not isinstance(assignment, str)
        axes = (assignment,) if isinstance(assignment, str) else tuple(assignment)
        axes = tuple(a for a in axes if a not in used)
        if mesh is not None and shape is not None:
            total = 1
            kept = []
            for a in axes:
                n = mesh.shape[a]
                if shape[i] % (total * n) == 0:
                    kept.append(a)
                    total *= n
            axes = tuple(kept)
        if not axes:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes if as_tuple else axes[0])
    return P(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without rules)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = logical_to_spec(logical_axes, rules, shape=x.shape, mesh=current_mesh())
    return jax.lax.with_sharding_constraint(x, spec)


def tree_specs(axes_tree, rules, mesh, shapes_tree) -> "jax.tree_util.PyTreeDef":
    """Map a pytree of logical-axes tuples + shapes to PartitionSpecs."""
    return jax.tree.map(
        lambda ax, shp: logical_to_spec(ax, rules, shape=shp.shape, mesh=mesh),
        axes_tree,
        shapes_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(isinstance(e, (str, type(None))) for e in v),
    )


def tree_shardings(axes_tree, rules, mesh, shapes_tree):
    specs = tree_specs(axes_tree, rules, mesh, shapes_tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda v: isinstance(v, P))
