"""Gradient compression: int8 quantized all-reduce with error feedback.

Used on the slow inter-pod axis where links dominate: gradients are
quantized to int8 with a per-tensor scale, summed in int32 (no overflow up
to 2^23 summands), and dequantized.  The quantization residual is carried
in an error-feedback buffer (Seide et al. / EF-SGD) so the compression
bias vanishes over steps.  Wire into shard_map over the ``pod`` axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed all-reduce mean over `axis_name` (inside shard_map)."""
    n = jax.lax.psum(1, axis_name)
    q, scale = quantize_int8(x)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # each shard used its own scale; reduce with the max scale bound
    max_scale = jax.lax.pmax(scale, axis_name)
    return total.astype(jnp.float32) * max_scale / n


def ef_compress(grad: jax.Array, error: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback step: corrected = grad + error; returns
    (int8 payload, scale, new_error)."""
    corrected = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(corrected)
    new_error = corrected - dequantize_int8(q, scale)
    return q, scale, new_error


def ef_compressed_psum_tree(grads: Any, errors: Any, axis_name: str
                            ) -> Tuple[Any, Any]:
    """Tree-wise EF-compressed all-reduce mean. Returns (reduced, new_errors)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, scale, new_e = ef_compress(g, e)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        max_scale = jax.lax.pmax(scale, axis_name)
        return (total.astype(jnp.float32) * max_scale / n).astype(g.dtype), new_e

    out = jax.tree.map(one, grads, errors)
    reduced = jax.tree.map(lambda t: t[0], out, is_leaf=lambda v: isinstance(v, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda v: isinstance(v, tuple))
    return reduced, new_err
