"""HLO analysis: collective-bytes extraction + TPU v5e roofline model.

``cost_analysis()`` exposes FLOPs and HBM bytes but not collective
traffic, so collective bytes are parsed from the post-SPMD HLO text: we
sum the *operand* sizes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute, with op-specific wire factors
(all-reduce moves ≈2× its operand on a ring: reduce-scatter + all-gather).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List

# --- hardware constants (TPU v5e, per chip) ---------------------------
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link (≈ per-chip usable here)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `%op.N = TYPE kind(...operands...), ... replica_groups=...`
# TYPE is a shape or a tuple of shapes; operands carry no inline types in
# post-optimization HLO, so sizes come from the RESULT type.
_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0
    dtype, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def _result_bytes(type_str: str, is_start: bool) -> int:
    type_str = type_str.strip()
    if type_str.startswith("("):
        parts = [p for p in type_str[1:-1].split(",") if "[" in p]
        sizes = [_shape_bytes(p) for p in parts]
        if not sizes:
            return 0
        # async -start ops: (operand, destination, ...) — use the destination
        return sizes[1] if is_start and len(sizes) > 1 else max(sizes)
    return _shape_bytes(type_str)


@dataclass
class CollectiveStats:
    # wire bytes PER CHIP (ring-algorithm estimates from result sizes)
    by_kind: Dict[str, float] = field(default_factory=dict)
    by_kind_count: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-chip wire-byte estimate per collective, ring algorithms:
    all-gather: recv ≈ result·(n-1)/n; all-reduce: ≈ 2·size·(n-1)/n;
    reduce-scatter: send ≈ result·(n-1); all-to-all: ≈ result·(n-1)/n;
    collective-permute: result."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        type_str, kind, start = m.group(1), m.group(2), m.group(3)
        size = _result_bytes(type_str, start is not None)
        gm = _GROUPS_RE.search(line)
        n = int(gm.group(2)) if gm else 2
        frac = (n - 1) / n
        if kind == "all-reduce":
            wire = 2.0 * size * frac
        elif kind == "all-gather":
            wire = size * frac
        elif kind == "reduce-scatter":
            wire = size * (n - 1)
        elif kind == "all-to-all":
            wire = size * frac
        else:  # collective-permute
            wire = size
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + wire
        stats.by_kind_count[kind] = stats.by_kind_count.get(kind, 0) + 1
    return stats


# ----------------------------------------------------------------------
@dataclass
class Roofline:
    n_chips: int
    hlo_flops: float            # whole-program FLOPs (all chips)
    hlo_bytes: float            # whole-program HBM bytes
    coll_bytes_per_chip: float  # wire bytes per chip
    model_flops: float          # analytic 6·N·D (active params)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.n_chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.n_chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline bound."""
        if self.step_s <= 0:
            return 0.0
        return self.model_flops / (self.step_s * self.n_chips * PEAK_FLOPS_BF16)

    def to_dict(self) -> dict:
        return {
            "n_chips": self.n_chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D for train, 2·N_active·D for a
    forward-only phase (prefill), 2·N_active·B for one decode token."""
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq
