"""shard_map wrappers: the Pallas kernels composed with the production
mesh.

GSPMD cannot partition an opaque `pallas_call`, so on TPU the kernels run
under `shard_map` with manual specs: batch over the data axes, heads over
'model' (when divisible — otherwise heads replicate and batch carries the
parallelism), KV broadcast for GQA.  The same wrappers run in interpret
mode on CPU fake-device meshes, which is how the tests validate the
sharded path against the unsharded oracle.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops


def _data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _head_axis(mesh: Mesh, n_heads: int, n_kv: int) -> Optional[str]:
    tp = "model" if "model" in mesh.shape else None
    if tp and n_heads % mesh.shape[tp] == 0 and n_kv % mesh.shape[tp] == 0:
        return tp
    return None


def sharded_flash_attention(q, k, v, mesh: Mesh, *, causal: bool = True,
                            window: int = 0):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) — batch over data axes,
    heads over 'model' when both H and KV divide it."""
    dp = _data_axes(mesh)
    hax = _head_axis(mesh, q.shape[1], k.shape[1])
    spec = P(dp or None, hax, None, None)

    fn = partial(ops.flash_attention, causal=causal, window=window)
    return shard_map(fn, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_rep=False)(q, k, v)


def sharded_decode_attention(q, k, v, pos, mesh: Mesh, *, window: int = 0):
    """q: (B, KV, G, hd); k, v: (B, KV, S, hd); pos: (B,)."""
    dp = _data_axes(mesh)
    hax = _head_axis(mesh, k.shape[1], k.shape[1])
    spec_q = P(dp or None, hax, None, None)
    spec_kv = P(dp or None, hax, None, None)
    spec_pos = P(dp or None)

    fn = partial(ops.decode_attention, window=window)
    return shard_map(fn, mesh=mesh,
                     in_specs=(spec_q, spec_kv, spec_kv, spec_pos),
                     out_specs=spec_q, check_rep=False)(q, k, v, pos)


def sharded_ssd_scan(x, dt, A, B_, C_, mesh: Mesh, *, chunk: int = 128):
    """x: (B, H, S, hd); dt: (B, H, S); A: (H,); B_, C_: (B, G, S, N).
    Heads shard over 'model' only when the group count divides too
    (otherwise B_/C_ would need replication-aware splitting)."""
    dp = _data_axes(mesh)
    hax = _head_axis(mesh, x.shape[1], B_.shape[1])
    fn = partial(ops.ssd_scan, chunk=chunk)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp or None, hax, None, None),
                  P(dp or None, hax, None),
                  P(hax),
                  P(dp or None, hax, None, None),
                  P(dp or None, hax, None, None)),
        out_specs=P(dp or None, hax, None, None), check_rep=False)(
            x, dt, A, B_, C_)


def sharded_fleet_select(mu, sig, acc, rank, t_u, t_l, keys, mesh: Mesh,
                         *, gamma: float = 1.0):
    """Fleet-wide ModiPick selection with the cell axis sharded.

    Every operand carries the cell on its leading axis — mu/sig/acc/rank
    (C, npad), t_u/t_l (C, B), keys (C, 2) PRNG keys — and shards over
    the mesh's ``cell`` axis (falling back to ``data`` when the fleet
    mesh reuses the training mesh's naming).  Each device vmaps the
    same jnp body (`kernels.policy_select.fleet_select_body`) over its
    local cells, so the sharded call is bit-identical to the single
    device `select_fleet_stacked` whenever C divides the axis; when it
    does not, the divisibility-aware rule drops the mapping and the
    call replicates (still correct, just not parallel)."""
    from repro.distributed.sharding import axis_rules, logical_to_spec
    from repro.kernels.policy_select import fleet_select_body

    ax = next((a for a in ("cell", "data") if a in mesh.shape), None)
    with axis_rules({"cell": ax}, mesh):
        spec = logical_to_spec(("cell", None), shape=t_u.shape, mesh=mesh)
    body = jax.vmap(partial(fleet_select_body, gamma=gamma))
    return shard_map(body, mesh=mesh, in_specs=(spec,) * 7,
                     out_specs=spec, check_rep=False)(
                         mu, sig, acc, rank, t_u, t_l, keys)


def sharded_rglru_scan(a, b, mesh: Mesh, *, block_s: int = 256):
    """a, b: (B, S, W) — batch over data, channels over 'model'."""
    dp = _data_axes(mesh)
    tp = "model" if "model" in mesh.shape and a.shape[2] % mesh.shape["model"] == 0 else None
    spec = P(dp or None, None, tp)
    fn = partial(ops.rglru_scan, block_s=block_s)
    return shard_map(fn, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
                     check_rep=False)(a, b)
