"""Sharding policy: logical-axis rules per (arch × shape × mesh).

Baseline strategy (what the dry-run lowers):

- train: 2D FSDP×TP.  Batch and the `embed_fsdp` weight dim shard over
  ('pod','data'); `ff`/`heads_merged`/`vocab`/`experts`/`rnn_width` shard
  over 'model'.  The layer scan amortizes FSDP all-gathers and GSPMD's
  latency-hiding scheduler overlaps the next superblock's gather with
  compute.
- prefill/decode: TP over 'model', batch over ('pod','data'); params
  replicated across data (latency path) unless the per-chip footprint
  exceeds a threshold, in which case `expert_ff` additionally shards over
  ('pod','data') (weight-2D, costs one psum — needed for dbrx serving).
- long_500k (batch=1): context parallelism — `cache_seq` shards over
  'data' with softmax combining handled by GSPMD reductions; recurrent
  state (O(1) in seq) stays TP-sharded.

Divisibility-aware fallbacks live in ``sharding.logical_to_spec``: any
rule whose mesh axis does not divide the dim is dropped (⇒ replicated),
which is how odd head counts (qwen2 12H, phi4 24H, rg 10H, whisper 6H)
degrade gracefully; the §Perf pass quantifies and fixes the big ones via
head padding.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig

# Per-chip bytes above which serving weights also shard over data axes.
SERVE_WEIGHT_SHARD_THRESHOLD = 8 << 30

# Microbatch counts for train_4k so per-chip activation temps fit v5e HBM
# (16 GB).  Sized from the measured baseline temp_size_in_bytes.
TRAIN_GRAD_ACCUM = {
    "recurrentgemma-2b": 4,
    "mamba2-1.3b": 4,
    "qwen2-1.5b": 2,
    "phi4-mini-3.8b": 4,
    "command-r-35b": 16,
    "gemma3-4b": 4,
    "whisper-tiny": 2,
    "dbrx-132b": 16,
    "moonshot-v1-16b-a3b": 4,
    "internvl2-2b": 2,
}


# 8-bit Adam moments where fp32 optimizer state alone would break the
# per-chip HBM budget (see EXPERIMENTS.md §fit).
TRAIN_OPT_MOMENTS = {"dbrx-132b": "int8"}


def train_grad_accum(arch: str, global_batch: int, mesh) -> int:
    """Accumulation capped so each microbatch still covers the DP axes —
    a microbatch smaller than the data-parallel degree replicates
    activations (observed: command-r train on multi-pod, 10.7 → 64.5 GB
    temps when micro=16 < dp=32)."""
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    want = TRAIN_GRAD_ACCUM.get(arch, 1)
    return max(1, min(want, global_batch // max(dp, 1)))


def make_rules(cfg: ModelConfig, shape: ShapeConfig, mesh,
               overrides: Optional[Dict] = None) -> Dict:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = "model" if "model" in mesh.shape else None
    mode = shape.mode

    rules: Dict = {
        "batch": dp,
        "seq": None,
        "heads": tp,
        "kv_heads": tp,
        "ff": tp,
        "expert_ff": None,
        "experts": tp,
        "vocab": tp,
        "rnn_width": tp,
        "layers": None,
        "cache_seq": None,
        "heads_merged": tp,
        "kv_merged": tp,
        "embed_fsdp": None,
    }

    if mode == "train":
        rules["embed_fsdp"] = dp  # FSDP: weights + optimizer state over data
    else:
        # Serving: replicate weights across data for latency, unless the
        # model doesn't fit TP-only — then 2D-shard the expert ffn dim.
        tp_deg = mesh.shape.get("model", 1)
        per_chip = 2 * cfg.param_count() / max(tp_deg, 1)  # bf16
        if per_chip > SERVE_WEIGHT_SHARD_THRESHOLD:
            rules["expert_ff"] = dp
            rules["embed_fsdp"] = None

    if mode == "decode" and shape.global_batch < _prod(mesh, dp):
        # batch can't cover the data axes (long_500k B=1): context-parallel
        # the KV cache over 'data' instead.
        rules["batch"] = None
        rules["cache_seq"] = "data" if "data" in mesh.shape else None
    elif mode == "decode" and tp and cfg.n_kv_heads % mesh.shape[tp] != 0:
        # KV heads don't divide TP ⇒ the cache would replicate across the
        # model axis (observed: 5× the per-chip KV-floor bytes on
        # command-r decode).  Context-parallel the cache sequence over
        # 'model' instead: flash-decode partial softmax combines via the
        # GSPMD-inserted reductions; per-chip cache traffic drops ×tp.
        rules["cache_seq"] = tp

    if overrides:
        rules.update(overrides)
    return rules


def _prod(mesh, axes) -> int:
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    return p
