"""Device-side fleet selection: stacked per-cell pool operands and the
one-call (cell × batch × pool) dispatch.

Each cell serves its own zoo subset, so its
:class:`~repro.kernels.policy_select.DevicePool` has its own width.  To
judge every cell's pending batch in ONE device call, the per-cell pools
are re-padded to the fleet-wide maximum width with the same sentinels
the single-cell pool uses on padded lanes (``PAD_MU`` — never eligible;
``PAD_RANK`` — never wins the stage-1 argmin), and stacked on a leading
cell axis.  The stacked snapshot is frozen against one set of
``ProfileTable`` snapshots — rebuild (cheap) when any cell's profiles
move, exactly like ``ProfileTable.device_pool()``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.kernels.policy_select import (PAD_MU, PAD_RANK,
                                         select_fleet_stacked)


class StackedPools:
    """(C, npad) pool operands for ``select_fleet`` — the fleet analogue
    of :class:`~repro.kernels.policy_select.DevicePool`."""

    __slots__ = ("C", "npad", "n", "mu", "sigma", "acc", "rank", "fastest")

    def __init__(self, tables: Sequence):
        pools = [t.device_pool() for t in tables]
        self.C = len(pools)
        if self.C == 0:
            raise ValueError("StackedPools needs at least one cell table")
        self.npad = max(p.npad for p in pools)
        self.n = np.array([p.n for p in pools], dtype=np.int64)
        self.fastest = np.array([p.fastest for p in pools], dtype=np.int64)

        def stack(attr, value):
            rows = []
            for p in pools:
                x = getattr(p, attr)
                rows.append(jnp.pad(x, (0, self.npad - x.shape[0]),
                                    constant_values=value))
            return jnp.stack(rows)

        self.mu = stack("mu", PAD_MU)
        self.sigma = stack("sigma", 0.0)
        self.acc = stack("acc", 1.0)
        self.rank = stack("rank", PAD_RANK)


def stack_cell_tables(tables: Sequence) -> StackedPools:
    """Stack every cell's ``ProfileTable`` snapshot into one
    :class:`StackedPools` (re-padded to the common width)."""
    return StackedPools(tables)


def select_fleet(stacked: StackedPools, t_u, t_l, *, gamma: float = 1.0,
                 seed: int = 0, mesh: Optional[object] = None) -> np.ndarray:
    """Every cell's judgment of every pending request in one call.

    ``t_u``/``t_l``: (C, B) budget bounds — row ``c`` is what request
    ``b``'s budget *would be* if served by cell ``c`` (home rows carry
    no RTT; remote rows already subtract it).  Returns (C, B) int32
    picks, −1 where cell ``c`` has no eligible variant for request
    ``b`` — the frontend's viability matrix.

    With a ``mesh`` whose ``cell`` (or ``data``) axis divides C, the
    call runs under ``shard_map``
    (``distributed.shardmap_ops.sharded_fleet_select``) — same jnp body,
    one shard of cells per device.  Otherwise (the CPU test path, or a
    non-divisible cell count) it is a single vmapped jit.
    """
    t_u = np.asarray(t_u, dtype=np.float32)
    t_l = np.asarray(t_l, dtype=np.float32)
    if t_u.shape != t_l.shape or t_u.ndim != 2 or t_u.shape[0] != stacked.C:
        raise ValueError(f"budget bounds must be (C={stacked.C}, B); got "
                         f"t_u {t_u.shape}, t_l {t_l.shape}")
    if mesh is not None:
        ax = next((a for a in ("cell", "data") if a in mesh.shape), None)
        if ax is not None and stacked.C % mesh.shape[ax] == 0:
            import jax
            from repro.distributed.shardmap_ops import sharded_fleet_select
            keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
                jax.random.PRNGKey(seed),
                jnp.arange(stacked.C, dtype=jnp.uint32))
            out = sharded_fleet_select(
                stacked.mu, stacked.sigma, stacked.acc, stacked.rank,
                jnp.asarray(t_u), jnp.asarray(t_l), keys, mesh,
                gamma=gamma)
            return np.asarray(out)
    return select_fleet_stacked(stacked.mu, stacked.sigma, stacked.acc,
                                stacked.rank, t_u, t_l, gamma=gamma,
                                seed=seed)
