"""The fleet frontend: sticky user→cell placement and honest spill.

Every request belongs to a *user*; users stick to a home cell chosen by
hashing their id against the cells' weight distribution (splitmix64 —
stateless, deterministic, no directory service to simulate).  Per
rebalancing epoch the frontend judges each pending request against
EVERY cell at once (one ``fleet.device.select_fleet`` call over the
(cell × batch × pool) operands): row ``c`` of the budget matrix is what
the request's budget would be if cell ``c`` served it,

    T_u[c, r] = T_sla − 2·T_input − L_c − RTT_xcell · [c ≠ home(r)]

so a spilled request's budget already pays the inter-cell round trip
and the target cell's load signal before anyone commits to it — the
same honesty rule :class:`~repro.router.api.BudgetBreakdown` encodes
per decision.

Spill volumes are *capacity-aware*, not signal-chasing.  The naive rule
— move every endangered request to the currently cheapest cell — is
unstable: the whole hot window herds onto one target, drowns it, the
drowned cell serves nothing, reads idle next epoch, and the herd comes
back (a textbook bang-bang oscillation; the first cut of this planner
did exactly that).  Instead the planner sheds only each hot cell's
*excess over its estimated capacity* (plus an optional load-triggered
fraction), spreads it across targets in proportion to their remaining
headroom, and never plans more into a target than that headroom — so a
valley cell absorbs spill up to its capacity and not beyond.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.fleet.device import StackedPools, select_fleet
from repro.scenario.spec import Scenario

_UID_SALT = np.uint64(0x9E3779B97F4A7C15)


def _mix(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer: uint64 → well-mixed uint64, vectorized."""
    with np.errstate(over="ignore"):
        z = x.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


@dataclass
class SpillPlan:
    """One epoch's placement: where every pending request runs."""
    home: np.ndarray          # (B,) home cell of each request
    assigned: np.ndarray      # (B,) serving cell after spill
    rtt_extra_ms: np.ndarray  # (B,) RTT the assignment pays (0 at home)
    picks: np.ndarray         # (C, B) per-cell variant picks (−1 = none)

    @property
    def spilled(self) -> np.ndarray:
        return self.assigned != self.home

    @property
    def n_spilled(self) -> int:
        return int(self.spilled.sum())


class FleetFrontend:
    """Sticky placement + capacity-aware spill planning."""

    # A load-triggered shed (beyond the capacity excess) never moves
    # more than this share of a hot cell's window.
    MAX_SPILL_FRAC = 0.5
    # Plan to this utilization of estimated capacity: at ρ = 1 the
    # in-window queue still grows without bound, so both the outbound
    # excess and the inbound headroom leave a margin.
    TARGET_UTIL = 0.9

    def __init__(self, scenario: Scenario):
        fleet = scenario.deployment.fleet
        if fleet is None:
            raise ValueError(f"scenario {scenario.name!r} has no fleet")
        self.fleet = fleet
        self.n_cells = fleet.n_cells
        w = np.array([c.weight for c in fleet.cells], dtype=np.float64)
        self._cum = np.cumsum(w) / w.sum()
        self._cum[-1] = 1.0 + 1e-12   # guard the u == 1.0 edge
        self.n_users = fleet.n_users
        self.rtt_ms = fleet.rtt_ms
        self.spill = fleet.spill
        self.spill_threshold_ms = fleet.spill_threshold_ms
        self.t_sla_ms = scenario.workload.t_sla_ms
        self.t_threshold = float(
            scenario.policy.kwargs.get("t_threshold", 20.0))
        # 2·T_input estimate per cell: the frontend plans on the uplink
        # *mean* (it has not seen the draw yet); the engine then samples
        # the real uplink per request.
        self.net2_ms = np.array(
            [2.0 * (c.network.mean_ms if c.network is not None
                    else scenario.network.mean_ms) for c in fleet.cells],
            dtype=np.float64)

    # -- sticky placement ----------------------------------------------
    def uid_of(self, rids) -> np.ndarray:
        """Global request id → user id (many requests per user)."""
        r = np.asarray(rids, dtype=np.uint64)
        return (_mix(r ^ _UID_SALT) % np.uint64(self.n_users)).astype(
            np.int64)

    def home_cell(self, uids) -> np.ndarray:
        """User id → home cell, proportional to cell weights."""
        u = _mix(np.asarray(uids, dtype=np.uint64))
        u01 = u.astype(np.float64) / float(2**64)
        return np.searchsorted(self._cum, u01, side="right").astype(
            np.int64)

    def home_of_requests(self, rids) -> np.ndarray:
        return self.home_cell(self.uid_of(rids))

    # -- spill planning --------------------------------------------------
    def budget_matrix(self, home: np.ndarray, load_ms: np.ndarray):
        """(C, B) upper budget bounds: the spilled-budget formula
        ``T_sla − 2·T_input − L_c − RTT·[c ≠ home]`` per cell × request;
        the lower bound subtracts the policy's t_threshold window."""
        rtt = self.rtt_ms * (np.arange(self.n_cells)[:, None]
                             != home[None, :])
        t_u = (self.t_sla_ms - self.net2_ms[home][None, :]
               - np.asarray(load_ms, dtype=np.float64)[:, None] - rtt)
        return t_u, t_u - self.t_threshold

    def plan(self, rids, load_ms, stacked: StackedPools, *,
             cap_req: Optional[np.ndarray] = None, gamma: float = 1.0,
             seed: int = 0, mesh=None) -> SpillPlan:
        """Place one epoch's pending requests.

        ``rids``: (B,) global request ids; ``load_ms``: (C,) per-cell
        load signal (previous window's mean queue wait); ``cap_req``:
        (C,) estimated per-window serving capacity in requests
        (``np.inf``/None = unknown — the engine learns it from observed
        throughput); ``stacked``: the cells' pooled profile snapshots.
        """
        rids = np.asarray(rids)
        home = self.home_of_requests(rids)
        load_ms = np.asarray(load_ms, dtype=np.float64)
        t_u, t_l = self.budget_matrix(home, load_ms)
        picks = select_fleet(stacked, t_u, t_l, gamma=gamma, seed=seed,
                             mesh=mesh)
        assigned = home.copy()
        if self.spill and self.n_cells > 1:
            # Structural viability: can the cell serve at ZERO load?
            # (fastest variant fits the un-loaded budget).  A cell that
            # fails this must spill regardless; a cell that merely has
            # a high load signal sheds only its capacity excess — its
            # queue drained at the epoch boundary, so congestion
            # non-viability must not force out the whole window.
            mu = np.asarray(stacked.mu, dtype=np.float64)
            mu_min = np.where(mu < 1e29, mu, np.inf).min(axis=1)
            struct_ok = (self.t_sla_ms - self.net2_ms
                         - self.t_threshold) > mu_min
            self._plan_spill(assigned, home, picks >= 0, struct_ok,
                             load_ms, cap_req)
        rtt_extra = np.where(assigned != home, self.rtt_ms, 0.0)
        return SpillPlan(home=home, assigned=assigned,
                         rtt_extra_ms=rtt_extra, picks=picks)

    def _plan_spill(self, assigned: np.ndarray, home: np.ndarray,
                    viable: np.ndarray, struct_ok: np.ndarray,
                    load_ms: np.ndarray,
                    cap_req: Optional[np.ndarray]) -> None:
        """Capacity-aware spill, in place on ``assigned``.

        Per hot cell (worst first) the outbound budget is the window's
        excess over the cell's estimated capacity plus an optional
        load-triggered share — or the whole window when the cell is
        *structurally* unable to serve (fastest variant misses the
        zero-load budget).  Congestion-non-viable requests (endangered
        by the load signal) are moved first, the rest evenly strided
        through the window.  Targets receive shares proportional to
        their remaining headroom (largest-remainder split), each
        request landing on its share's cell only if that cell has a
        viable variant for it — otherwise its cheapest viable target."""
        C = self.n_cells
        n_home = np.bincount(home, minlength=C).astype(np.float64)
        if cap_req is None:
            cap = np.full(C, np.inf)
        else:
            cap = np.asarray(cap_req, dtype=np.float64)
        # Unknown capacity: a neutral guess — one average window.
        guess = max(n_home.mean(), 1.0)
        cap = np.where(np.isfinite(cap), cap, guess) * self.TARGET_UTIL
        head = np.maximum(cap - n_home, 0.0)

        thr = self.spill_threshold_ms
        for c in np.argsort(-load_ms):
            mine = np.where(home == c)[0]
            if mine.size == 0:
                continue
            forced = not struct_ok[c]
            excess = max(0.0, n_home[c] - cap[c])
            extra = 0.0
            if thr > 0.0 and load_ms[c] > thr:
                extra = min((load_ms[c] - thr) / load_ms[c],
                            self.MAX_SPILL_FRAC) * mine.size
            budget = mine.size if forced else \
                int(min(max(excess, extra), mine.size))
            if budget == 0:
                continue
            # Count-based excess is proactive — this window WILL
            # overrun home capacity, so any cell with headroom is a
            # valid target (per-request viability, which already pays
            # the RTT, gates below).  A purely load-triggered shed is
            # reactive and keeps the conservative gate: the target must
            # win even after the RTT.
            if forced or excess > 0.0:
                ok_target = np.ones(C, dtype=bool)
            else:
                ok_target = load_ms + self.rtt_ms < max(load_ms[c], thr)
            targets = np.where((np.arange(C) != c)
                               & (head > 0.0) & ok_target)[0]
            if targets.size == 0:
                continue
            # Endangered requests (non-viable under the load signal)
            # move first, then an even stride over the rest.
            risk = ~viable[c, mine]
            sel = mine[risk][:budget]
            rest = budget - sel.size
            if rest > 0:
                others = mine[~risk]
                take = min(rest, others.size)
                sel = np.concatenate([
                    sel, others[np.linspace(0, others.size - 1, num=take,
                                            dtype=np.int64)]])
            # Headroom caps bound the total; largest-remainder split
            # spreads it proportionally.
            k = min(sel.size, int(head[targets].sum()))
            if k == 0:
                continue
            sel = sel[:k]
            share = head[targets] / head[targets].sum()
            alloc = np.minimum(np.floor(share * k + 0.5),
                               head[targets]).astype(np.int64)
            while alloc.sum() > k:
                alloc[np.argmax(alloc)] -= 1
            t_of = np.repeat(targets, alloc)
            if t_of.size < sel.size:
                sel = sel[:t_of.size]
            if sel.size == 0:
                continue
            # A request whose allotted target has no viable variant for
            # it falls back to its least-loaded viable target (or stays
            # home when none is viable).
            ok = viable[t_of, sel]
            if not ok.all():
                bad = ~ok
                tl = np.where(viable[np.ix_(targets, sel[bad])],
                              load_ms[targets][:, None], np.inf)
                alt = np.argmin(tl, axis=0)
                feasible = np.isfinite(tl[alt, np.arange(alt.size)])
                t_of[bad] = np.where(feasible, targets[alt], c)
            assigned[sel] = t_of
            moved = np.bincount(t_of[t_of != c], minlength=C)
            head -= moved
            head[c] += moved.sum()        # the shed frees home headroom
            np.maximum(head, 0.0, out=head)