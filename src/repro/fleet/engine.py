"""The fleet engine: N per-cell serving simulators on a shared clock.

Execution model — epoch-stepped, like the autoscaler harness but across
space instead of time:

1. The sticky frontend assigns every request a home cell; each cell
   gets its own arrival timeline (Poisson at its weighted share, a
   phase-shifted diurnal synthesizer, or a phase-shifted replay of the
   fleet's rate trace — its time zone).
2. Time advances in ``FleetSpec.epoch_ms`` windows.  Per window the
   frontend re-plans: all cells' pending requests are judged against
   all cells in ONE stacked device call
   (:func:`~repro.fleet.device.select_fleet`), and requests whose home
   cell cannot serve them spill to the cheapest viable remote cell,
   paying the inter-cell RTT inside their own budget.
3. Each cell's :class:`~repro.sim.engine.ServingSimulator` runs its
   window to completion (cells drain at epoch boundaries — the same
   consecutive-observation-window semantics as multi-epoch scenarios),
   with spilled-in requests carrying ``extra_input_for = RTT/2`` so
   ``2·T_input`` grows by exactly the RTT.  Profile stores persist per
   cell across epochs; the load signal the next plan sees is each
   cell's mean queue wait from the window just run.

A 1-cell fleet with no trace runs *passthrough*: the scenario executes
on the ordinary single-cell harness path, bit-identical to the same
scenario without a ``FleetSpec`` (the parity guarantee the golden test
pins).
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.fleet.device import stack_cell_tables
from repro.fleet.frontend import FleetFrontend
from repro.fleet.spec import CellSpec, FleetSpec
from repro.scenario.spec import Scenario
from repro.sim.arrivals import TraceArrivals, diurnal_trace, load_trace
from repro.sim.engine import LoadSimResult

_CELL_SEED_STRIDE = 1_000_003
_FLEET_TRACE_SALT = 0xF1EE7
_PLAN_SEED_STRIDE = 7919


def cell_view(scenario: Scenario, cell: CellSpec) -> Scenario:
    """The single-cell Scenario a fleet cell runs: the fleet scenario
    with this cell's overrides applied and the fleet field dropped."""
    dep = scenario.deployment
    replicas = cell.replicas or dep.replicas
    topology = cell.topology or dep.topology
    # Explicit shared-pool speeds only survive when the cell keeps the
    # declared shape (build_replicas applies the same rule on resize).
    speeds = dep.speeds if (topology == dep.topology
                            and replicas == dep.replicas) else ()
    return dataclasses.replace(
        scenario,
        name=f"{scenario.name}:{cell.name}",
        network=cell.network if cell.network is not None else
        scenario.network,
        deployment=dataclasses.replace(
            dep, fleet=None, subset=cell.subset or dep.subset,
            topology=topology, replicas=replicas, speeds=speeds))


def _resolve_trace_path(path: str) -> str:
    """Relative trace paths resolve against the repo root (where
    ``examples/`` lives), falling back to the cwd."""
    if os.path.isabs(path) or os.path.exists(path):
        return path
    root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))
    cand = os.path.join(root, path)
    return cand if os.path.exists(cand) else path


@dataclass
class FleetEpoch:
    """One rebalancing window across the whole fleet."""
    epoch: int
    result: LoadSimResult            # merged across cells (exact arrays)
    cell_results: List[Optional[LoadSimResult]]
    router_stats: Dict[str, float]   # summed across cells
    n_assigned: np.ndarray           # (C,) requests served per cell
    n_spilled: int
    load_ms: np.ndarray              # (C,) load signal the plan used


@dataclass
class FleetResult:
    """A full fleet run: per-epoch merged results plus fleet headlines."""
    scenario: Scenario
    epochs: List[FleetEpoch] = field(default_factory=list)

    @property
    def n_cells(self) -> int:
        fl = self.scenario.deployment.fleet
        return fl.n_cells if fl is not None else 1

    @property
    def n_arrived(self) -> int:
        return sum(e.result.n_arrived for e in self.epochs)

    @property
    def n_completed(self) -> int:
        return sum(e.result.n_completed for e in self.epochs)

    @property
    def n_spilled(self) -> int:
        return sum(e.n_spilled for e in self.epochs)

    @property
    def spill_rate(self) -> float:
        return self.n_spilled / max(self.n_arrived, 1)

    @property
    def locality(self) -> float:
        """Fraction of requests served by their home cell."""
        return 1.0 - self.spill_rate

    @property
    def sla_attainment(self) -> float:
        return self._pooled("sla_attainment", "n_arrived")

    @property
    def mean_accuracy(self) -> float:
        return self._pooled("mean_accuracy", "n_completed")

    @property
    def mean_latency(self) -> float:
        return self._pooled("mean_latency", "n_completed")

    @property
    def mean_queue_wait(self) -> float:
        return self._pooled("mean_queue_wait", "n_completed")

    def _pooled(self, attr: str, weight: str) -> float:
        n = sum(getattr(e.result, weight) for e in self.epochs)
        return sum(getattr(e.result, attr) * getattr(e.result, weight)
                   for e in self.epochs) / max(n, 1)

    def as_scenario_result(self):
        """Adapt to :class:`~repro.scenario.build.ScenarioResult` so
        every ScenarioResult consumer (the benchmark suite, frontier
        scripts) reads a fleet run unchanged."""
        from repro.scenario.build import EpochResult, ScenarioResult
        fl = self.scenario.deployment.fleet
        n_rep = sum((c.replicas or self.scenario.deployment.replicas)
                    for c in fl.cells) if fl is not None else \
            self.scenario.deployment.replicas
        out = ScenarioResult(scenario=self.scenario, fleet=self)
        for e in self.epochs:
            out.epochs.append(EpochResult(
                epoch=e.epoch, n_replicas=n_rep, result=e.result,
                router_stats=dict(e.router_stats)))
        return out


class FleetEngine:
    """Run one fleet scenario end to end."""

    def __init__(self, scenario: Scenario, *, mesh=None):
        fleet = scenario.deployment.fleet
        if fleet is None:
            raise ValueError(f"scenario {scenario.name!r} has no FleetSpec")
        self.scenario = scenario
        self.fleet: FleetSpec = fleet
        self.mesh = mesh
        self.frontend = FleetFrontend(scenario)
        self.cells = [cell_view(scenario, c) for c in fleet.cells]
        self.gamma = float(scenario.policy.kwargs.get("gamma", 1.0))

    # -- arrival synthesis ---------------------------------------------
    def _cell_times(self, c: int, n_c: int, share: float) -> np.ndarray:
        """Cell ``c``'s arrival timestamps: its weighted share of the
        fleet rate, shaped by the trace/diurnal profile at the cell's
        time-zone phase."""
        sc, wl = self.scenario, self.scenario.workload
        cell = self.fleet.cells[c]
        seed = (sc.seed ^ _FLEET_TRACE_SALT) + _CELL_SEED_STRIDE * c
        rate = max(wl.rate_rps * share, 1e-9)
        if self.fleet.trace_path:
            tr = load_trace(_resolve_trace_path(self.fleet.trace_path),
                            n=n_c, rate_rps=rate, period_ms=wl.period_ms,
                            phase=cell.phase, seed=seed)
            return np.asarray(tr.times_ms)
        if wl.arrival == "diurnal":
            tr = diurnal_trace(n_c, rate, period_ms=wl.period_ms,
                               amplitude=wl.amplitude,
                               phase=2.0 * np.pi * cell.phase, seed=seed)
            return np.asarray(tr.times_ms)
        # poisson: render the stream up front so it slices into epochs
        rng = np.random.default_rng(seed)
        return np.cumsum(rng.exponential(1000.0 / rate, size=n_c))

    def _cap_rps(self, stacked) -> np.ndarray:
        """Analytic per-cell capacity prior in req/s from the pooled
        profiles: per_model topology runs every variant on its own
        replica set, so rates add (Σ replicas/μ); shared topologies get
        the uniform-mix rate.  Observed throughput refines this upward
        (e.g. when load skews picks toward fast variants)."""
        mu = np.asarray(stacked.mu, dtype=np.float64)   # (C, npad)
        cap = np.empty(self.fleet.n_cells, dtype=np.float64)
        for c in range(self.fleet.n_cells):
            m = mu[c][mu[c] < 1e29]       # drop PAD_MU sentinels
            if m.size == 0:
                cap[c] = np.inf           # no profiles yet: unknown
                continue
            dep = self.cells[c].deployment
            rep = max(dep.replicas, 1)
            rates = 1000.0 / m            # req/s per dedicated replica
            cap[c] = rep * (rates.sum()
                            if dep.topology in ("", "per_model")
                            else rates.mean())
        return cap

    # -- passthrough parity ----------------------------------------------
    def _is_passthrough(self) -> bool:
        return (self.fleet.n_cells == 1 and not self.fleet.trace_path
                and self.scenario.workload.arrival in ("poisson",
                                                       "closed_loop"))

    # -- execution -------------------------------------------------------
    def run(self) -> FleetResult:
        if self._is_passthrough():
            return self._run_passthrough()
        return self._run_fleet()

    def _run_passthrough(self) -> FleetResult:
        """1-cell, generative arrivals: execute on the ordinary
        single-cell harness path — bit-identical (pick for pick, shed
        for shed) to the same scenario without a FleetSpec."""
        from repro.scenario.build import ScenarioHarness
        sr = ScenarioHarness(self.scenario).run()
        out = FleetResult(scenario=self.scenario)
        C = 1
        for ep in sr.epochs:
            out.epochs.append(FleetEpoch(
                epoch=ep.epoch, result=ep.result,
                cell_results=[ep.result],
                router_stats=dict(ep.router_stats),
                n_assigned=np.array([ep.result.n_arrived]),
                n_spilled=0, load_ms=np.zeros(C)))
        return out

    def _run_fleet(self) -> FleetResult:
        from repro.scenario.build import build_engine, build_policy
        from repro.scenario.build import ScenarioHarness

        sc, fleet = self.scenario, self.fleet
        wl = sc.workload
        C = fleet.n_cells
        n = wl.n_requests
        rids = np.arange(n, dtype=np.int64)
        home = self.frontend.home_of_requests(rids)

        # Per-cell arrival timelines, written back into one global
        # times[] column (request i arrives at its home cell's clock).
        w = np.array([c.weight for c in fleet.cells], dtype=np.float64)
        share = w / w.sum()
        times = np.zeros(n, dtype=np.float64)
        for c in range(C):
            mask = home == c
            n_c = int(mask.sum())
            if n_c:
                times[mask] = np.sort(self._cell_times(c, n_c, share[c]))

        harnesses = [ScenarioHarness(cv) for cv in self.cells]
        stores = [h.store() for h in harnesses]
        policies = [build_policy(cv) for cv in self.cells]

        horizon = float(times.max())
        n_epochs = int(horizon // fleet.epoch_ms) + 1
        load = np.zeros(C, dtype=np.float64)
        tput_rps = np.zeros(C, dtype=np.float64)  # observed peak service rate
        out = FleetResult(scenario=sc)

        for e in range(n_epochs):
            t0 = e * fleet.epoch_ms
            emask = (times >= t0) & (times < t0 + fleet.epoch_ms)
            erids = rids[emask]
            if erids.size == 0:
                continue
            etimes = times[emask]
            stacked = stack_cell_tables([s.table() for s in stores])
            plan_load = load.copy()
            cap_req = np.maximum(self._cap_rps(stacked), tput_rps) \
                * fleet.epoch_ms / 1000.0
            plan = self.frontend.plan(
                erids, plan_load, stacked, cap_req=cap_req,
                gamma=self.gamma,
                seed=sc.seed + _PLAN_SEED_STRIDE * e, mesh=self.mesh)

            cell_results: List[Optional[LoadSimResult]] = [None] * C
            n_assigned = np.zeros(C, dtype=np.int64)
            merged = _EpochMerger()
            for c in range(C):
                cmask = plan.assigned == c
                n_assigned[c] = int(cmask.sum())
                if not n_assigned[c]:
                    load[c] *= 0.5   # idle window: decay, don't forget
                    continue
                order = np.argsort(etimes[cmask], kind="stable")
                ctimes = etimes[cmask][order]
                extra = plan.rtt_extra_ms[cmask][order] / 2.0
                eng = build_engine(
                    self.cells[c],
                    seed=sc.seed + _CELL_SEED_STRIDE * c + e)
                res = eng.run(policies[c], wl.t_sla_ms, int(n_assigned[c]),
                              arrivals=TraceArrivals(ctimes - t0),
                              store=stores[c],
                              extra_input_for=extra)
                cell_results[c] = res
                merged.add(eng, res, fleet.cells[c].name)
                # Queues drain at epoch boundaries, so last window's
                # mean wait overstates next-window congestion; damp it
                # (EWMA) instead of chasing it raw.
                load[c] = 0.5 * load[c] + 0.5 * res.mean_queue_wait
                tput_rps[c] = max(
                    tput_rps[c],
                    res.n_completed / max(res.horizon_ms / 1000.0, 1e-9))
            out.epochs.append(FleetEpoch(
                epoch=e, result=merged.result(wl.t_sla_ms),
                cell_results=cell_results,
                router_stats=merged.router_stats,
                n_assigned=n_assigned,
                n_spilled=plan.n_spilled,
                load_ms=plan_load))
        return out


class _EpochMerger:
    """Exact cross-cell merge of one epoch: concatenates the cells' raw
    completion columns so percentiles and means are computed over the
    union, not averaged from per-cell summaries."""

    def __init__(self):
        self.e2e: List[np.ndarray] = []
        self.wait: List[np.ndarray] = []
        self.acc: List[np.ndarray] = []
        self.met = 0
        self.n_arrived = 0
        self.n_completed = 0
        self.n_rejected = 0
        self.n_retries = 0
        self.peak_depth = 0
        self.horizon = 1e-9
        self.usage: Dict[str, float] = {}
        self.util: Dict[str, float] = {}
        self.router_stats: Dict[str, float] = {}
        self._batch_sum = 0.0
        self._policy = ""

    def add(self, eng, res: LoadSimResult, cell_name: str) -> None:
        self._policy = res.policy
        cols = eng._cols
        ci = np.asarray(eng._completed_rids, dtype=np.int64)
        if len(ci):
            t_in = cols.t_input[ci]
            wait = cols.sstart[ci] - cols.enqueue[ci]
            e2e = 2.0 * t_in + wait + cols.service[ci]
            self.met += int((e2e <= cols.t_sla[ci]).sum())
            acc_by_id = np.array([en.top1 / 100.0 for en in eng.entries])
            self.e2e.append(e2e)
            self.wait.append(wait)
            self.acc.append(acc_by_id[cols.model[ci]])
        self.n_arrived += res.n_arrived
        self.n_completed += res.n_completed
        self.n_rejected += res.n_rejected
        self.n_retries += res.n_retries
        self.peak_depth = max(self.peak_depth, res.peak_queue_depth)
        self.horizon = max(self.horizon, res.horizon_ms)
        for name, frac in res.model_usage.items():
            self.usage[name] = self.usage.get(name, 0.0) \
                + frac * res.n_completed
        for name, u in res.replica_utilization.items():
            self.util[f"{cell_name}/{name}"] = u
        stats = eng.router.stats() if eng.router is not None else {}
        for k, v in stats.items():
            if k == "mean_batch":
                self._batch_sum += v * stats.get("n_batches", 0)
            elif isinstance(v, (int, float)):
                self.router_stats[k] = self.router_stats.get(k, 0) + v

    def result(self, t_sla: float) -> LoadSimResult:
        nb = self.router_stats.get("n_batches", 0)
        if nb:
            self.router_stats["mean_batch"] = self._batch_sum / nb
        if not self.n_completed:
            return LoadSimResult(
                policy=self._policy, t_sla=t_sla,
                n_arrived=self.n_arrived, n_completed=0,
                n_rejected=self.n_rejected, sla_attainment=0.0,
                mean_accuracy=0.0, mean_latency=0.0, p50_latency=0.0,
                p99_latency=0.0, mean_queue_wait=0.0, p99_queue_wait=0.0,
                peak_queue_depth=self.peak_depth, model_usage={},
                replica_utilization=dict(self.util),
                horizon_ms=self.horizon, n_retries=self.n_retries)
        e2e = np.concatenate(self.e2e)
        wait = np.concatenate(self.wait)
        acc = np.concatenate(self.acc)
        return LoadSimResult(
            policy=self._policy, t_sla=t_sla,
            n_arrived=self.n_arrived, n_completed=self.n_completed,
            n_rejected=self.n_rejected,
            sla_attainment=self.met / max(self.n_arrived, 1),
            mean_accuracy=float(acc.mean()),
            mean_latency=float(e2e.mean()),
            p50_latency=float(np.percentile(e2e, 50)),
            p99_latency=float(np.percentile(e2e, 99)),
            p95_latency=float(np.percentile(e2e, 95)),
            mean_queue_wait=float(wait.mean()),
            p99_queue_wait=float(np.percentile(wait, 99)),
            p95_queue_wait=float(np.percentile(wait, 95)),
            peak_queue_depth=self.peak_depth,
            model_usage={k: v / self.n_completed
                         for k, v in sorted(self.usage.items())},
            replica_utilization=dict(self.util),
            horizon_ms=self.horizon,
            n_retries=self.n_retries)
