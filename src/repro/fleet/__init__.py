"""Sharded multi-cell fleet simulation.

``FleetSpec`` (cells + inter-cell network) rides on the Scenario API;
``FleetFrontend`` places requests (sticky hashing, honest spill);
``FleetEngine`` steps the per-cell serving simulators on a shared
rebalancing clock; ``fleet.device`` runs all cells' selection batches
as one (cell × batch × pool) device call.
"""
from repro.fleet.device import StackedPools, select_fleet, stack_cell_tables
from repro.fleet.engine import (FleetEngine, FleetEpoch, FleetResult,
                                cell_view)
from repro.fleet.frontend import FleetFrontend, SpillPlan
from repro.fleet.spec import CellSpec, FleetSpec

__all__ = [
    "CellSpec", "FleetSpec", "FleetFrontend", "SpillPlan", "FleetEngine",
    "FleetEpoch", "FleetResult", "cell_view", "StackedPools",
    "stack_cell_tables", "select_fleet",
]
