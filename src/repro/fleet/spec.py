"""Fleet specs: the multi-cell layer over the Scenario API.

A *fleet* is N serving cells (regions / availability zones), each a
complete single-cell deployment — its own zoo subset, replica topology
and mobile-uplink model — joined by an inter-cell network with a known
round-trip time.  :class:`FleetSpec` rides on
:class:`~repro.scenario.spec.DeploymentSpec` as an optional field, so a
fleet scenario is an ordinary :class:`~repro.scenario.spec.Scenario`
that still round-trips through plain dicts / JSON / TOML; single-cell
dicts (no ``fleet`` key) are untouched.

Per-cell knobs default to "inherit from the scenario" (empty subset /
topology, zero replicas, ``network=None``), so the common case — a
homogeneous fleet — is just a list of names with weights and time-zone
phases.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.scenario.spec import NetworkSpec, TOPOLOGIES, _require


@dataclass(frozen=True)
class CellSpec:
    """One serving cell.

    ``weight`` sets the share of the user population whose sticky hash
    lands here; ``phase`` ∈ [0, 1) offsets this cell's diurnal load by a
    fraction of the trace day (its time zone).  ``subset`` / ``topology``
    / ``replicas`` / ``network`` override the scenario-level deployment
    when non-empty / non-zero / non-None — a fleet can mix a big cell
    running the full zoo with edge cells holding only the fast variants.
    """
    name: str
    weight: float = 1.0
    phase: float = 0.0
    subset: Tuple[str, ...] = ()     # () = scenario's subset
    topology: str = ""               # "" = scenario's topology
    replicas: int = 0                # 0 = scenario's replica count
    network: Optional[NetworkSpec] = None  # None = scenario's uplink

    def __post_init__(self):
        _require(bool(self.name), "CellSpec needs a non-empty name")
        _require(self.weight > 0.0,
                 f"cell {self.name!r}: weight must be positive")
        _require(0.0 <= self.phase < 1.0,
                 f"cell {self.name!r}: phase must be in [0, 1), "
                 f"got {self.phase}")
        _require(self.topology in ("",) + TOPOLOGIES,
                 f"cell {self.name!r}: topology must be '' (inherit) or "
                 f"one of {TOPOLOGIES}, got {self.topology!r}")
        _require(self.replicas >= 0,
                 f"cell {self.name!r}: replicas must be >= 0 (0 inherits)")


@dataclass(frozen=True)
class FleetSpec:
    """The fleet: cells plus the inter-cell network and spill policy.

    ``rtt_ms`` is the inter-cell round trip a spilled request pays on
    top of its mobile uplink; the frontend judges the remote budget as
    ``T_sla − 2·T_input − RTT_xcell − W_queue(m)``, so spilling is never
    silently optimistic.  ``spill_threshold_ms``: also consider spilling
    (not only when the home cell has *no* viable variant) once the home
    cell's load signal exceeds this queue-wait level; 0 keeps the
    conservative no-viable-variant-only trigger.  ``epoch_ms`` is the
    shared rebalancing clock of the fleet engine; ``n_users`` the sticky
    user population; ``trace_path`` an optional Azure-Functions-style
    rate trace (CSV/JSON) replayed per cell at its ``phase`` offset.
    """
    cells: Tuple[CellSpec, ...] = (CellSpec("cell0"),)
    rtt_ms: float = 40.0
    spill: bool = True
    spill_threshold_ms: float = 0.0
    n_users: int = 10_000
    epoch_ms: float = 10_000.0
    trace_path: str = ""

    def __post_init__(self):
        if self.cells and not isinstance(self.cells, tuple):
            object.__setattr__(self, "cells", tuple(self.cells))
        _require(len(self.cells) >= 1, "FleetSpec needs at least one cell")
        names = [c.name for c in self.cells]
        _require(len(names) == len(set(names)),
                 f"duplicate cell names: {names}")
        _require(self.rtt_ms >= 0.0, "rtt_ms must be non-negative")
        _require(self.spill_threshold_ms >= 0.0,
                 "spill_threshold_ms must be non-negative")
        _require(self.n_users >= 1, "n_users must be >= 1")
        _require(self.epoch_ms > 0.0, "epoch_ms must be positive")

    @property
    def n_cells(self) -> int:
        return len(self.cells)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FleetSpec":
        """Inverse of the ``dataclasses.asdict`` form embedded in
        ``Scenario.to_dict()``."""
        d = dict(d)
        cells = []
        for c in d.get("cells", ()):
            c = dict(c)
            if c.get("network") is not None:
                c["network"] = NetworkSpec(**c["network"])
            if "subset" in c:
                c["subset"] = tuple(c["subset"])
            cells.append(CellSpec(**c))
        if cells:
            d["cells"] = tuple(cells)
        else:
            d.pop("cells", None)
        return cls(**d)
