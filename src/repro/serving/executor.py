"""Pool executor: the live serving path, as a thin execution shell
around the unified ``repro.router.Router``.

Per request: simulate the mobile uplink (the paper's measured WiFi/LTE
distributions), hand the request to the Router (admission verdict,
Eq. 1 budget, queue-aware shifted view, policy selection), run real
prefill+decode on the chosen pool member, feed the measured wall time
back into the EWMA profiles, and score the SLA against the request's own
``t_sla`` — per-request SLA mixes need no special casing.

Straggler mitigation (execution-shell concerns, deliberately *not* in
the Router):
- primary: ModiPick's σ-aware probabilistic routing (a straggling variant
  sees its σ inflate and its selection probability collapse smoothly);
- secondary: hedged re-issue — when a request exceeds μ + hedge_k·σ of its
  variant's profile, it is re-issued on the fastest variant and the
  effective latency is min(straggler, detect + fast) (standard
  tail-at-scale hedging, emulated single-process).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.core.policy import Policy
from repro.core.profiles import ModelProfile, ProfileStore
from repro.router import AdmissionController, InferenceRequest, Router
from repro.serving.pool import Variant


@dataclass
class RequestResult:
    variant: str
    t_input_ms: float
    t_infer_ms: float
    t_e2e_ms: float
    t_sla_ms: float
    met_sla: bool
    quality: float
    hedged: bool = False
    w_queue_ms: float = 0.0     # queue-wait estimate charged at selection
    admitted: bool = True       # False: shed by router-side admission
    reject_reason: str = ""


@dataclass
class PoolExecutor:
    variants: List[Variant]
    network: NetworkModel
    policy: Policy
    seed: int = 0
    warmup_requests: int = 3
    hedge_k: float = 6.0        # hedge when t > μ + k·σ
    hedging: bool = False
    alpha: float = 0.2
    # queue-aware routing: budget becomes T_sla − 2·T_input − W_queue(m),
    # with W_queue from per-variant in-flight work + batcher telemetry
    # (or an injected estimator, e.g. a load-emulation model).
    queue_aware: bool = False
    w_queue_fn: Optional[Callable[[str], float]] = None
    # router-side admission control (None = admit everything)
    admission: Optional[AdmissionController] = None
    # policy_vec backend override for batched selection
    backend: Optional[str] = None

    @classmethod
    def from_scenario(cls, scenario, variants: List[Variant],
                      **overrides) -> "PoolExecutor":
        """Adapter: build the live execution shell from a declarative
        :class:`repro.scenario.Scenario` — the scenario supplies the
        network/policy/admission/queue-aware surface, the caller supplies
        the real model pool (``variants``)."""
        from repro.scenario.build import build_executor
        return build_executor(scenario, variants, **overrides)

    def __post_init__(self):
        self.by_name: Dict[str, Variant] = {v.name: v for v in self.variants}
        self.store = ProfileStore(
            [ModelProfile(name=v.name, accuracy=v.quality) for v in self.variants],
            alpha=self.alpha)
        self.router = Router(self.store, self.policy,
                             admission=self.admission,
                             queue_aware=self.queue_aware,
                             backend=self.backend)
        self.rng = np.random.default_rng(self.seed)
        self.results: List[RequestResult] = []

    def w_queue(self, name: str) -> float:
        """W_queue(m) estimate for variant ``name``."""
        if self.w_queue_fn is not None:
            return float(self.w_queue_fn(name))
        v = self.by_name[name]
        prof = self.store[name]
        if hasattr(v, "estimated_wait_ms"):
            return v.estimated_wait_ms(prof)
        return prof.queue_mu

    def warm_up(self, tokens: np.ndarray, n_decode: int = 2):
        """Paper §4: warm every model (compile + build profiles).  The
        first run per variant is the JIT compile and is discarded."""
        for v in self.variants:
            v.run(tokens, n_decode)  # compile; not a latency sample
            for _ in range(self.warmup_requests):
                ms = v.run(tokens, n_decode)
                self.store.observe(v.name, ms)

    def execute(self, tokens: np.ndarray, t_sla: float,
                n_decode: int = 2) -> RequestResult:
        t_input = float(self.network.sample(self.rng, 1)[0])
        request = InferenceRequest(rid=len(self.results), t_sla_ms=t_sla,
                                   t_input_ms=t_input)
        dec = self.router.route(request, self.rng, w_queue_fn=self.w_queue)
        if not dec.admitted:
            # Shed before any model ran: the downlink never happens, but
            # the uplink was already spent — charge it and score a miss.
            res = RequestResult(
                variant="", t_input_ms=t_input, t_infer_ms=0.0,
                t_e2e_ms=t_input, t_sla_ms=t_sla, met_sla=False,
                quality=0.0, w_queue_ms=dec.budget.w_queue_ms,
                admitted=False, reject_reason=dec.reject_reason)
            self.results.append(res)
            return res
        name = dec.variant
        v = self.by_name[name]
        v.inflight = getattr(v, "inflight", 0) + 1
        try:
            t_infer = v.run(tokens, n_decode)
        finally:
            v.inflight -= 1
        hedged = False
        prof = self.store[name]
        if self.hedging and prof.n_obs > 3 and \
                t_infer > prof.mu + self.hedge_k * prof.sigma:
            # re-issue on the fastest variant; overlap from detection point
            fast = min(self.store.profiles.values(), key=lambda p: p.mu)
            if fast.name != name:
                detect = prof.mu + self.hedge_k * prof.sigma
                t2 = self.by_name[fast.name].run(tokens, n_decode)
                t_infer = min(t_infer, detect + t2)
                hedged = True
        self.store.observe(name, t_infer)
        e2e = 2.0 * t_input + t_infer
        res = RequestResult(
            variant=name, t_input_ms=t_input, t_infer_ms=t_infer,
            t_e2e_ms=e2e, t_sla_ms=t_sla, met_sla=e2e <= t_sla,
            quality=v.quality, hedged=hedged,
            w_queue_ms=dec.budget.w_queue_ms)
        self.results.append(res)
        return res

    # ------------------------------------------------------------------
    def summary(self) -> Dict:
        if not self.results:
            return {}
        rs = self.results
        served = [r for r in rs if r.admitted]
        usage: Dict[str, int] = {}
        for r in served:
            usage[r.variant] = usage.get(r.variant, 0) + 1
        e2e = [r.t_e2e_ms for r in served]
        return {
            "n": len(rs),
            # shed requests count as SLA misses (met_sla is False);
            # latency/quality stats cover served requests, zero (like the
            # simulator's empty summary) when everything was shed
            "sla_attainment": sum(r.met_sla for r in rs) / len(rs),
            "mean_quality": float(np.mean([r.quality for r in served]))
            if served else 0.0,
            "mean_latency_ms": float(np.mean(e2e)) if served else 0.0,
            "p95_latency_ms": float(np.percentile(e2e, 95)) if served else 0.0,
            "p99_latency_ms": float(np.percentile(e2e, 99)) if served else 0.0,
            "hedged": sum(r.hedged for r in rs),
            "shed": len(rs) - len(served),
            "usage": {k: v / len(served) for k, v in sorted(usage.items())},
        }
