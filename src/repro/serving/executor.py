"""Pool executor + ModiPick router: the live serving path.

Per request: simulate the mobile uplink (the paper's measured WiFi/LTE
distributions), compute the budget (Eq. 1), let the policy pick a variant,
run real prefill+decode on the pool member, feed the measured wall time
back into the EWMA profiles, and score the SLA.

Straggler mitigation:
- primary: ModiPick's σ-aware probabilistic routing (a straggling variant
  sees its σ inflate and its selection probability collapse smoothly);
- secondary: hedged re-issue — when a request exceeds μ + hedge_k·σ of its
  variant's profile, it is re-issued on the fastest variant and the
  effective latency is min(straggler, detect + fast) (standard
  tail-at-scale hedging, emulated single-process).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.core.policy import Policy, budget
from repro.core.profiles import ModelProfile, ProfileStore
from repro.serving.pool import Variant


@dataclass
class RequestResult:
    variant: str
    t_input_ms: float
    t_infer_ms: float
    t_e2e_ms: float
    t_sla_ms: float
    met_sla: bool
    quality: float
    hedged: bool = False
    w_queue_ms: float = 0.0     # queue-wait estimate charged at selection


@dataclass
class PoolExecutor:
    variants: List[Variant]
    network: NetworkModel
    policy: Policy
    seed: int = 0
    warmup_requests: int = 3
    hedge_k: float = 6.0        # hedge when t > μ + k·σ
    hedging: bool = False
    alpha: float = 0.2
    # queue-aware routing: budget becomes T_sla − 2·T_input − W_queue(m),
    # with W_queue from per-variant in-flight work + batcher telemetry
    # (or an injected estimator, e.g. a load-emulation model).
    queue_aware: bool = False
    w_queue_fn: Optional[Callable[[str], float]] = None

    def __post_init__(self):
        self.by_name: Dict[str, Variant] = {v.name: v for v in self.variants}
        self.store = ProfileStore(
            [ModelProfile(name=v.name, accuracy=v.quality) for v in self.variants],
            alpha=self.alpha)
        self.rng = np.random.default_rng(self.seed)
        self.results: List[RequestResult] = []
        self._qa = None
        if self.queue_aware:
            # lazy: the live path only depends on repro.sim when the
            # queue-aware feature is actually enabled
            from repro.sim.queueaware import QueueAwareSelector
            self._qa = QueueAwareSelector(self.policy)

    def w_queue(self, name: str) -> float:
        """W_queue(m) estimate for variant ``name``."""
        if self.w_queue_fn is not None:
            return float(self.w_queue_fn(name))
        v = self.by_name[name]
        prof = self.store[name]
        if hasattr(v, "estimated_wait_ms"):
            return v.estimated_wait_ms(prof)
        return prof.queue_mu

    def warm_up(self, tokens: np.ndarray, n_decode: int = 2):
        """Paper §4: warm every model (compile + build profiles).  The
        first run per variant is the JIT compile and is discarded."""
        for v in self.variants:
            v.run(tokens, n_decode)  # compile; not a latency sample
            for _ in range(self.warmup_requests):
                ms = v.run(tokens, n_decode)
                self.store.observe(v.name, ms)

    def execute(self, tokens: np.ndarray, t_sla: float,
                n_decode: int = 2) -> RequestResult:
        t_input = float(self.network.sample(self.rng, 1)[0])
        t_budget = budget(t_sla, t_input)
        w_queue = 0.0
        if self.queue_aware:
            name = self._qa.select(self.store, t_budget, self.w_queue,
                                   self.rng)
            w_queue = self.w_queue(name)
        else:
            name = self.policy.select(self.store, t_budget, self.rng)
        self.store.mark_selected(name)
        v = self.by_name[name]
        v.inflight = getattr(v, "inflight", 0) + 1
        try:
            t_infer = v.run(tokens, n_decode)
        finally:
            v.inflight -= 1
        hedged = False
        prof = self.store[name]
        if self.hedging and prof.n_obs > 3 and \
                t_infer > prof.mu + self.hedge_k * prof.sigma:
            # re-issue on the fastest variant; overlap from detection point
            fast = min(self.store.profiles.values(), key=lambda p: p.mu)
            if fast.name != name:
                detect = prof.mu + self.hedge_k * prof.sigma
                t2 = self.by_name[fast.name].run(tokens, n_decode)
                t_infer = min(t_infer, detect + t2)
                hedged = True
        self.store.observe(name, t_infer)
        e2e = 2.0 * t_input + t_infer
        res = RequestResult(
            variant=name, t_input_ms=t_input, t_infer_ms=t_infer,
            t_e2e_ms=e2e, t_sla_ms=t_sla, met_sla=e2e <= t_sla,
            quality=v.quality, hedged=hedged, w_queue_ms=w_queue)
        self.results.append(res)
        return res

    # ------------------------------------------------------------------
    def summary(self) -> Dict:
        if not self.results:
            return {}
        rs = self.results
        usage: Dict[str, int] = {}
        for r in rs:
            usage[r.variant] = usage.get(r.variant, 0) + 1
        return {
            "n": len(rs),
            "sla_attainment": sum(r.met_sla for r in rs) / len(rs),
            "mean_quality": float(np.mean([r.quality for r in rs])),
            "mean_latency_ms": float(np.mean([r.t_e2e_ms for r in rs])),
            "p99_latency_ms": float(np.percentile([r.t_e2e_ms for r in rs], 99)),
            "hedged": sum(r.hedged for r in rs),
            "usage": {k: v / len(rs) for k, v in sorted(usage.items())},
        }
