"""Live model pool: JAX-served variants exposing accuracy/latency
trade-offs (the LLM analogue of the paper's CNN zoo).

Each variant owns compiled prefill/decode functions; ``scaled_family``
builds a pool from one architecture at several widths/depths — e.g.
qwen2-family at 0.25×/0.5×/1× — exactly the MobileNet-vs-Inception
spectrum ModiPick exploits.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api, model as M


@dataclass
class Variant:
    name: str
    cfg: ModelConfig
    quality: float
    params: object = None
    prefill_fn: Callable = None
    decode_fn: Callable = None
    cache_len: int = 128
    inflight: int = 0           # requests dispatched but not finished

    def estimated_wait_ms(self, profile) -> float:
        """Queue-wait estimate for one more request on this variant.
        The two signals overlap — observed queue waits (queue_mu, see
        ProfileStore.observe_queue) already include time spent behind
        in-flight work — so take the max rather than the sum."""
        return max(self.inflight * max(profile.mu, 0.0), profile.queue_mu)

    def build(self, key, dtype=jnp.float32):
        self.params = M.init_params(self.cfg, key, dtype)
        cache_len = self.cache_len

        @jax.jit
        def prefill_fn(params, tokens):
            return M.prefill(self.cfg, params, {"tokens": tokens}, cache_len)

        @jax.jit
        def decode_fn(params, cache, tok, pos):
            return M.decode_step(self.cfg, params, cache, tok, pos)

        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        return self

    def run(self, tokens: np.ndarray, n_decode: int = 4) -> float:
        """Execute prefill + n_decode steps; returns wall ms (blocking)."""
        t0 = time.perf_counter()
        tok = jnp.asarray(tokens)
        cache, logits = self.prefill_fn(self.params, tok)
        B, S = tokens.shape
        pos = jnp.full((B,), S, jnp.int32)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(n_decode):
            logits_d, cache = self.decode_fn(self.params, cache, nxt, pos)
            nxt = jnp.argmax(logits_d, axis=-1).astype(jnp.int32)
            pos = pos + 1
        jax.block_until_ready(logits_d)
        return (time.perf_counter() - t0) * 1e3


def scaled_family(base: ModelConfig, *, widths=(0.25, 0.5, 1.0),
                  qualities=None, seed: int = 0,
                  cache_len: int = 128) -> List[Variant]:
    """Build a pool of width-scaled variants of one family."""
    reduced = base.reduced()
    out = []
    key = jax.random.PRNGKey(seed)
    for i, w in enumerate(widths):
        cfg = reduced.scaled(w, name=f"{base.name}-w{w:g}")
        q = qualities[i] if qualities else base.quality * (0.6 + 0.4 * w)
        key, k = jax.random.split(key)
        v = Variant(name=cfg.name, cfg=cfg, quality=q, cache_len=cache_len)
        v.build(k)
        out.append(v)
    return out
