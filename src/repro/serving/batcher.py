"""Continuous batching: slot-based decode over a shared KV/state pool.

The engine keeps a fixed decode batch of `max_slots` sequences.  New
requests are prefilled (batch-1) and inserted into free slots; every
engine step runs ONE batched `decode_step` with per-slot positions (the
cache machinery supports per-request `pos` natively — ring buffers,
SSM/RG-LRU states and cross caches are all slot-isolated).  Finished
sequences retire and free their slot immediately — no head-of-line
blocking on long generations (Orca-style continuous batching).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import axes_tree
from repro.models.model import cache_template


@dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int
    arrival_s: float = field(default_factory=time.perf_counter)
    generated: List[int] = field(default_factory=list)
    done: bool = False
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    queue_wait_s: Optional[float] = None  # submit → slot insert


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 cache_len: int = 256, eos_id: Optional[int] = None,
                 dtype=jnp.float32, store=None, model_name: str = ""):
        # ``store``: optional repro.core.profiles.ProfileStore — queue
        # waits observed here feed W_queue(m) for queue-aware selection.
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.store = store
        self.model_name = model_name or cfg.name
        self.cache = M.init_cache(cfg, max_slots, cache_len, dtype)
        # batch-dim index per cache leaf (stacked leaves lead with 'layers')
        self._batch_dims = jax.tree.leaves(jax.tree.map(
            lambda ax: ax.index("batch"),
            axes_tree(cache_template(cfg, max_slots, cache_len)),
            is_leaf=lambda v: isinstance(v, tuple) and all(
                isinstance(e, (str, type(None))) for e in v)))
        self.slots: List[Optional[GenRequest]] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        self.next_tok = np.zeros(max_slots, np.int32)
        self.waiting: List[GenRequest] = []
        self.n_steps = 0

        self._decode = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
        self._prefill = jax.jit(
            lambda p, batch: M.prefill(cfg, p, batch, cache_len))

    # ------------------------------------------------------------------
    def submit(self, req: GenRequest) -> None:
        # queue wait is measured from here, not from request construction
        req.arrival_s = time.perf_counter()
        self.waiting.append(req)

    def _insert_slot(self, slot: int, req: GenRequest) -> None:
        req.queue_wait_s = time.perf_counter() - req.arrival_s
        if self.store is not None:
            self.store.observe_queue(self.model_name,
                                     req.queue_wait_s * 1e3)
        tokens = jnp.asarray(req.prompt[None, :])
        cache1, logits = self._prefill(self.params, {"tokens": tokens})

        def insert(pool, one, bdim):
            idx = (slice(None),) * bdim + (slice(slot, slot + 1),)
            return pool.at[idx].set(one.astype(pool.dtype))

        flat_pool, treedef = jax.tree.flatten(self.cache)
        flat_one = treedef.flatten_up_to(cache1)
        self.cache = treedef.unflatten(
            [insert(p, o, b) for p, o, b in
             zip(flat_pool, flat_one, self._batch_dims)])
        self.slots[slot] = req
        self.pos[slot] = len(req.prompt)
        tok = int(jnp.argmax(logits[0]))
        req.generated.append(tok)
        req.first_token_s = time.perf_counter()
        self.next_tok[slot] = tok

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slots[slot] is None and self.waiting:
                self._insert_slot(slot, self.waiting.pop(0))

    def _retire(self) -> None:
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            hit_eos = self.eos_id is not None and req.generated and \
                req.generated[-1] == self.eos_id
            if len(req.generated) >= req.max_new or hit_eos or \
                    int(self.pos[slot]) >= self.cache_len - 1:
                req.done = True
                req.finish_s = time.perf_counter()
                self.slots[slot] = None

    # ------------------------------------------------------------------
    def queue_depth(self) -> int:
        """Waiting + in-flight requests (the replica's FIFO depth)."""
        return len(self.waiting) + sum(r is not None for r in self.slots)

    def telemetry(self) -> Dict:
        """Queue-depth / queue-wait snapshot for the profile store."""
        waits = [r.queue_wait_s for r in self.slots
                 if r is not None and r.queue_wait_s is not None]
        return {
            "model": self.model_name,
            "queue_depth": self.queue_depth(),
            "waiting": len(self.waiting),
            "active": sum(r is not None for r in self.slots),
            "mean_queue_wait_ms":
                float(np.mean(waits)) * 1e3 if waits else 0.0,
        }

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine step. Returns False when fully idle."""
        self._admit()
        active = [s for s, r in enumerate(self.slots) if r is not None]
        if not active:
            return bool(self.waiting)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.next_tok),
            jnp.asarray(self.pos))
        toks = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for slot in active:
            req = self.slots[slot]
            req.generated.append(int(toks[slot]))
            self.next_tok[slot] = toks[slot]
            self.pos[slot] += 1
        self.n_steps += 1
        self._retire()
        return True

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step() and not self.waiting and \
                    all(s is None for s in self.slots):
                return
        raise RuntimeError("batcher did not drain")
