"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt

Full-size configs on the production mesh are exercised via dryrun.py (this
container is CPU-only); this launcher runs real steps on whatever devices
exist, with the same config/checkpoint machinery.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import TokenStream
from repro.training.loop import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(5, args.steps // 10),
                       grad_accum=args.grad_accum, seed=args.seed)
    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    loop = TrainLoop(cfg, tcfg, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, dtype=jnp.float32)

    def on_step(step, m):
        if step % 10 == 0:
            print(f"step {step:5d} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.3f} {m['step_time_s']*1e3:.0f}ms")

    final = loop.run(stream, args.steps, on_step=on_step)
    print("final:", {k: round(float(v), 4) for k, v in final.items()})


if __name__ == "__main__":
    main()
