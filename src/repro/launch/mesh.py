"""Production mesh builders.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e-256-like).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for debug runs (e.g. (2, 4) on 8 fake devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_axis(mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def data_axes(mesh):
    """The data-parallel axes present in this mesh ('pod' + 'data')."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
