"""Serving launcher: a live model pool behind a selection policy.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
      --policy modipick --requests 100 --sla-ms 120
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.netmodel import NetworkModel
from repro.core.policy import (DynamicGreedy, ModiPick, PureRandom,
                               RelatedAccurate, RelatedRandom, StaticGreedy)
from repro.serving.executor import PoolExecutor
from repro.serving.pool import scaled_family


def make_policy(name: str, sla: float, threshold: float, gamma: float):
    return {
        "modipick": lambda: ModiPick(threshold, gamma=gamma),
        "static_greedy": lambda: StaticGreedy(sla),
        "dynamic_greedy": lambda: DynamicGreedy(),
        "pure_random": lambda: PureRandom(),
        "related_random": lambda: RelatedRandom(threshold),
        "related_accurate": lambda: RelatedAccurate(threshold),
    }[name]()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--policy", default="modipick")
    ap.add_argument("--widths", default="0.5,1.0,2.0")
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--sla-ms", type=float, default=120.0)
    ap.add_argument("--threshold-ms", type=float, default=25.0)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--net-mean-ms", type=float, default=20.0)
    ap.add_argument("--net-cv", type=float, default=0.5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--hedging", action="store_true")
    args = ap.parse_args()

    variants = scaled_family(
        get_config(args.arch),
        widths=tuple(float(w) for w in args.widths.split(",")),
        cache_len=args.seq + 16)
    tokens = np.random.default_rng(0).integers(
        0, 500, (args.batch, args.seq), dtype=np.int32)
    net = NetworkModel.from_cv(args.net_mean_ms, args.net_cv)
    policy = make_policy(args.policy, args.sla_ms, args.threshold_ms, args.gamma)
    ex = PoolExecutor(variants, net, policy, hedging=args.hedging)
    ex.warm_up(tokens)
    for i in range(args.requests):
        r = ex.execute(tokens, t_sla=args.sla_ms)
        if i % 20 == 0:
            print(f"req {i:4d} -> {r.variant:24s} infer={r.t_infer_ms:6.1f}ms "
                  f"e2e={r.t_e2e_ms:6.1f}ms met={r.met_sla}")
    print(json.dumps(ex.summary(), indent=1))


if __name__ == "__main__":
    main()
