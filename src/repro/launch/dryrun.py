import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import — jax locks the device
# count at first init.  Debug override (still before jax import):
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and extract memory / cost / collective analyses.

This is the proof (without hardware) that the distribution config is
coherent: sharding mismatches, compile-time OOMs and unsupported
collectives all fail here.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh multi --out benchmarks/results
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import applicable_shapes, get_config
from repro.models import runtime_flags
from repro.distributed import hlo as hlo_mod
from repro.distributed.policy import make_rules
from repro.distributed.sharding import axis_rules, logical_to_spec
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import api
from repro.models.layers import abstract, axes_tree
from repro.models.model import cache_template, param_template
from repro.training.optimizer import OptState
from repro.training.train_step import make_train_step


def _shardings_for(template_axes, template_abs, rules, mesh):
    def one(ax, arr):
        spec = logical_to_spec(ax, rules, shape=arr.shape, mesh=mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, template_axes, template_abs,
                        is_leaf=lambda v: isinstance(v, tuple) and all(
                            isinstance(e, (str, type(None))) for e in v))


def _batch_shardings(specs, rules, mesh):
    out = {}
    for name, s in specs.items():
        if name in ("tokens", "targets"):
            ax = ("batch",) + (None,) * (len(s.shape) - 1)
        else:  # frames / image_embeds
            ax = ("batch",) + (None,) * (len(s.shape) - 1)
        out[name] = NamedSharding(mesh, logical_to_spec(ax, rules, shape=s.shape, mesh=mesh))
    return out


def _with_reps(cfg, reps: int):
    """Same arch at `reps` pattern repetitions (plus the original tail) —
    used by the scan-calibration builds."""
    n_tail = len(cfg.tail_kinds)
    return dataclasses.replace(
        cfg, n_layers=reps * len(cfg.pattern) + n_tail)


PAD_HEADS = int(os.environ.get("REPRO_PAD_HEADS", "0"))


def build_cell(arch: str, shape_name: str, mesh, overrides=None, cfg=None,
               grad_accum=None):
    """Returns (jitted_fn, example_args (abstract), rules)."""
    cfg = cfg or get_config(arch)
    if PAD_HEADS:
        cfg = cfg.with_padded_heads(PAD_HEADS)
    if os.environ.get("REPRO_KV_INT8"):
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    shape = SHAPES[shape_name]
    rules = make_rules(cfg, shape, mesh, overrides)
    dtype = jnp.bfloat16

    p_tmpl = param_template(cfg)
    p_abs = abstract(p_tmpl, dtype)
    p_axes = axes_tree(p_tmpl)
    p_shard = _shardings_for(p_axes, p_abs, rules, mesh)

    if shape.mode == "train":
        from repro.distributed.policy import TRAIN_OPT_MOMENTS, train_grad_accum
        from repro.training.optimizer import init_opt_state
        if grad_accum is None:
            grad_accum = train_grad_accum(arch, shape.global_batch, mesh)
        moments = TRAIN_OPT_MOMENTS.get(arch, "fp32")
        tcfg = TrainConfig(remat="full", grad_accum=grad_accum,
                           opt_moments=moments)
        step = make_train_step(cfg, tcfg)
        opt_abs = jax.eval_shape(
            lambda p: init_opt_state(p, moments), p_abs)
        if moments == "int8":
            # q shards like the param; the per-row scale drops the last dim
            def q8_shard(shard, with_lo=False):
                spec = shard.spec
                row = NamedSharding(mesh, P(*spec[:-1], None)) \
                    if len(spec) else shard
                out = {"q": shard, "scale": row}
                if with_lo:
                    out["lo"] = row
                return out
            is_ns = lambda v: isinstance(v, NamedSharding)
            opt_shard = OptState(
                step=NamedSharding(mesh, P()),
                mu=jax.tree.map(q8_shard, p_shard, is_leaf=is_ns),
                nu=jax.tree.map(lambda s: q8_shard(s, with_lo=True),
                                p_shard, is_leaf=is_ns))
        else:
            opt_shard = OptState(
                step=NamedSharding(mesh, P()),
                mu=p_shard, nu=p_shard)
        b_specs = api.batch_specs(cfg, shape)
        b_shard = _batch_shardings(b_specs, rules, mesh)
        fn = jax.jit(step, in_shardings=(p_shard, opt_shard, b_shard),
                     donate_argnums=(0, 1))
        args = (p_abs, opt_abs, b_specs)
    elif shape.mode == "prefill":
        pre = api.make_prefill_step(cfg, cache_len=shape.seq_len)
        b_specs = api.batch_specs(cfg, shape)
        b_shard = _batch_shardings(b_specs, rules, mesh)
        fn = jax.jit(pre, in_shardings=(p_shard, b_shard))
        args = (p_abs, b_specs)
    else:  # decode
        serve = api.make_serve_step(cfg)
        c_tmpl = cache_template(cfg, shape.global_batch, shape.seq_len)
        c_abs = abstract(c_tmpl, dtype)
        c_axes = axes_tree(c_tmpl)
        c_shard = _shardings_for(c_axes, c_abs, rules, mesh)
        tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        tspec = logical_to_spec(("batch",), rules, shape=tok.shape, mesh=mesh)
        tshard = NamedSharding(mesh, tspec)
        fn = jax.jit(serve, in_shardings=(p_shard, c_shard, tshard, tshard),
                     donate_argnums=(1,))
        args = (p_abs, c_abs, tok, pos)
    return fn, args, rules, cfg, shape


def _analyze(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    coll = hlo_mod.collective_bytes(compiled.as_text())
    return flops, bytes_acc, coll


def _compile(arch, shape_name, mesh, overrides, cfg=None, grad_accum=None):
    fn, args, rules, cfg, shape = build_cell(arch, shape_name, mesh,
                                             overrides, cfg=cfg,
                                             grad_accum=grad_accum)
    with mesh:
        with axis_rules(rules, mesh):
            lowered = fn.lower(*args)
    return lowered.compile(), cfg, shape


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             overrides=None, verbose: bool = True, calibrate: bool = True):
    t0 = time.time()
    # ---- the deliverable artifact: full depth, scanned layers ----------
    compiled, cfg, shape = _compile(arch, shape_name, mesh, overrides)
    t1 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "n_chips": mesh.size, "status": "ok"}

    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            k: getattr(mem, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        print("memory_analysis:", result["memory"])
    except Exception as e:  # pragma: no cover
        result["memory"] = {"error": str(e)}

    flops_raw, bytes_raw, coll_raw = _analyze(compiled)
    print("cost_analysis(raw, scan body counted once): flops=%.3e bytes=%.3e"
          % (flops_raw, bytes_raw))
    result["cost_raw"] = {"flops": flops_raw, "bytes_accessed": bytes_raw,
                          "collective_bytes": coll_raw.total_bytes,
                          "collective_counts": coll_raw.by_kind_count}
    del compiled

    # ---- scan calibration ----------------------------------------------
    # XLA cost_analysis visits while bodies once, so scanned-layer programs
    # under-report by the trip count.  Compile unrolled 1-rep and 2-rep
    # variants; their delta is the exact per-repetition cost.
    K = cfg.n_superblocks
    if calibrate and K >= 1:
        qc = 2048 if shape.mode != "decode" else None  # == the runtime tile size, so calibration measures the real path
        # calibration compiles with grad_accum=1: the accumulation scan would
        # otherwise also be trip-count-undercounted; the accumulator traffic
        # it removes (2·4·N·(k−1) bytes) is negligible vs activation traffic.
        with runtime_flags.unrolled(q_chunk=qc, kv_chunk=qc):
            c1, _, _ = _compile(arch, shape_name, mesh, overrides,
                                cfg=_with_reps(cfg, 1), grad_accum=1)
            f1, b1, coll1 = _analyze(c1)
            del c1
            c2, _, _ = _compile(arch, shape_name, mesh, overrides,
                                cfg=_with_reps(cfg, 2), grad_accum=1)
            f2, b2, coll2 = _analyze(c2)
            del c2
        flops = f1 + (K - 1) * (f2 - f1)
        bytes_acc = b1 + (K - 1) * (b2 - b1)
        coll_total = coll1.total_bytes + (K - 1) * (coll2.total_bytes - coll1.total_bytes)
        coll_by_kind = {
            k: coll1.by_kind.get(k, 0.0) + (K - 1) * (
                coll2.by_kind.get(k, 0.0) - coll1.by_kind.get(k, 0.0))
            for k in set(coll1.by_kind) | set(coll2.by_kind)}
        result["calibration"] = {
            "u1": {"flops": f1, "bytes": b1, "coll": coll1.total_bytes},
            "u2": {"flops": f2, "bytes": b2, "coll": coll2.total_bytes},
            "n_superblocks": K}
    else:
        flops, bytes_acc, coll_total = flops_raw, bytes_raw, coll_raw.total_bytes
        coll_by_kind = coll_raw.by_kind

    result["cost"] = {"flops": flops, "bytes_accessed": bytes_acc}
    result["collectives"] = {"bytes_per_chip": coll_total,
                             "by_kind": coll_by_kind}

    roof = hlo_mod.Roofline(
        n_chips=mesh.size,
        hlo_flops=flops * mesh.size,   # cost_analysis is per-partition
        hlo_bytes=bytes_acc * mesh.size,
        coll_bytes_per_chip=coll_total,
        model_flops=hlo_mod.model_flops_for(cfg, shape))
    result["roofline"] = roof.to_dict()
    t2 = time.time()
    result["timing"] = {"compile_s": t1 - t0, "calibrate_s": t2 - t1}
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] "
              f"compute={roof.compute_s*1e3:.2f}ms memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms dominant={roof.dominant} "
              f"useful={roof.useful_flops_ratio:.2f} mfu_bound={roof.mfu:.3f} "
              f"(compile {t1-t0:.0f}s + calib {t2-t1:.0f}s)")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    help="single | multi | RxC (debug, e.g. 2x4)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of logical-axis rule overrides")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.mesh == "single":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "multi":
        mesh = make_production_mesh(multi_pod=True)
    else:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = make_mesh(dims, axes)

    overrides = json.loads(args.overrides) if args.overrides else None
    os.makedirs(args.out, exist_ok=True)

    cells = []
    if args.all:
        from repro.configs.registry import ARCH_IDS
        for a in ARCH_IDS:
            for s in applicable_shapes(get_config(a)):
                cells.append((a, s.name))
    else:
        cells.append((args.arch, args.shape))

    n_ok = 0
    for arch, shape_name in cells:
        tag = f"{arch}__{shape_name}__{args.mesh}{args.tag}"
        out_path = os.path.join(args.out, tag + ".json")
        try:
            res = run_cell(arch, shape_name, mesh, args.mesh, overrides)
            n_ok += 1
        except Exception:
            res = {"arch": arch, "shape": shape_name, "mesh": args.mesh,
                   "status": "fail", "error": traceback.format_exc()}
            print(f"[{arch} × {shape_name}] FAILED")
            print(res["error"])
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1)
    print(f"dry-run complete: {n_ok}/{len(cells)} cells ok")
    if n_ok < len(cells):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
