"""RG-LRU linear-recurrence Pallas TPU kernel.

h_t = a_t · h_{t-1} + b_t, elementwise over channels.  The channel dim
rides the 128-lane axis; the sequence is blocked on the sublane axis with
the carry state in fp32 VMEM scratch across sequence blocks (innermost
sequential grid dim).  Inside a block the recurrence runs as a log-depth
Blelloch-style doubling scan on VMEM values — O(log bs) vector ops instead
of bs sequential steps, which is the VPU-friendly formulation (there is no
MXU work in this kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h_ref, carry_ref, *, bs: int):
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)  # (bs, W)
    b = b_ref[0].astype(jnp.float32)

    # Inclusive scan of the affine maps h ← a·h + b via doubling:
    # (a, b) ∘ (a', b') = (a·a', b·a' + b')  — log2(bs) rounds.
    steps = max(1, bs.bit_length() - 1)
    av, bv = a, b
    shift = 1
    for _ in range(steps):
        a_sh = jnp.concatenate([jnp.ones((shift, av.shape[1]), jnp.float32),
                                av[:-shift]], axis=0)
        b_sh = jnp.concatenate([jnp.zeros((shift, bv.shape[1]), jnp.float32),
                                bv[:-shift]], axis=0)
        bv = b_sh * av + bv
        av = a_sh * av
        shift *= 2

    h0 = carry_ref[...]  # (1, W) state entering this block
    h = bv + av * h0
    carry_ref[...] = h[-1:]
    h_ref[0] = h.astype(h_ref.dtype)


def rglru_scan(a, b, *, block_s: int = 256, interpret: bool = False):
    """a, b: (B, S, W) — returns h: (B, S, W) with h_t = a_t h_{t-1} + b_t."""
    B, S, W = a.shape
    bs = min(block_s, S)
    assert S % bs == 0 and (bs & (bs - 1)) == 0, "block must be a power of two"
    nb = S // bs

    kernel = functools.partial(_rglru_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, bs, W), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, W), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, W), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        interpret=interpret,
    )(a, b)
