"""Batched ModiPick stage-3 Pallas TPU kernel + jitted Gumbel sampling.

The hot step of the vectorized policy engine is the fused
eligibility-mask / Eq. 3–4 utility / normalize pass over the
(batch × pool) matrix.  The pool rides the 128-lane axis (padded), the
batch is blocked on the sublane axis, and each grid step produces the
per-request probability rows for its batch block in one VPU pass — no
intermediate (B, n) utility matrix ever round-trips through HBM.

``sample_batch`` wraps the kernel with the Gumbel-top-1 draw
(``argmax(log p + Gumbel)`` samples exactly from ``p``) under one jit, so
the whole stage 3 — utilities, normalization, sampling — runs compiled.
Off-TPU the kernel executes in interpret mode, same as every other
kernel in this package; ``kernels.ref.policy_probs_ref`` is the pure-jnp
oracle and ``core.policy_vec.modipick_probs`` the float64 numpy
reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

EPS = 1e-9
LANES = 128


def _probs_kernel(mu_ref, sig_ref, acc_ref, tu_ref, tl_ref, elig_ref,
                  out_ref, *, gamma: float):
    mu = mu_ref[...]          # (1, n)
    sig = sig_ref[...]
    acc = acc_ref[...]
    tu = tu_ref[...]          # (bb, 1)
    tl = tl_ref[...]
    e = elig_ref[...]         # (bb, n) 0/1 mask

    num = tu - (mu + sig)                      # broadcast → (bb, n)
    den = jnp.maximum(jnp.abs(tl - mu), EPS)
    u = jnp.power(jnp.maximum(acc, EPS), gamma) * num / den
    u = jnp.where(e > 0, u, 0.0)
    total = jnp.sum(u, axis=1, keepdims=True)
    cnt = jnp.sum(e, axis=1, keepdims=True)
    good = jnp.isfinite(total) & (total > 0)
    uniform = e / jnp.maximum(cnt, 1.0)
    out_ref[...] = jnp.where(good, u / jnp.where(good, total, 1.0), uniform)


def modipick_probs(mu, sigma, acc, t_u, t_l, elig, *, gamma: float = 1.0,
                   block_b: int = 256, interpret: bool = False):
    """Fused stage-3 probability matrix.

    mu/sigma/acc: (n,) pool arrays; t_u/t_l: (B,) per-request bounds;
    elig: (B, n) stage-2 eligibility → (B, n) float32 probabilities
    (rows with no eligible model come back all-zero).
    """
    B, n = elig.shape
    npad = max(LANES, -(-n // LANES) * LANES)
    bb = min(block_b, max(8, -(-B // 8) * 8))
    bpad = -(-B // bb) * bb

    f32 = jnp.float32
    pool = lambda x: jnp.pad(jnp.asarray(x, f32), (0, npad - n))[None, :]
    per_req = lambda x: jnp.pad(jnp.asarray(x, f32), (0, bpad - B))[:, None]
    e = jnp.pad(jnp.asarray(elig, f32), ((0, bpad - B), (0, npad - n)))

    out = pl.pallas_call(
        functools.partial(_probs_kernel, gamma=gamma),
        grid=(bpad // bb,),
        in_specs=[
            pl.BlockSpec((1, npad), lambda i: (0, 0)),
            pl.BlockSpec((1, npad), lambda i: (0, 0)),
            pl.BlockSpec((1, npad), lambda i: (0, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, npad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, npad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bpad, npad), f32),
        interpret=interpret,
    )(pool(mu), pool(sigma), pool(acc), per_req(t_u), per_req(t_l), e)
    return out[:B, :n]


@functools.partial(jax.jit,
                   static_argnames=("gamma", "block_b", "interpret"))
def _sample_jit(mu, sigma, acc, t_u, t_l, elig, key, *, gamma, block_b,
                interpret):
    probs = modipick_probs(mu, sigma, acc, t_u, t_l, elig, gamma=gamma,
                           block_b=block_b, interpret=interpret)
    g = jax.random.gumbel(key, probs.shape, dtype=probs.dtype)
    logits = jnp.where(probs > 0, jnp.log(probs), -jnp.inf)
    return jnp.argmax(logits + g, axis=1)


def sample_batch(mu, sigma, acc, t_u, t_l, elig, *, gamma: float = 1.0,
                 seed: int = 0, block_b: int = 256) -> np.ndarray:
    """One Gumbel-top-1 pick per request from the kernel's probability
    rows; returns (B,) pool indices as numpy.  Rows with no eligible
    model return an arbitrary index — callers mask them with their
    fallback (``policy_vec`` routes those to the fastest model)."""
    interpret = jax.default_backend() != "tpu"
    key = jax.random.PRNGKey(seed)
    idx = _sample_jit(jnp.asarray(mu, jnp.float32),
                      jnp.asarray(sigma, jnp.float32),
                      jnp.asarray(acc, jnp.float32),
                      jnp.asarray(t_u, jnp.float32),
                      jnp.asarray(t_l, jnp.float32),
                      jnp.asarray(elig, jnp.float32),
                      key, gamma=gamma, block_b=block_b,
                      interpret=interpret)
    return np.asarray(idx)
