"""Device-resident ModiPick selection: fused stages 1–3 under one jit.

Two layers live here:

- the **stage-3 Pallas TPU kernel** (``_probs_kernel`` /
  ``modipick_probs``): the fused eligibility-mask / Eq. 3–4 utility /
  normalize pass over the (batch × pool) matrix.  The pool rides the
  128-lane axis (padded), the batch is blocked on the sublane axis, and
  each grid step produces the per-request probability rows for its batch
  block in one VPU pass — no intermediate (B, n) utility matrix ever
  round-trips through HBM.
- the **fused selection pipeline** (``select_fused``): stages 1–2 — the
  Eq. 2 eligibility matrix, the accuracy-order masked argmax and the
  window-membership mask — computed in jitted jnp on device, feeding the
  stage-3 utilities (the Pallas kernel on TPU, the identical jnp math
  elsewhere) and an inverse-CDF categorical draw, all under ONE jit.
  Input is ``(mu, sigma, acc, t_u, t_l)``; output is the sampled pool
  indices.  Nothing round-trips through the host between stages.

Compiled callables are cached per ``(pool_size, gamma, batch_block)``
(``functools.lru_cache`` over the jit closure; XLA's own cache handles
the bucketed batch shapes), and the pool-side operands are padded to the
128-lane axis ONCE per :class:`DevicePool` — built at ``ProfileTable``
freeze via ``ProfileTable.device_pool()`` — instead of per call.  That
is what turned the historical 1.9 ms batch-1 dispatch into a plain jit
call.

Sampling uses the inverse-CDF trick (one uniform per request against
the cumulative utility row) instead of per-lane Gumbel noise: exactly
categorical, and it draws B random numbers instead of B × 128.
``sample_batch`` keeps the original Gumbel-top-1 kernel wrapper for
oracle tests; ``kernels.ref`` holds the pure-jnp references.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

EPS = 1e-9
LANES = 128
# Padded-lane sentinels: a fake model this slow can never be eligible,
# and a rank this large never wins the stage-1 argmin.
PAD_MU = 1e30
PAD_RANK = 1e9


def _probs_kernel(mu_ref, sig_ref, acc_ref, tu_ref, tl_ref, elig_ref,
                  out_ref, *, gamma: float):
    mu = mu_ref[...]          # (1, n)
    sig = sig_ref[...]
    acc = acc_ref[...]
    tu = tu_ref[...]          # (bb, 1)
    tl = tl_ref[...]
    e = elig_ref[...]         # (bb, n) 0/1 mask

    num = tu - (mu + sig)                      # broadcast → (bb, n)
    den = jnp.maximum(jnp.abs(tl - mu), EPS)
    u = jnp.power(jnp.maximum(acc, EPS), gamma) * num / den
    u = jnp.where(e > 0, u, 0.0)
    total = jnp.sum(u, axis=1, keepdims=True)
    cnt = jnp.sum(e, axis=1, keepdims=True)
    good = jnp.isfinite(total) & (total > 0)
    uniform = e / jnp.maximum(cnt, 1.0)
    out_ref[...] = jnp.where(good, u / jnp.where(good, total, 1.0), uniform)


def modipick_probs(mu, sigma, acc, t_u, t_l, elig, *, gamma: float = 1.0,
                   block_b: int = 256, interpret: bool = False):
    """Fused stage-3 probability matrix.

    mu/sigma/acc: (n,) pool arrays; t_u/t_l: (B,) per-request bounds;
    elig: (B, n) stage-2 eligibility → (B, n) float32 probabilities
    (rows with no eligible model come back all-zero).
    """
    B, n = elig.shape
    npad = max(LANES, -(-n // LANES) * LANES)
    bb = min(block_b, max(8, -(-B // 8) * 8))
    bpad = -(-B // bb) * bb

    f32 = jnp.float32
    pool = lambda x: jnp.pad(jnp.asarray(x, f32), (0, npad - n))[None, :]
    per_req = lambda x: jnp.pad(jnp.asarray(x, f32), (0, bpad - B))[:, None]
    e = jnp.pad(jnp.asarray(elig, f32), ((0, bpad - B), (0, npad - n)))

    out = pl.pallas_call(
        functools.partial(_probs_kernel, gamma=gamma),
        grid=(bpad // bb,),
        in_specs=[
            pl.BlockSpec((1, npad), lambda i: (0, 0)),
            pl.BlockSpec((1, npad), lambda i: (0, 0)),
            pl.BlockSpec((1, npad), lambda i: (0, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, npad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, npad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bpad, npad), f32),
        interpret=interpret,
    )(pool(mu), pool(sigma), pool(acc), per_req(t_u), per_req(t_l), e)
    return out[:B, :n]


@functools.partial(jax.jit,
                   static_argnames=("gamma", "block_b", "interpret"))
def _sample_jit(mu, sigma, acc, t_u, t_l, elig, key, *, gamma, block_b,
                interpret):
    probs = modipick_probs(mu, sigma, acc, t_u, t_l, elig, gamma=gamma,
                           block_b=block_b, interpret=interpret)
    g = jax.random.gumbel(key, probs.shape, dtype=probs.dtype)
    logits = jnp.where(probs > 0, jnp.log(probs), -jnp.inf)
    return jnp.argmax(logits + g, axis=1)


def sample_batch(mu, sigma, acc, t_u, t_l, elig, *, gamma: float = 1.0,
                 seed: int = 0, block_b: int = 256) -> np.ndarray:
    """One Gumbel-top-1 pick per request from the kernel's probability
    rows; returns (B,) pool indices as numpy.  Rows with no eligible
    model return an arbitrary index — callers mask them with their
    fallback (``policy_vec`` routes those to the fastest model)."""
    interpret = jax.default_backend() != "tpu"
    key = jax.random.PRNGKey(seed)
    idx = _sample_jit(jnp.asarray(mu, jnp.float32),
                      jnp.asarray(sigma, jnp.float32),
                      jnp.asarray(acc, jnp.float32),
                      jnp.asarray(t_u, jnp.float32),
                      jnp.asarray(t_l, jnp.float32),
                      jnp.asarray(elig, jnp.float32),
                      key, gamma=gamma, block_b=block_b,
                      interpret=interpret)
    return np.asarray(idx)


# ======================================================================
# Device-resident stages 1–3: one jit from (mu, sigma, acc, t_u, t_l)
# straight to sampled pool indices.
# ======================================================================

class DevicePool:
    """Pool-side operands of the fused selection, padded to the 128-lane
    axis once and parked on device.  Frozen against one ProfileTable
    snapshot — rebuild (cheap) when the profiles move.

    ``rank[i]`` is model ``i``'s position in the accuracy-descending
    order (the stable argsort the scalar path caches), so the stage-1
    "first eligible in accuracy order" is ``argmin`` of the masked rank
    row.  Padded lanes carry ``PAD_MU``/``PAD_RANK`` sentinels, which
    keeps every stage's math finite without a separate validity mask.

    The 128-lane padding is a TPU tiling constraint (the Pallas stage-3
    kernel rides the lane axis); the XLA-CPU path has no such
    constraint, so off-TPU the pool keeps its natural width instead of
    paying 16× elementwise waste on a typical 8-model zoo.
    """

    __slots__ = ("n", "npad", "mu", "sigma", "acc", "rank", "fastest")

    def __init__(self, mu, sigma, acc, acc_order, fastest: int):
        n = len(mu)
        if jax.default_backend() == "tpu":
            npad = max(LANES, -(-n // LANES) * LANES)
        else:
            npad = n
        self.n = n
        self.npad = npad

        def pad(x, value):
            return jnp.asarray(np.pad(np.asarray(x, np.float32),
                                      (0, npad - n),
                                      constant_values=value))

        rank = np.empty(n, np.float32)
        rank[np.asarray(acc_order)] = np.arange(n, dtype=np.float32)
        self.mu = pad(mu, PAD_MU)
        self.sigma = pad(sigma, 0.0)
        self.acc = pad(acc, 1.0)
        self.rank = pad(rank, PAD_RANK)
        self.fastest = int(fastest)


def _stages12(mu, sig, rank, t_u, t_l):
    """Stages 1–2 on device.  mu/sig/rank: (npad,); t_u/t_l: (B,).
    Returns ``(base, has_base, eligible)`` — the Eq. 2 eligibility matrix
    reduced by accuracy-order masked argmin (stage 1) and the window
    membership mask with the base forced in (stage 2)."""
    tu, tl = t_u[:, None], t_l[:, None]
    mus = (mu + sig)[None, :]
    elig1 = (mus < tu) & ((mu - sig)[None, :] < tl)          # Eq. 2, (B, npad)
    has_base = elig1.any(axis=1)
    base = jnp.argmin(jnp.where(elig1, rank[None, :], PAD_RANK + 1.0),
                      axis=1).astype(jnp.int32)              # first in acc order
    half = jnp.abs(t_l - mu[base]) + sig[base]               # (B,)
    lo, hi = (t_l - half)[:, None], (t_l + half)[:, None]
    natural = (lo <= mu[None, :]) & (mu[None, :] <= hi) & (mus < tu)
    eligible = natural | (jnp.arange(mu.shape[0])[None, :] == base[:, None])
    eligible &= has_base[:, None]
    return base, has_base, eligible


def _utilities(mu, sig, acc, t_u, t_l, eligible, gamma):
    """Eq. 3–4 utility rows (plain jnp, identical math to the Pallas
    kernel); degenerate rows (non-finite or non-positive mass) fall back
    to uniform-over-eligible, exactly like the scalar path."""
    tu, tl = t_u[:, None], t_l[:, None]
    num = tu - (mu + sig)[None, :]
    den = jnp.maximum(jnp.abs(tl - mu[None, :]), EPS)
    u = jnp.power(jnp.maximum(acc, EPS), gamma)[None, :] * num / den
    u = jnp.where(eligible, u, 0.0)
    total = jnp.sum(u, axis=1, keepdims=True)
    good = jnp.isfinite(total) & (total > 0)
    return jnp.where(good, u, eligible.astype(u.dtype))


def _fused_select(mu, sig, acc, rank, t_u, t_l, seed, *, gamma: float,
                  block_b: int, use_pallas: bool):
    """The whole pipeline under one trace: stages 1–2, stage-3 utility
    rows (Pallas kernel on TPU, jnp elsewhere), inverse-CDF categorical
    draw.  Returns (B,) int32: the sampled pool index, or -1 where no
    base model exists (the caller's fallback lane)."""
    base, has_base, eligible = _stages12(mu, sig, rank, t_u, t_l)
    if use_pallas:
        w = modipick_probs(mu, sig, acc, t_u, t_l,
                           eligible.astype(jnp.float32), gamma=gamma,
                           block_b=block_b)
    else:
        w = _utilities(mu, sig, acc, t_u, t_l, eligible, gamma)
    cdf = jnp.cumsum(w, axis=1)
    total = cdf[:, -1]
    r01 = jax.random.uniform(jax.random.PRNGKey(seed), total.shape,
                             dtype=cdf.dtype)
    thresh = r01 * total
    # First index whose cumulative mass exceeds the threshold — exact
    # categorical sampling with ONE uniform per request (no per-lane
    # noise).  Zero-probability lanes have flat cdf segments and are
    # never selected; the float edge thresh == total falls back to the
    # (always eligible) base.
    choice = jnp.argmax(cdf > thresh[:, None], axis=1).astype(jnp.int32)
    choice = jnp.where(total > thresh, choice, base)
    return jnp.where(has_base, choice, -1)


@functools.lru_cache(maxsize=64)
def _fused_jit(npad: int, gamma: float, block_b: int, use_pallas: bool):
    """The jit cache: one compiled callable per (pool_size, gamma,
    batch_block) — XLA's shape cache handles the bucketed batch axis."""
    return jax.jit(functools.partial(_fused_select, gamma=gamma,
                                     block_b=block_b,
                                     use_pallas=use_pallas))


@functools.lru_cache(maxsize=8)
def _masks_jit(npad: int):
    return jax.jit(_stages12)


def _bucket(B: int, block_b: int) -> int:
    """Pad the batch axis to a bounded family of shapes so jit retraces
    stay rare: multiples of ``block_b`` up to 4096, multiples of 4096
    beyond (≤4% padding waste at large B)."""
    step = block_b if B <= 4096 else 4096
    return max(block_b, -(-B // step) * step)


def _pad_batch(x, bpad: int) -> np.ndarray:
    out = np.zeros(bpad, np.float32)
    out[:len(x)] = x
    return out


def select_fused(pool: DevicePool, t_u, t_l, *, gamma: float = 1.0,
                 seed: int = 0, block_b: int = 256):
    """Device-resident batched ModiPick selection.

    ``t_u``/``t_l``: (B,) per-request budget bounds.  Returns
    ``(idx, has_base)`` numpy arrays — ``idx[b]`` is the sampled pool
    index (already routed to ``pool.fastest`` where ``~has_base``).
    One host→device transfer (the budget rows), one device→host
    transfer (the packed picks)."""
    B = len(t_u)
    bpad = _bucket(B, block_b)
    fn = _fused_jit(pool.npad, float(gamma), block_b,
                    jax.default_backend() == "tpu")
    out = np.asarray(fn(pool.mu, pool.sigma, pool.acc, pool.rank,
                        jnp.asarray(_pad_batch(t_u, bpad)),
                        jnp.asarray(_pad_batch(t_l, bpad)),
                        np.uint32(seed & 0xFFFFFFFF)))[:B]
    has_base = out >= 0
    return np.where(has_base, out, pool.fastest), has_base


# ======================================================================
# Fleet selection: the fused pipeline over a leading cell axis.  Every
# cell's pending batch is judged in ONE device call — (cell × batch ×
# pool) operands in, (cell × batch) picks out.  The per-cell math is
# exactly the `_fused_select` jnp path (stages 1–2, Eq. 3–4 utilities,
# inverse-CDF draw); cells ride `jax.vmap`, and
# `distributed.shardmap_ops.sharded_fleet_select` wraps the same body
# under `shard_map` when a mesh carries a "cell" axis.  The jnp branch
# is used on every backend (no Pallas inside the vmapped body), so the
# call is bit-identical between CPU tests and sharded meshes.
# ======================================================================

def fleet_select_body(mu, sig, acc, rank, t_u, t_l, key, *,
                      gamma: float = 1.0):
    """One cell's fused selection, written to be vmapped/shard_mapped
    over a leading cell axis.  mu/sig/acc/rank: (npad,) pool operands
    (PAD_MU/PAD_RANK sentinels on lanes beyond the cell's own pool);
    t_u/t_l: (B,) budget bounds; key: a PRNG key.  Returns (B,) int32
    picks, −1 where no base model exists (the caller's shed/fallback
    lane)."""
    base, has_base, eligible = _stages12(mu, sig, rank, t_u, t_l)
    w = _utilities(mu, sig, acc, t_u, t_l, eligible, gamma)
    cdf = jnp.cumsum(w, axis=1)
    total = cdf[:, -1]
    r01 = jax.random.uniform(key, total.shape, dtype=cdf.dtype)
    thresh = r01 * total
    choice = jnp.argmax(cdf > thresh[:, None], axis=1).astype(jnp.int32)
    choice = jnp.where(total > thresh, choice, base)
    return jnp.where(has_base, choice, -1)


@functools.lru_cache(maxsize=16)
def _fleet_jit(npad: int, gamma: float):
    """One compiled callable per (common pool width, gamma): cells ride
    a vmap over the leading axis, batches bucket like `select_fused`."""
    return jax.jit(jax.vmap(
        functools.partial(fleet_select_body, gamma=gamma)))


def select_fleet_stacked(mu, sig, acc, rank, t_u, t_l, *,
                         gamma: float = 1.0, seed: int = 0) -> np.ndarray:
    """All cells' pending batches as one device call.

    ``mu/sig/acc/rank``: (C, npad) stacked pool operands (see
    ``fleet.device.stack_cell_tables``); ``t_u``/``t_l``: (C, B) budget
    bounds — row c is cell c's judgment of every pending request.
    Returns (C, B) int32 numpy picks, −1 where cell c has no eligible
    model for request b.  Each cell draws from its own fold of the
    seed, so per-cell streams are decorrelated but deterministic."""
    C, B = np.shape(t_u)
    bpad = _bucket(B, 256)
    pad2 = lambda x: np.pad(np.asarray(x, np.float32),
                            ((0, 0), (0, bpad - B)))
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(seed), jnp.arange(C, dtype=jnp.uint32))
    fn = _fleet_jit(int(np.shape(mu)[1]), float(gamma))
    out = fn(jnp.asarray(mu), jnp.asarray(sig), jnp.asarray(acc),
             jnp.asarray(rank), jnp.asarray(pad2(t_u)),
             jnp.asarray(pad2(t_l)), keys)
    return np.asarray(out)[:, :B]


# ======================================================================
# Class-conditional selection: the fused pipeline with PER-REQUEST pool
# operands.  The premodel layer keeps K per-class profile tables over
# the same zoo (premodel.conditional.ConditionalProfileStore); a batch
# carrying per-request input-class ids gathers each request's class row
# out of the stacked (K, npad) mu/sigma operands and runs the identical
# stage 1–3 math row-wise — ONE device call for the whole classed
# batch, exactly like the fleet's stacked dispatch.  Accuracy (and the
# stage-1 rank derived from it) never varies by class, so acc/rank stay
# (npad,) and broadcast.  jnp on every backend (no Pallas inside), so
# CPU tests and TPU runs are bit-identical.
# ======================================================================

def _stages12_rows(mu, sig, rank, t_u, t_l):
    """Stages 1–2 with per-request pool rows.  mu/sig: (B, npad);
    rank: (npad,); t_u/t_l: (B,).  Same math as :func:`_stages12`, with
    the base row's μ/σ gathered per request instead of indexed from a
    shared pool vector."""
    tu, tl = t_u[:, None], t_l[:, None]
    mus = mu + sig
    elig1 = (mus < tu) & ((mu - sig) < tl)                   # Eq. 2, (B, npad)
    has_base = elig1.any(axis=1)
    base = jnp.argmin(jnp.where(elig1, rank[None, :], PAD_RANK + 1.0),
                      axis=1).astype(jnp.int32)              # first in acc order
    mu_base = jnp.take_along_axis(mu, base[:, None], axis=1)[:, 0]
    sig_base = jnp.take_along_axis(sig, base[:, None], axis=1)[:, 0]
    half = jnp.abs(t_l - mu_base) + sig_base                 # (B,)
    lo, hi = (t_l - half)[:, None], (t_l + half)[:, None]
    natural = (lo <= mu) & (mu <= hi) & (mus < tu)
    eligible = natural | (jnp.arange(mu.shape[1])[None, :] == base[:, None])
    eligible &= has_base[:, None]
    return base, has_base, eligible


def _utilities_rows(mu, sig, acc, t_u, t_l, eligible, gamma):
    """Eq. 3–4 utilities with per-request μ/σ rows (same degenerate
    fallback as :func:`_utilities`)."""
    tu, tl = t_u[:, None], t_l[:, None]
    num = tu - (mu + sig)
    den = jnp.maximum(jnp.abs(tl - mu), EPS)
    u = jnp.power(jnp.maximum(acc, EPS), gamma)[None, :] * num / den
    u = jnp.where(eligible, u, 0.0)
    total = jnp.sum(u, axis=1, keepdims=True)
    good = jnp.isfinite(total) & (total > 0)
    return jnp.where(good, u, eligible.astype(u.dtype))


def _classed_select(mu_k, sig_k, acc, rank, cls, shifts, t_u, t_l, seed, *,
                    gamma: float):
    """The classed pipeline under one trace: gather each request's class
    row, add the (class-independent) queue-wait shifts, then stages 1–3
    and the inverse-CDF draw.  Returns (B,) int32 picks with the
    no-base fallback resolved to the row's own fastest model, plus the
    has_base mask."""
    mu = mu_k[cls] + shifts[None, :]       # (B, npad); shifts are per-model
    sig = sig_k[cls]
    base, has_base, eligible = _stages12_rows(mu, sig, rank, t_u, t_l)
    w = _utilities_rows(mu, sig, acc, t_u, t_l, eligible, gamma)
    cdf = jnp.cumsum(w, axis=1)
    total = cdf[:, -1]
    r01 = jax.random.uniform(jax.random.PRNGKey(seed), total.shape,
                             dtype=cdf.dtype)
    thresh = r01 * total
    choice = jnp.argmax(cdf > thresh[:, None], axis=1).astype(jnp.int32)
    choice = jnp.where(total > thresh, choice, base)
    # Fallback: the fastest model of the request's OWN class view
    # (padded lanes carry PAD_MU and never win the argmin).
    fb = jnp.argmin(mu, axis=1).astype(jnp.int32)
    return jnp.where(has_base, choice, fb), has_base


@functools.lru_cache(maxsize=32)
def _classed_jit(K: int, npad: int, gamma: float):
    return jax.jit(functools.partial(_classed_select, gamma=gamma))


def select_classed(stacked, cls, t_u, t_l, *, shifts=None,
                   gamma: float = 1.0, seed: int = 0,
                   block_b: int = 256):
    """Batched class-conditional ModiPick selection in one device call.

    ``stacked``: a ``premodel.conditional.StackedClassPools`` — (K, npad)
    per-class mu/sigma plus shared (npad,) acc/rank.  ``cls``: (B,)
    int input-class ids; ``t_u``/``t_l``: (B,) budget bounds;
    ``shifts``: optional (n,) per-model queue-wait shifts (identical
    across classes — waits live at replicas, not input classes).
    Returns ``(idx, has_base)`` numpy arrays with the fallback already
    resolved to the per-class fastest model.
    """
    B = len(t_u)
    bpad = _bucket(B, block_b)
    cls_pad = np.zeros(bpad, np.int32)
    cls_pad[:B] = np.asarray(cls, np.int32)
    sh = np.zeros(stacked.npad, np.float32)
    if shifts is not None:
        sh[:len(shifts)] = np.asarray(shifts, np.float32)
    fn = _classed_jit(stacked.k, stacked.npad, float(gamma))
    idx, has_base = fn(stacked.mu, stacked.sigma, stacked.acc, stacked.rank,
                       jnp.asarray(cls_pad), jnp.asarray(sh),
                       jnp.asarray(_pad_batch(t_u, bpad)),
                       jnp.asarray(_pad_batch(t_l, bpad)),
                       np.uint32(seed & 0xFFFFFFFF))
    return np.asarray(idx)[:B], np.asarray(has_base)[:B]


# ======================================================================
# Charged sequential-greedy selection: lax.scan over the batch, with the
# per-replica wait ledger as the carry.
# ======================================================================

def _charged_step(rep_wait, xs, *, mu, sig, acc, rank, mu_charge,
                  cand_mask, speed, gamma: float, slack: float,
                  include_mu: bool, fastest: int):
    """One scan step = one request judged against the *charged* waits.

    Carry: ``rep_wait`` (R,) — every replica's wait including all
    charges so far.  Per step: derive the live ``W_queue(m)`` row (min
    over each model's candidate replicas), run admission viability +
    shifted-μ stages 1–3 + the inverse-CDF draw against it, then charge
    the admitted pick's μ/speed to its least-loaded capable replica
    before the next step sees the carry.
    """
    tu, tl, r01, lim = xs
    # (npad,) per-model wait: min over candidate replicas.  Padded lanes
    # have no candidates → +inf; they also carry PAD_MU, so clamping
    # their shift to 0 keeps every downstream comparison finite.
    wq_raw = jnp.min(jnp.where(cand_mask, rep_wait[None, :], jnp.inf),
                     axis=1)
    wq = jnp.where(jnp.isfinite(wq_raw), wq_raw, 0.0)

    # SLA-aware admission viability against the charged waits: some
    # model must satisfy W_queue + slack (+ μ) < limit.  AdmitAll passes
    # lim=+inf; padded *batch* rows pass lim=−inf so they neither admit
    # nor charge.
    cost = wq_raw + slack
    if include_mu:
        cost = cost + mu_charge
    admitted = jnp.any(cost < lim)

    mu_i = mu + wq                       # the shifted-μ store view
    base, has_base, eligible = _stages12(mu_i, sig, rank,
                                         tu[None], tl[None])
    w = _utilities(mu_i, sig, acc, tu[None], tl[None], eligible, gamma)
    cdf = jnp.cumsum(w[0])
    total = cdf[-1]
    thresh = r01 * total
    choice = jnp.argmax(cdf > thresh).astype(jnp.int32)
    choice = jnp.where(total > thresh, choice, base[0])
    pick = jnp.where(has_base[0], choice, fastest)

    # Charge: least-loaded capable replica, first-index tie-break (the
    # pool-order rule ``ReplicaPool.best_for`` uses).
    masked = jnp.where(cand_mask[pick], rep_wait, jnp.inf)
    rep = jnp.argmin(masked).astype(jnp.int32)
    delta = jnp.where(admitted, mu_charge[pick] / speed[rep], 0.0)
    rep_wait = rep_wait.at[rep].add(delta)

    w_chosen = jnp.where(admitted, wq[pick], jnp.min(wq_raw))
    return rep_wait, (pick, admitted, has_base[0], rep, w_chosen)


@functools.lru_cache(maxsize=32)
def _charged_jit(npad: int, gamma: float, slack: float, include_mu: bool,
                 fastest: int):
    def run(mu, sig, acc, rank, mu_charge, cand_mask, speed, rep_wait,
            t_u, t_l, r01, lim):
        step = functools.partial(
            _charged_step, mu=mu, sig=sig, acc=acc, rank=rank,
            mu_charge=mu_charge, cand_mask=cand_mask, speed=speed,
            gamma=gamma, slack=slack, include_mu=include_mu,
            fastest=fastest)
        _, ys = jax.lax.scan(step, rep_wait, (t_u, t_l, r01, lim))
        return ys
    return jax.jit(run)


def charged_select(pool: DevicePool, t_u, t_l, state, *,
                   gamma: float = 1.0, adm_limit=None,
                   adm_slack: float = 0.0, adm_include_mu: bool = False,
                   seed: int = 0, block_b: int = 256):
    """Device-resident charged batch selection: a ``lax.scan`` over the
    batch whose carry is the per-replica wait ledger, so request ``i``
    is admitted and selected against waits that include the charges of
    requests ``0..i-1`` — the sequential-greedy staleness fix, riding
    the same fused stage-1–3 math as :func:`select_fused`.

    ``state`` is a :class:`repro.router.charging.ChargedWaits` (replica
    waits, model → candidate topology, speeds, live charge-μ).
    ``adm_limit`` (B,) enables the in-scan SLA-aware viability test
    (``W_queue + slack (+ μ) < limit``); ``None`` admits everything.
    Returns numpy ``(picks, admitted, has_base, replica, w_chosen)``:
    the picked pool index, the admission verdict, the fallback
    indicator, the replica the charge landed on, and the chosen model's
    pre-charge wait (for shed rows: the pool's minimum wait).

    Like the uncharged fused path, the draw is categorical from the
    exact per-request distribution but rides jax's RNG — same law as
    the numpy sequential loop, not the same stream.
    """
    B = len(t_u)
    n, npad = pool.n, pool.npad
    R = len(state.rep_wait)
    bpad = _bucket(B, block_b)
    f32 = jnp.float32

    cand_mask = np.zeros((npad, R), dtype=bool)
    for m, c in enumerate(state.cand):
        cand_mask[m, np.asarray(c)] = True
    mu_charge = np.zeros(npad, np.float32)
    mu_charge[:n] = np.asarray(state.mu, np.float64)[:n]

    lim = np.full(bpad, -np.inf, np.float32)
    if adm_limit is None:
        lim[:B] = np.inf
    else:
        lim[:B] = np.asarray(adm_limit, np.float32)
    r01 = jax.random.uniform(jax.random.PRNGKey(seed), (bpad,),
                             dtype=f32)

    fn = _charged_jit(npad, float(gamma), float(adm_slack),
                      bool(adm_include_mu), pool.fastest)
    picks, admitted, has_base, rep, w_chosen = fn(
        pool.mu, pool.sigma, pool.acc, pool.rank,
        jnp.asarray(mu_charge), jnp.asarray(cand_mask),
        jnp.asarray(state.speed, f32),
        jnp.asarray(state.rep_wait, f32),
        jnp.asarray(_pad_batch(t_u, bpad)),
        jnp.asarray(_pad_batch(t_l, bpad)),
        r01, jnp.asarray(lim))
    return (np.asarray(picks)[:B], np.asarray(admitted)[:B],
            np.asarray(has_base)[:B], np.asarray(rep)[:B],
            np.asarray(w_chosen, np.float64)[:B])


def masks_device(pool: DevicePool, t_u, t_l):
    """Stages 1–2 alone, through the same traced code as
    :func:`select_fused` — the test surface for pinning the device
    masks against the ``policy_vec.modipick_masks`` numpy reference.
    Returns numpy ``(base, has_base, eligible)`` with ``eligible``
    trimmed to the unpadded pool."""
    B = len(t_u)
    bpad = _bucket(B, 8)
    base, has, elig = _masks_jit(pool.npad)(
        pool.mu, pool.sigma, pool.rank,
        jnp.asarray(_pad_batch(t_u, bpad)),
        jnp.asarray(_pad_batch(t_l, bpad)))
    return (np.asarray(base)[:B], np.asarray(has)[:B],
            np.asarray(elig)[:B, :pool.n])
