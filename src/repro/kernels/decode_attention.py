"""Flash-decode Pallas TPU kernel: one new token attending over a KV cache.

TPU adaptation: at q_len=1 a naive kernel would waste the MXU (1×hd tiles),
so the whole GQA *q-head group* is packed into the sublane dim — the block
is (group, hd) × (bk, hd), an MXU-shaped matmul.  The grid walks KV blocks
(innermost, sequential) carrying online-softmax state in fp32 VMEM scratch;
per-sequence lengths arrive via scalar prefetch so fully-invalid KV blocks
(beyond `pos`) are skipped without issuing compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, bk: int, nk: int, group: int, scale: float,
                   window: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[b]
    k_start = ik * bk
    live = k_start <= pos  # no valid slot beyond the write position
    if window > 0:
        live = jnp.logical_and(live, k_start + bk - 1 > pos - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (group, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (group, bk), 1)
        mask = kj <= pos
        if window > 0:
            mask = jnp.logical_and(mask, kj > pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(q, k, v, pos, *, window: int = 0, block_k: int = 128,
                     interpret: bool = False):
    """q: (B, KV, group, hd) — new-token queries grouped per kv head;
    k, v: (B, KV, S, hd) cache (the new token's k/v already written);
    pos: (B,) int32 absolute position of the new token.

    Returns (B, KV, group, hd).
    """
    B, KV, group, hd = q.shape
    S = k.shape[2]
    bk = min(block_k, S)
    assert S % bk == 0
    nk = S // bk

    kernel = functools.partial(_decode_kernel, bk=bk, nk=nk, group=group,
                               scale=hd ** -0.5, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd), lambda b, h, j, pos_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, pos_ref: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, pos_ref: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), lambda b, h, j, pos_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, group, hd), q.dtype),
        interpret=interpret,
    )(pos, q, k, v)
