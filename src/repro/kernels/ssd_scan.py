"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the SSD algorithm (Dao & Gu, 2024): per (batch, head,
chunk) block the intra-chunk quadratic term runs as two MXU matmuls
(C·Bᵀ then (scores⊙L⊙dt)·X) while the inter-chunk recurrence carries the
(hd, N) state in fp32 VMEM scratch across the sequential innermost grid
dim.  chunk=128..256 keeps the whole working set (x, B, C, scores, state ≈
cs² + 3·cs·N + hd·N floats) inside VMEM, with cs and N lane/sublane
aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                cs: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)      # (cs, hd)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (cs, 1)
    A = a_ref[0, 0]                          # scalar fp32, negative
    Bm = b_ref[0, 0].astype(jnp.float32)     # (cs, N)
    Cm = c_ref[0, 0].astype(jnp.float32)     # (cs, N)

    dtA = dt * A                             # (cs, 1)
    cum = jnp.cumsum(dtA, axis=0)            # inclusive within-chunk decay
    total = cum[cs - 1]

    # intra-chunk: y1 = ((C Bᵀ) ⊙ L ⊙ dt_j) x
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    li = cum  # (cs, 1) at i (rows)
    lj = cum.reshape(1, cs)  # at j (cols)
    L = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))
    ii = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    L = jnp.where(jj <= ii, L, 0.0)
    M = scores * L * dt.reshape(1, cs)
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y2 = (exp(cum_i) C_i) · state_in
    state_in = state_ref[...]  # (hd, N)
    y = y + jnp.exp(jnp.clip(cum, -60.0, 0.0)) * jax.lax.dot_general(
        Cm, state_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: state = exp(total)·state + Σ_j exp(total-cum_j)·dt_j·x_jᵀB_j
    w = jnp.exp(jnp.clip(total.reshape(1, 1) - cum, -60.0, 0.0)) * dt  # (cs,1)
    upd = jax.lax.dot_general(x, Bm * w, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (hd, N)
    state_ref[...] = jnp.exp(jnp.clip(total, -60.0, 0.0)) * state_in + upd

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, B_, C_, *, chunk: int = 128, interpret: bool = False):
    """x: (B, H, S, hd); dt: (B, H, S) post-softplus; A: (H,) negative;
    B_, C_: (B, G, S, N) with H % G == 0 (groups broadcast over heads).

    Returns y: (B, H, S, hd) — D-skip and gating applied by the caller.
    """
    Bb, H, S, hd = x.shape
    G, N = B_.shape[1], B_.shape[3]
    group = H // G
    cs = min(chunk, S)
    assert S % cs == 0
    nc = S // cs

    dt3 = dt[..., None]  # (B, H, S, 1)
    a2 = A.reshape(H, 1).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, cs=cs)
    return pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, cs, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, cs, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, cs, N), lambda b, h, c: (b, h // group, c, 0)),
            pl.BlockSpec((1, 1, cs, N), lambda b, h, c: (b, h // group, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, cs, hd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, H, S, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt3, a2, B_, C_)
