"""Flash attention (prefill/train) Pallas TPU kernel.

Design (TPU-native, not a CUDA port):
- layout (B, H, S, hd): S on the sublane axis, hd on the lane axis; block
  shapes (bq, hd)/(bk, hd) with bq=bk=128 keep the MXU fed with 128×128
  tiles and the per-step working set (q, k, v, acc, m, l ≈ 5·128·hd·4B)
  well inside one core's VMEM.
- grid (B, H, nq, nk): nk is the innermost (sequential) dim; online-softmax
  running max/denominator and the output accumulator are carried across kv
  blocks in fp32 VMEM scratch.
- causal/sliding-window: fully-masked kv blocks are skipped with pl.when
  (no MXU work issued); partially-masked blocks apply an elementwise mask.
- GQA: the kv-head index map folds the q-head group (h → h // group), so
  kv is never materialized per-q-head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, bq: int, bk: int, nk: int,
                  scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk

    # Block-level skip: block is live iff some (i, j) with j <= i and
    # j > i - window overlaps it.
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window > 0:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kj <= qi)
        if window > 0:
            mask = jnp.logical_and(mask, kj > qi - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd). window=0 ⇒ unbounded.

    Returns (B, H, Sq, hd) in q.dtype.
    """
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0
    group = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
        scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
