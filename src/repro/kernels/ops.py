"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (CPU CI, tests) they run
in interpret mode, which executes the same kernel bodies through the JAX
interpreter — bit-identical control flow, validated against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import policy_select as _ps
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """q: (B,H,Sq,hd); k,v: (B,KV,Sk,hd)."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("window", "block_k"))
def decode_attention(q, k, v, pos, *, window: int = 0, block_k: int = 128):
    """q: (B,KV,G,hd); k,v: (B,KV,S,hd); pos: (B,)."""
    return _dec.decode_attention(q, k, v, pos, window=window, block_k=block_k,
                                 interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B_, C_, *, chunk: int = 128):
    """x: (B,H,S,hd); dt post-softplus (B,H,S); A: (H,); B_,C_: (B,G,S,N)."""
    return _ssd.ssd_scan(x, dt, A, B_, C_, chunk=chunk,
                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_s",))
def rglru_scan(a, b, *, block_s: int = 256):
    """Linear recurrence over (B,S,W)."""
    return _rg.rglru_scan(a, b, block_s=block_s, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("gamma", "block_b"))
def modipick_probs(mu, sigma, acc, t_u, t_l, elig, *, gamma: float = 1.0,
                   block_b: int = 256):
    """Fused ModiPick stage-3: mu/sigma/acc (n,); t_u/t_l (B,);
    elig (B,n) → (B,n) probability rows."""
    return _ps.modipick_probs(mu, sigma, acc, t_u, t_l, elig, gamma=gamma,
                              block_b=block_b, interpret=not _on_tpu())
