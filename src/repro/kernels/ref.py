"""Pure-jnp oracles for every Pallas kernel (small-shape ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,Sq,hd); k,v: (B,KV,Sk,hd) → (B,H,Sq,hd). Naive full softmax."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * hd ** -0.5
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= kj > qi - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, pos, *, window=0):
    """q: (B,KV,G,hd); k,v: (B,KV,S,hd); pos: (B,) → (B,KV,G,hd)."""
    hd = q.shape[-1]
    S = k.shape[2]
    s = jnp.einsum("bngd,bnkd->bngk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    kj = jnp.arange(S)[None, None, None, :]
    mask = kj <= pos[:, None, None, None]
    if window > 0:
        mask &= kj > pos[:, None, None, None] - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bngk,bnkd->bngd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B_, C_):
    """Sequential SSD recurrence. x: (B,H,S,hd); dt: (B,H,S); A: (H,);
    B_,C_: (B,G,S,N). h_t = exp(dt·A)·h + dt·B⊗x ; y = C·h."""
    Bb, H, S, hd = x.shape
    G, N = B_.shape[1], B_.shape[3]
    group = H // G
    Bx = jnp.repeat(B_, group, axis=1).astype(jnp.float32)  # (B,H,S,N)
    Cx = jnp.repeat(C_, group, axis=1).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, :, None])  # (B,H,S)

    def step(h, t):
        d, u, c = t
        h = h * d[..., None, None] + u
        y = jnp.einsum("bhpn,bhn->bhp", h, c)
        return h, y

    upd = jnp.einsum("bhs,bhsp,bhsn->sbhpn", dtf, xf, Bx)
    h0 = jnp.zeros((Bb, H, hd, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(decay, 2, 0), upd,
                                    jnp.moveaxis(Cx, 2, 0)))
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)  # (B,H,S,hd)


def policy_probs_ref(mu, sigma, acc, t_u, t_l, elig, *, gamma=1.0,
                     eps=1e-9):
    """Batched ModiPick stage-3 (Eqs. 3–4) oracle.  mu/sigma/acc: (n,);
    t_u/t_l: (B,); elig: (B, n) mask → (B, n) probability rows (all-zero
    where a row has no eligible model)."""
    muf = mu.astype(jnp.float32)
    num = t_u.astype(jnp.float32)[:, None] - (muf + sigma.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(t_l.astype(jnp.float32)[:, None] - muf), eps)
    u = jnp.maximum(acc.astype(jnp.float32), eps)[None, :] ** gamma * num / den
    u = jnp.where(elig > 0, u, 0.0)
    total = u.sum(axis=1, keepdims=True)
    cnt = (elig > 0).sum(axis=1, keepdims=True)
    good = jnp.isfinite(total) & (total > 0)
    uniform = (elig > 0) / jnp.maximum(cnt, 1)
    return jnp.where(good, u / jnp.where(good, total, 1.0), uniform)


def modipick_masks_ref(mu, sigma, rank, t_u, t_l, *, pad_rank=1e9):
    """Batched ModiPick stages 1–2 oracle (pure jnp, unpadded shapes).

    mu/sigma: (n,); rank: (n,) position of each model in the
    accuracy-descending order; t_u/t_l: (B,).  Returns
    ``(base, has_base, eligible)``: the Eq. 2 eligibility reduced by
    accuracy-order masked argmin (stage 1) and the window-membership
    matrix with the base forced in (stage 2) — the ground truth for the
    fused device pipeline in ``kernels.policy_select``."""
    tu, tl = t_u[:, None], t_l[:, None]
    mus = (mu + sigma)[None, :]
    elig1 = (mus < tu) & ((mu - sigma)[None, :] < tl)
    has_base = elig1.any(axis=1)
    base = jnp.argmin(jnp.where(elig1, rank[None, :], pad_rank + 1.0),
                      axis=1).astype(jnp.int32)
    half = jnp.abs(t_l - mu[base]) + sigma[base]
    lo, hi = (t_l - half)[:, None], (t_l + half)[:, None]
    natural = (lo <= mu[None, :]) & (mu[None, :] <= hi) & (mus < tu)
    eligible = natural | (jnp.arange(mu.shape[0])[None, :] == base[:, None])
    eligible &= has_base[:, None]
    return base, has_base, eligible


def rglru_scan_ref(a, b):
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t. a,b: (B,S,W)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(h, t):
        at, bt = t
        h = at * h + bt
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(af, 1, 0), jnp.moveaxis(bf, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
