"""Beyond-paper extension: ModiPick over TPU pool configurations.

The paper's pool members are CNNs on one GPU box.  At datacenter scale the
natural pool is (architecture × mesh slice): the same request can be
served by a small model on a small slice or a large model on a big slice,
with latencies that follow from the roofline — which our dry-run derives
per (arch × shape × mesh) from compiled artifacts.  This module builds a
ModiPick zoo directly from those artifacts, so the selection policy the
paper runs over `{MobileNet … NasNet}` runs unchanged over
`{qwen2@v5e-256 … command-r@v5e-256}`.

Latency model per request (prefill P tokens + emit T tokens):
  t(m) = prefill_bound(m) · P/P₀ + T · decode_bound(m) + t_dispatch
with bounds = max(compute, memory, collective) roofline terms from the
dry-run JSONs; σ from a configurable jitter CV (TPU co-tenancy and ICI
congestion take the role the paper gives to cloud co-tenants).
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.zoo import ZooEntry

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


@dataclass(frozen=True)
class TPUPoolMember:
    arch: str
    mesh: str
    prefill_bound_s: float   # for the 32k-token prefill shape
    decode_bound_s: float    # per token
    quality: float


def load_pool(results_dir: str = DEFAULT_DIR, mesh: str = "single"
              ) -> List[TPUPoolMember]:
    from repro.configs.registry import get_config
    by_arch: Dict[str, Dict[str, dict]] = {}
    for f in glob.glob(os.path.join(results_dir, f"*__{mesh}.json")):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("status") != "ok":
            continue
        by_arch.setdefault(r["arch"], {})[r["shape"]] = r
    pool = []
    for arch, shapes in sorted(by_arch.items()):
        if "prefill_32k" not in shapes or "decode_32k" not in shapes:
            continue
        pre = shapes["prefill_32k"]["roofline"]
        dec = shapes["decode_32k"]["roofline"]
        bound = lambda ro: max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        # per-request bounds: prefill is per batch-of-32 32k sequences ⇒
        # per sequence; decode bound is per step for the whole 128-batch.
        pool.append(TPUPoolMember(
            arch=arch, mesh=mesh,
            prefill_bound_s=bound(pre) / 32.0,
            decode_bound_s=bound(dec),
            quality=get_config(arch).quality))
    return pool


def to_zoo(pool: List[TPUPoolMember], *, prefill_tokens: int = 2048,
           decode_tokens: int = 16, jitter_cv: float = 0.05,
           dispatch_ms: float = 2.0) -> List[ZooEntry]:
    """Convert pool members to ModiPick ZooEntries (ms latencies)."""
    entries = []
    for m in pool:
        # scale the 32k prefill bound to the request's prompt length
        t = (m.prefill_bound_s * (prefill_tokens / 32768.0)
             + decode_tokens * m.decode_bound_s) * 1e3 + dispatch_ms
        entries.append(ZooEntry(name=f"{m.arch}@{m.mesh}",
                                top1=m.quality * 100.0,
                                mu_ms=t, sigma_ms=t * jitter_cv))
    return entries
