"""Mobile network models, seeded with the paper's empirical measurements.

The paper simulates input-transfer time from campus-WiFi stats
(μ=57.87ms, σ=30.78ms for a 330KB image) and sweeps the coefficient of
variation (CV = σ/μ) from 0% to 100% in §4.3.  Latencies are sampled from
a truncated normal (≥ 0.1ms floor), matching the paper's setup.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class NetworkModel:
    mean_ms: float
    std_ms: float
    floor_ms: float = 0.1

    @property
    def cv(self) -> float:
        return self.std_ms / max(self.mean_ms, 1e-9)

    def sample(self, rng: np.random.Generator, n: int = 1) -> np.ndarray:
        x = rng.normal(self.mean_ms, self.std_ms, size=n)
        return np.maximum(x, self.floor_ms)

    def sample_one(self, rng: np.random.Generator) -> float:
        """Scalar draw — one standard normal off the stream, exactly
        like ``sample(rng, 1)[0]``, without the length-1 array churn."""
        x = rng.normal(self.mean_ms, self.std_ms)
        return x if x > self.floor_ms else self.floor_ms

    @staticmethod
    def from_cv(mean_ms: float, cv: float) -> "NetworkModel":
        return NetworkModel(mean_ms=mean_ms, std_ms=mean_ms * cv)


def campus_wifi() -> NetworkModel:
    from repro.core.zoo import CAMPUS_WIFI
    return NetworkModel(CAMPUS_WIFI["mean"], CAMPUS_WIFI["std"])


def prototype_wifi() -> NetworkModel:
    from repro.core.zoo import PROTOTYPE_WIFI
    return NetworkModel(PROTOTYPE_WIFI["mean"], PROTOTYPE_WIFI["std"])
