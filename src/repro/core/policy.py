"""Model-selection policies: ModiPick's three-stage algorithm (§3.3) plus
the paper's baselines (§3.2 static/dynamic greedy; §4.4 pure random,
related random, related accurate).

Every policy implements ``select(store, t_budget, rng) -> model name``.
Time units are milliseconds throughout, matching the paper.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import ModelProfile, ProfileStore

EPS = 1e-9


def budget(t_sla: float, t_input: float) -> float:
    """Eq. 1: T_budget = T_sla − 2·T_input (conservative network estimate)."""
    return t_sla - 2.0 * t_input


@dataclass
class SelectionTrace:
    """Full decision record (base model, exploration set, probabilities) —
    used by tests and the decomposition benchmark."""
    chosen: str
    base: Optional[str] = None
    eligible: Tuple[str, ...] = ()
    probs: Tuple[float, ...] = ()
    fallback: bool = False


class Policy:
    name = "policy"

    def select(self, store: ProfileStore, t_budget: float,
               rng: np.random.Generator) -> str:
        return self.select_traced(store, t_budget, rng).chosen

    def select_traced(self, store: ProfileStore, t_budget: float,
                      rng: np.random.Generator) -> SelectionTrace:
        raise NotImplementedError


def _fastest(store: ProfileStore) -> str:
    return min(store.profiles.values(), key=lambda p: p.mu).name


def _by_accuracy(store: ProfileStore) -> List[ModelProfile]:
    return sorted(store.profiles.values(), key=lambda p: -p.accuracy)


class StaticGreedy(Policy):
    """§3.2.1: development-time pick — most accurate model whose average
    inference time fits the *SLA itself* (no network correction).  The
    chosen model is frozen at construction time against the dev-time
    profiles, exactly like a developer hard-coding an endpoint."""
    name = "static_greedy"

    def __init__(self, t_sla: float):
        self.t_sla = t_sla
        self._frozen: Optional[str] = None

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        if self._frozen is None:
            for p in _by_accuracy(store):
                if p.mu <= self.t_sla:
                    self._frozen = p.name
                    break
            else:
                self._frozen = _fastest(store)
        return SelectionTrace(chosen=self._frozen)


class DynamicGreedy(Policy):
    """§3.2.2: runtime pick — most accurate model with μ ≤ T_budget."""
    name = "dynamic_greedy"

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        for p in _by_accuracy(store):
            if p.mu <= t_budget:
                return SelectionTrace(chosen=p.name)
        return SelectionTrace(chosen=_fastest(store), fallback=True)


class ModiPick(Policy):
    """The paper's three-stage probabilistic selection (§3.3).

    t_threshold ∈ [0, T_D] controls the exploration window: T_U = T_budget,
    T_L = T_U − t_threshold.

    gamma: exponent on A(m) in the utility.  gamma=1.0 is Eq. 3 exactly as
    printed.  Reproduction note (EXPERIMENTS.md §Fig9): with gamma=1 two
    models sharing a latency profile split probability ∝ accuracy, so the
    adversarial NasNet-Fictional (A=0.50 vs 0.826) is picked ≈38% of the
    time — *not* the "low probability" the paper reports.  gamma≈4 recovers
    the paper's qualitative Fig. 9 behaviour (low-but-nonzero exploration
    of the fictional model); both settings are benchmarked.
    """
    name = "modipick"

    def __init__(self, t_threshold: float, gamma: float = 1.0):
        assert t_threshold >= 0.0
        self.t_threshold = t_threshold
        self.gamma = gamma

    # -- stage 1: greedy base pick (Eq. 2) ------------------------------
    def _base_model(self, store, t_u, t_l) -> Optional[str]:
        for p in _by_accuracy(store):
            if p.mu + p.sigma < t_u and p.mu - p.sigma < t_l:
                return p.name
        return None

    # -- stage 2: exploration set --------------------------------------
    def _eligible(self, store, base: str, t_u, t_l) -> List[str]:
        bp = store[base]
        half = abs(t_l - bp.mu) + bp.sigma
        lo, hi = t_l - half, t_l + half
        out = []
        for p in store.profiles.values():
            if lo <= p.mu <= hi and p.mu + p.sigma < t_u:
                out.append(p.name)
        if base not in out:  # base always eligible by construction
            out.append(base)
        return out

    # -- stage 3: utility-weighted sampling (Eqs. 3–4) ------------------
    def _probs(self, store, eligible: Sequence[str], t_u, t_l) -> np.ndarray:
        u = np.empty(len(eligible))
        for i, name in enumerate(eligible):
            p = store[name]
            num = t_u - (p.mu + p.sigma)  # > 0 by stage-2 constraint
            den = max(abs(t_l - p.mu), EPS)
            u[i] = max(p.accuracy, EPS) ** self.gamma * num / den
        total = u.sum()
        if not math.isfinite(total) or total <= 0:
            return np.full(len(eligible), 1.0 / len(eligible))
        return u / total

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        t_u = t_budget
        t_l = t_u - self.t_threshold
        base = self._base_model(store, t_u, t_l)
        if base is None:
            # best-effort fallback: fastest model (§3.3.1)
            return SelectionTrace(chosen=_fastest(store), fallback=True)
        eligible = self._eligible(store, base, t_u, t_l)
        probs = self._probs(store, eligible, t_u, t_l)
        idx = int(rng.choice(len(eligible), p=probs))
        return SelectionTrace(chosen=eligible[idx], base=base,
                              eligible=tuple(eligible), probs=tuple(probs))


class PureRandom(Policy):
    """§4.4 stage-1 counterpart: uniform over all managed models."""
    name = "pure_random"

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        names = store.names()
        return SelectionTrace(chosen=names[int(rng.integers(len(names)))])


class _ExplorationSetPolicy(ModiPick):
    """Shares ModiPick stages 1–2, replaces stage 3."""

    def _pick_from(self, store, eligible, rng) -> str:
        raise NotImplementedError

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        t_u = t_budget
        t_l = t_u - self.t_threshold
        base = self._base_model(store, t_u, t_l)
        if base is None:
            return SelectionTrace(chosen=_fastest(store), fallback=True)
        eligible = self._eligible(store, base, t_u, t_l)
        return SelectionTrace(chosen=self._pick_from(store, eligible, rng),
                              base=base, eligible=tuple(eligible))


class RelatedRandom(_ExplorationSetPolicy):
    """§4.4 stage-3 counterpart: uniform over the exploration set M_E."""
    name = "related_random"

    def _pick_from(self, store, eligible, rng) -> str:
        return eligible[int(rng.integers(len(eligible)))]


class RelatedAccurate(_ExplorationSetPolicy):
    """§4.4 stage-3 counterpart: most accurate model in M_E."""
    name = "related_accurate"

    def _pick_from(self, store, eligible, rng) -> str:
        return max(eligible, key=lambda n: store[n].accuracy)
