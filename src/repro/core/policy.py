"""Model-selection policies: ModiPick's three-stage algorithm (§3.3) plus
the paper's baselines (§3.2 static/dynamic greedy; §4.4 pure random,
related random, related accurate).

Every policy implements ``select(store, t_budget, rng) -> model name``
and ``select_batch(store, t_budgets, rng) -> names`` (the vectorized
fan-out in ``core.policy_vec``).  The scalar path is a batch-of-1 view
over the store's :class:`~repro.core.profiles.ProfileTable` snapshot —
the accuracy-descending order is cached on the store and invalidated by
its dirty flag, so nothing here re-sorts the pool per request.

Time units are milliseconds throughout, matching the paper.
"""
from __future__ import annotations

import math
from bisect import bisect_right
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import ProfileStore, ProfileTable

EPS = 1e-9


def budget(t_sla: float, t_input: float) -> float:
    """Eq. 1: T_budget = T_sla − 2·T_input (conservative network estimate)."""
    return t_sla - 2.0 * t_input


@dataclass
class SelectionTrace:
    """Full decision record (base model, exploration set, probabilities) —
    used by tests and the decomposition benchmark."""
    chosen: str
    base: Optional[str] = None
    eligible: Tuple[str, ...] = ()
    probs: Tuple[float, ...] = ()
    fallback: bool = False


class Policy:
    name = "policy"

    def select(self, store: ProfileStore, t_budget: float,
               rng: np.random.Generator) -> str:
        return self.select_traced(store, t_budget, rng).chosen

    def select_traced(self, store: ProfileStore, t_budget: float,
                      rng: np.random.Generator) -> SelectionTrace:
        raise NotImplementedError

    def select_batch(self, store: ProfileStore, t_budgets,
                     rng: np.random.Generator, *,
                     backend: Optional[str] = None) -> List[str]:
        """Vectorized selection for a batch of budgets; see
        ``repro.core.policy_vec.select_batch``."""
        from repro.core import policy_vec
        return policy_vec.select_batch(self, store, t_budgets, rng,
                                       backend=backend)

    def select_lean(self, store: ProfileStore, t_budget: float,
                    rng: np.random.Generator) -> SelectionTrace:
        """Hot-path scalar selection: identical pick and RNG consumption
        to :meth:`select_traced`, but the returned trace carries only
        ``chosen`` + ``fallback`` (no eligible/probs tuples).  Policies
        without a cheaper core just run the full trace."""
        return self.select_traced(store, t_budget, rng)


def _fastest(store: ProfileStore) -> str:
    tab = store.table()
    return tab.names[tab.fastest]


class StaticGreedy(Policy):
    """§3.2.1: development-time pick — most accurate model whose average
    inference time fits the *SLA itself* (no network correction).  The
    chosen model is frozen the first time the policy sees a store,
    exactly like a developer hard-coding an endpoint.  Presenting a
    *different* store re-freezes against it (each store is a different
    dev-time profiling run), so one policy instance can be reused across
    ``rate_sweep`` points without leaking the previous run's pick;
    ``reset()`` forces the next call to re-freeze.  Store identity
    follows ``store.base``, so the per-selection shifted views built by
    queue-aware wrapping do not thaw the pick."""
    name = "static_greedy"

    def __init__(self, t_sla: float):
        self.t_sla = t_sla
        self._frozen: Optional[str] = None
        self._frozen_store: Optional[ProfileStore] = None

    def reset(self) -> None:
        self._frozen = None
        self._frozen_store = None

    def freeze_pick(self, tab: ProfileTable) -> str:
        """Dev-time choice against a snapshot: most accurate model with
        μ ≤ T_sla, else the fastest."""
        for i in tab.acc_order:
            if tab.mu[i] <= self.t_sla:
                return tab.names[i]
        return tab.names[tab.fastest]

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        root = getattr(store, "base", store)
        if self._frozen is None or self._frozen_store is not root:
            self._frozen = self.freeze_pick(root.table())
            self._frozen_store = root
        return SelectionTrace(chosen=self._frozen)


class DynamicGreedy(Policy):
    """§3.2.2: runtime pick — most accurate model with μ ≤ T_budget."""
    name = "dynamic_greedy"

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        tab = store.table()
        for i in tab.acc_order:
            if tab.mu[i] <= t_budget:
                return SelectionTrace(chosen=tab.names[i])
        return SelectionTrace(chosen=tab.names[tab.fastest], fallback=True)

    def select_lean(self, store, t_budget, rng) -> SelectionTrace:
        """Same greedy walk over the snapshot's python-float cache —
        identical comparisons, no numpy scalar boxing per step."""
        tab = store.table()
        mu, _, _, _, order, names = tab.scalar_cache()
        for i in order:
            if mu[i] <= t_budget:
                return SelectionTrace(chosen=names[i])
        return SelectionTrace(chosen=names[tab.fastest], fallback=True)


class ModiPick(Policy):
    """The paper's three-stage probabilistic selection (§3.3).

    t_threshold ∈ [0, T_D] controls the exploration window: T_U = T_budget,
    T_L = T_U − t_threshold.

    gamma: exponent on A(m) in the utility.  gamma=1.0 is Eq. 3 exactly as
    printed.  Reproduction note (EXPERIMENTS.md §Fig9): with gamma=1 two
    models sharing a latency profile split probability ∝ accuracy, so the
    adversarial NasNet-Fictional (A=0.50 vs 0.826) is picked ≈38% of the
    time — *not* the "low probability" the paper reports.  gamma≈4 recovers
    the paper's qualitative Fig. 9 behaviour (low-but-nonzero exploration
    of the fictional model); both settings are benchmarked.
    """
    name = "modipick"

    def __init__(self, t_threshold: float, gamma: float = 1.0):
        assert t_threshold >= 0.0
        self.t_threshold = t_threshold
        self.gamma = gamma

    # -- stage 1: greedy base pick (Eq. 2) ------------------------------
    def _base_index(self, tab: ProfileTable, t_u, t_l) -> Optional[int]:
        for i in tab.acc_order:
            if tab.mu[i] + tab.sigma[i] < t_u and tab.mu[i] - tab.sigma[i] < t_l:
                return int(i)
        return None

    def _base_model(self, store, t_u, t_l) -> Optional[str]:
        tab = store.table()
        i = self._base_index(tab, t_u, t_l)
        return None if i is None else tab.names[i]

    # -- stage 2: exploration set --------------------------------------
    def _eligible_indices(self, tab: ProfileTable, base_idx: int,
                          t_u, t_l) -> List[int]:
        half = abs(t_l - tab.mu[base_idx]) + tab.sigma[base_idx]
        lo, hi = t_l - half, t_l + half
        mask = (lo <= tab.mu) & (tab.mu <= hi) & (tab.mu + tab.sigma < t_u)
        out = [int(i) for i in np.flatnonzero(mask)]
        if base_idx not in out:  # base always eligible by construction
            out.append(base_idx)
        return out

    def _eligible(self, store, base: str, t_u, t_l) -> List[str]:
        tab = store.table()
        return [tab.names[i]
                for i in self._eligible_indices(tab, tab.index[base], t_u, t_l)]

    # -- stage 3: utility-weighted sampling (Eqs. 3–4) ------------------
    def _probs_indices(self, tab: ProfileTable, idxs: Sequence[int],
                       t_u, t_l) -> np.ndarray:
        mu, sigma = tab.mu[idxs], tab.sigma[idxs]
        num = t_u - (mu + sigma)  # > 0 by stage-2 constraint
        den = np.maximum(np.abs(t_l - mu), EPS)
        u = np.maximum(tab.accuracy[idxs], EPS) ** self.gamma * num / den
        total = u.sum()
        if not math.isfinite(total) or total <= 0:
            return np.full(len(u), 1.0 / len(u))
        return u / total

    def _probs(self, store, eligible: Sequence[str], t_u, t_l) -> np.ndarray:
        tab = store.table()
        return self._probs_indices(tab, [tab.index[n] for n in eligible],
                                   t_u, t_l)

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        tab = store.table()
        t_u = t_budget
        t_l = t_u - self.t_threshold
        base_idx = self._base_index(tab, t_u, t_l)
        if base_idx is None:
            # best-effort fallback: fastest model (§3.3.1)
            return SelectionTrace(chosen=tab.names[tab.fastest], fallback=True)
        idxs = self._eligible_indices(tab, base_idx, t_u, t_l)
        probs = self._probs_indices(tab, idxs, t_u, t_l)
        pick = int(rng.choice(len(idxs), p=probs))
        return SelectionTrace(chosen=tab.names[idxs[pick]],
                              base=tab.names[base_idx],
                              eligible=tuple(tab.names[i] for i in idxs),
                              probs=tuple(probs))

    def select_lean(self, store, t_budget, rng) -> SelectionTrace:
        """Bit-identical scalar hot path: every stage re-expressed over
        the snapshot's python-float ``scalar_cache`` and the categorical
        draw replicated from ``Generator.choice``'s internals (cumsum,
        tail-normalize, one uniform, right-bisect) — same IEEE doubles,
        same RNG consumption, same pick as :meth:`select_traced`, with
        no numpy dispatch or trace materialisation per request.  Pools
        wider than 8 fall back to the numpy stages (numpy's pairwise
        summation stops being replicable past its 8-lane unroll)."""
        tab = store.table()
        mu, sigma, musig, acc, order, names = tab.scalar_cache()
        t_u = t_budget
        t_l = t_u - self.t_threshold
        base_idx = -1
        for i in order:
            if musig[i] < t_u and mu[i] - sigma[i] < t_l:
                base_idx = i
                break
        if base_idx < 0:
            return SelectionTrace(chosen=names[tab.fastest], fallback=True)
        half = abs(t_l - mu[base_idx]) + sigma[base_idx]
        lo, hi = t_l - half, t_l + half
        idxs = [i for i in range(len(mu))
                if lo <= mu[i] <= hi and musig[i] < t_u]
        if base_idx not in idxs:  # base always eligible by construction
            idxs.append(base_idx)
        k = len(idxs)
        if k > 8:
            probs = self._probs_indices(tab, idxs, t_u, t_l)
            pick = int(rng.choice(k, p=probs))
            return SelectionTrace(chosen=names[idxs[pick]])
        # Eq. 3–4 utilities, element-for-element the ops of
        # ``_probs_indices`` (python floats are the same IEEE doubles;
        # pow(x, 1.0) == x exactly, so γ=1 skips the libm call).
        g = self.gamma
        if g == 1.0:
            u = [(acc[i] if acc[i] > EPS else EPS)
                 * (t_u - musig[i])
                 / (den if (den := abs(t_l - mu[i])) > EPS else EPS)
                 for i in idxs]
        else:
            u = [(acc[i] if acc[i] > EPS else EPS) ** g
                 * (t_u - musig[i])
                 / (den if (den := abs(t_l - mu[i])) > EPS else EPS)
                 for i in idxs]
        # numpy's small-n sum: sequential below 8, 8-lane tree at 8.
        if k == 8:
            total = ((u[0] + u[1]) + (u[2] + u[3])) \
                + ((u[4] + u[5]) + (u[6] + u[7]))
        else:
            total = 0.0
            for x in u:
                total += x
        if not math.isfinite(total) or total <= 0:
            u = [1.0 / k] * k
        else:
            u = [x / total for x in u]
        # Generator.choice(k, p=u) replica: cumsum, normalize by the
        # tail, one uniform, searchsorted-right.
        cdf = []
        t = 0.0
        for x in u:
            t += x
            cdf.append(t)
        last = cdf[-1]
        if last != 1.0:
            cdf = [c / last for c in cdf]
        pick = bisect_right(cdf, rng.random())
        if pick >= k:  # float tail guard, as searchsorted clips
            pick = k - 1
        return SelectionTrace(chosen=names[idxs[pick]])


class PureRandom(Policy):
    """§4.4 stage-1 counterpart: uniform over all managed models."""
    name = "pure_random"

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        tab = store.table()
        return SelectionTrace(chosen=tab.names[int(rng.integers(len(tab)))])


class _ExplorationSetPolicy(ModiPick):
    """Shares ModiPick stages 1–2, replaces stage 3."""

    def _pick_from(self, store, eligible, rng) -> str:
        raise NotImplementedError

    # ModiPick's lean core runs ModiPick's stage 3 — subclasses replace
    # stage 3, so they must fall back to their own full trace.
    select_lean = Policy.select_lean

    def select_traced(self, store, t_budget, rng) -> SelectionTrace:
        tab = store.table()
        t_u = t_budget
        t_l = t_u - self.t_threshold
        base_idx = self._base_index(tab, t_u, t_l)
        if base_idx is None:
            return SelectionTrace(chosen=tab.names[tab.fastest], fallback=True)
        eligible = [tab.names[i]
                    for i in self._eligible_indices(tab, base_idx, t_u, t_l)]
        return SelectionTrace(chosen=self._pick_from(store, eligible, rng),
                              base=tab.names[base_idx],
                              eligible=tuple(eligible))


class RelatedRandom(_ExplorationSetPolicy):
    """§4.4 stage-3 counterpart: uniform over the exploration set M_E."""
    name = "related_random"

    def _pick_from(self, store, eligible, rng) -> str:
        return eligible[int(rng.integers(len(eligible)))]


class RelatedAccurate(_ExplorationSetPolicy):
    """§4.4 stage-3 counterpart: most accurate model in M_E."""
    name = "related_accurate"

    def _pick_from(self, store, eligible, rng) -> str:
        return max(eligible, key=lambda n: store[n].accuracy)


# Name -> class registry: the declarative-config axis (PolicySpec in
# ``repro.scenario`` builds policies from strings, mirroring
# ``router.admission.make_admission``).
POLICIES = {
    "static_greedy": StaticGreedy,
    "dynamic_greedy": DynamicGreedy,
    "modipick": ModiPick,
    "pure_random": PureRandom,
    "related_random": RelatedRandom,
    "related_accurate": RelatedAccurate,
}


def make_policy(name: str, **kwargs) -> Policy:
    """Build a policy from its registry name (``modipick``,
    ``dynamic_greedy``, ...) and constructor kwargs."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r} "
                         f"(valid: {', '.join(sorted(POLICIES))})")
    return cls(**kwargs)
