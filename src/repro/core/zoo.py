"""The paper's model zoo (Table 2) and measured reference points.

Latency/σ measured by the authors on an EC2 p2.xlarge GPU server over
1,000 runs; accuracies are ImageNet top-1 from the original publications.
``NASNET_FICTIONAL`` is the adversarial pool member used in §4.4 (same
latency profile as NasNet Large, accuracy 50%).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core.profiles import ModelProfile


@dataclass(frozen=True)
class ZooEntry:
    name: str
    top1: float        # %
    mu_ms: float
    sigma_ms: float


TABLE2: List[ZooEntry] = [
    ZooEntry("SqueezeNet", 49.0, 4.91, 0.06),
    ZooEntry("MobileNetV1-0.25", 49.7, 3.21, 0.08),
    ZooEntry("MobileNetV1-0.5", 63.2, 4.21, 0.06),
    ZooEntry("DenseNet", 64.2, 25.49, 0.14),
    ZooEntry("MobileNetV1-0.75", 68.3, 4.67, 0.07),
    ZooEntry("MobileNetV1-1.0", 71.0, 5.43, 0.11),
    ZooEntry("NasNet-Mobile", 73.9, 21.18, 0.17),
    ZooEntry("InceptionResNetV2", 77.5, 50.85, 0.33),
    ZooEntry("InceptionV3", 77.9, 31.11, 0.19),
    ZooEntry("InceptionV4", 80.1, 59.21, 0.22),
    ZooEntry("NasNet-Large", 82.6, 112.61, 0.36),
]

NASNET_FICTIONAL = ZooEntry("NasNet-Fictional", 50.0, 112.61, 0.36)

# Prototype pool (§4.1): two retrained models on the small dataset.
PROTOTYPE_POOL: List[ZooEntry] = [
    ZooEntry("MobileNetV1-0.25", 88.9, 3.21, 0.08),
    ZooEntry("InceptionV3", 94.3, 31.11, 0.19),
]

# Fig. 1 / §4: empirical mobile network stats (ms, one-way input transfer).
CAMPUS_WIFI = {"mean": 57.87, "std": 30.78}
PROTOTYPE_WIFI = {"mean": 63.0, "std": 30.0}

# Fig. 3: on-device reference latencies (ms) on a MotoX.
ON_DEVICE = {"MobileNetV1-0.25": 150.0, "MobileNetV1-1.0": 435.0,
             "InceptionV4": 3900.0}
# Fig. 3: server-side InceptionV4 on p2.xlarge ≈ 59 ms.


def true_profiles(entries: List[ZooEntry]) -> Dict[str, ZooEntry]:
    return {e.name: e for e in entries}


def make_store(entries: List[ZooEntry], *, alpha: float = 0.1,
               cold_age: int = 500, warm: bool = True,
               profile: str = "ewma", window: int = 64,
               stale_after: int = 400, explore_bonus: float = 0.9):
    """Build a ProfileStore; ``warm`` seeds profiles at the true (μ, σ)
    like the paper's 1000-request warm-up.

    ``profile`` picks the estimator family: ``"ewma"`` (the paper's
    EWMA store — the default, and the only mode existing call sites
    see), ``"window"`` (sliding-window + staleness exploration —
    ``WindowedProfileStore``), ``"frozen"`` (never updates — the
    drift-ablation baseline).  The window knobs are ignored outside
    ``"window"`` mode."""
    from repro.core.profiles import (FrozenProfileStore, ProfileStore,
                                     WindowedProfileStore)
    profiles = []
    for e in entries:
        p = ModelProfile(name=e.name, accuracy=e.top1 / 100.0)
        profiles.append(p)
    if profile == "window":
        store = WindowedProfileStore(
            profiles, alpha=alpha, cold_age=cold_age, window=window,
            stale_after=stale_after, explore_bonus=explore_bonus)
    elif profile == "frozen":
        store = FrozenProfileStore(profiles, alpha=alpha, cold_age=cold_age)
    elif profile == "ewma":
        store = ProfileStore(profiles, alpha=alpha, cold_age=cold_age)
    else:
        raise ValueError(f"unknown profile mode {profile!r} "
                         "(expected ewma|window|frozen)")
    if warm:
        for e in entries:
            if isinstance(store, WindowedProfileStore):
                store.warm_seed(e.name, e.mu_ms, e.sigma_ms ** 2,
                                n_obs=1000)
            else:
                p = store[e.name]
                p.mu = e.mu_ms
                p.var = e.sigma_ms ** 2
                p.n_obs = 1000
        store.invalidate()  # direct field writes bypass the dirty flag
    return store
