"""Model performance profiles: EWMA μ/σ per model + cold-model refresh.

Faithful to ModiPick §3.3 "Practical considerations": profiles are
exponentially-weighted moving averages of observed inference latency, so
they track drift (co-tenant interference, server load) without unbounded
history; models not selected recently are flagged for periodic re-probing
so one bad sample cannot permanently exile an accurate model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


@dataclass
class ModelProfile:
    name: str
    accuracy: float            # A(m): quality score in [0, 1]
    mu: float = 0.0            # EWMA mean inference time (ms)
    var: float = 0.0           # EWMA variance (ms²)
    n_obs: int = 0
    last_selected: int = 0     # request counter at last selection
    queue_mu: float = 0.0      # EWMA queue wait (ms) at this model's replica
    queue_obs: int = 0

    @property
    def sigma(self) -> float:
        return math.sqrt(max(self.var, 0.0))

    def update(self, latency_ms: float, alpha: float) -> None:
        if self.n_obs == 0:
            self.mu = latency_ms
            self.var = 0.0
        else:
            delta = latency_ms - self.mu
            self.mu += alpha * delta
            # EW variance (West 1979 incremental form)
            self.var = (1.0 - alpha) * (self.var + alpha * delta * delta)
        self.n_obs += 1

    def update_queue(self, wait_ms: float, alpha: float) -> None:
        """EWMA of the queue wait observed in front of this model's
        replica — the telemetry behind queue-aware budgets."""
        if self.queue_obs == 0:
            self.queue_mu = wait_ms
        else:
            self.queue_mu += alpha * (wait_ms - self.queue_mu)
        self.queue_obs += 1


class ProfileTable:
    """Structure-of-arrays snapshot of a :class:`ProfileStore`.

    Selection math (``core.policy`` / ``core.policy_vec``) runs over
    contiguous ``mu``/``sigma``/``accuracy``/``queue_mu`` arrays instead
    of a dict of dataclasses, and the accuracy-descending order — which
    every greedy stage needs — is computed once per snapshot instead of
    re-sorted per call.  Array positions follow the store's insertion
    order, so index ``i`` everywhere means "the i-th managed model".
    """

    __slots__ = ("names", "index", "accuracy", "mu", "sigma", "queue_mu",
                 "acc_order", "fastest")

    def __init__(self, names: Tuple[str, ...], accuracy: np.ndarray,
                 mu: np.ndarray, sigma: np.ndarray, queue_mu: np.ndarray,
                 acc_order: Optional[np.ndarray] = None):
        self.names = tuple(names)
        self.index: Dict[str, int] = {n: i for i, n in enumerate(self.names)}
        self.accuracy = accuracy
        self.mu = mu
        self.sigma = sigma
        self.queue_mu = queue_mu
        # Stable sort ties on insertion order — matches
        # ``sorted(profiles, key=lambda p: -p.accuracy)`` exactly.
        self.acc_order = (np.argsort(-accuracy, kind="stable")
                          if acc_order is None else acc_order)
        self.fastest = int(np.argmin(mu))

    @classmethod
    def from_store(cls, store: "ProfileStore") -> "ProfileTable":
        ps = list(store.profiles.values())
        return cls(
            names=tuple(p.name for p in ps),
            accuracy=np.array([p.accuracy for p in ps], dtype=np.float64),
            mu=np.array([p.mu for p in ps], dtype=np.float64),
            sigma=np.array([p.sigma for p in ps], dtype=np.float64),
            queue_mu=np.array([p.queue_mu for p in ps], dtype=np.float64),
        )

    def shifted(self, shifts: np.ndarray) -> "ProfileTable":
        """Table with ``mu + shifts`` (the queue-aware view: waits folded
        into the location of the latency distribution).  Accuracy — and
        therefore the cached order — is unchanged; ``queue_mu`` is zeroed
        because the shift has consumed it."""
        return ProfileTable(self.names, self.accuracy, self.mu + shifts,
                            self.sigma, np.zeros_like(self.queue_mu),
                            acc_order=self.acc_order)

    def __len__(self) -> int:
        return len(self.names)


class ProfileStore:
    """Pool of model profiles with ModiPick's maintenance rules."""

    def __init__(self, models: Iterable[ModelProfile], *, alpha: float = 0.1,
                 cold_age: int = 500):
        self.profiles: Dict[str, ModelProfile] = {m.name: m for m in models}
        self.alpha = alpha
        self.cold_age = cold_age
        self.step = 0
        self._table: Optional[ProfileTable] = None
        # Identity root for derived views: ``router.queueaware.shifted_store``
        # points its per-selection views back at the store they shadow, so
        # store-identity semantics (StaticGreedy's freeze) survive wrapping.
        self.base: "ProfileStore" = self

    def names(self) -> List[str]:
        return list(self.profiles)

    def __getitem__(self, name: str) -> ModelProfile:
        return self.profiles[name]

    def table(self) -> ProfileTable:
        """SoA snapshot, rebuilt lazily after ``observe``/``observe_queue``
        (dirty flag) rather than re-derived per selection.  Callers that
        mutate ``ModelProfile`` fields directly must call
        :meth:`invalidate` themselves."""
        if self._table is None:
            self._table = ProfileTable.from_store(self)
        return self._table

    def invalidate(self) -> None:
        self._table = None

    def observe(self, name: str, latency_ms: float) -> None:
        self.profiles[name].update(latency_ms, self.alpha)
        self._table = None

    def observe_queue(self, name: str, wait_ms: float) -> None:
        self.profiles[name].update_queue(wait_ms, self.alpha)
        self._table = None

    def queue_wait(self, name: str) -> float:
        """Estimated queue wait W_queue(m) from telemetry (0 until the
        first observation)."""
        return self.profiles[name].queue_mu

    def mark_selected(self, name: str) -> None:
        self.step += 1
        self.profiles[name].last_selected = self.step

    def cold_models(self) -> List[str]:
        """Models whose profile is stale and due a re-probe."""
        return [
            m.name for m in self.profiles.values()
            if m.n_obs == 0 or (self.step - m.last_selected) > self.cold_age
        ]

    def warm_up(self, name: str, samples: Iterable[float]) -> None:
        for s in samples:
            self.observe(name, s)

    def snapshot(self) -> Dict[str, dict]:
        return {
            n: {"mu": p.mu, "sigma": p.sigma, "accuracy": p.accuracy,
                "n_obs": p.n_obs, "queue_mu": p.queue_mu}
            for n, p in self.profiles.items()
        }
