"""Model performance profiles: EWMA μ/σ per model + cold-model refresh.

Faithful to ModiPick §3.3 "Practical considerations": profiles are
exponentially-weighted moving averages of observed inference latency, so
they track drift (co-tenant interference, server load) without unbounded
history; models not selected recently are flagged for periodic re-probing
so one bad sample cannot permanently exile an accurate model.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, List, Optional, Tuple

import numpy as np


def _valid_sample(x: float) -> bool:
    """A usable latency/wait sample: finite and non-negative.  NaN fails
    the comparison, ±inf fails ``isfinite`` — fault-injected failure
    signals (inf waits from dead replicas) must never reach the EWMA."""
    return x >= 0.0 and math.isfinite(x)


@dataclass
class ModelProfile:
    name: str
    accuracy: float            # A(m): quality score in [0, 1]
    mu: float = 0.0            # EWMA mean inference time (ms)
    var: float = 0.0           # EWMA variance (ms²)
    n_obs: int = 0
    last_selected: int = 0     # request counter at last selection
    queue_mu: float = 0.0      # EWMA queue wait (ms) at this model's replica
    queue_obs: int = 0

    @property
    def sigma(self) -> float:
        return math.sqrt(max(self.var, 0.0))

    def update(self, latency_ms: float, alpha: float) -> None:
        if self.n_obs == 0:
            self.mu = latency_ms
            self.var = 0.0
        else:
            delta = latency_ms - self.mu
            self.mu += alpha * delta
            # EW variance (West 1979 incremental form)
            self.var = (1.0 - alpha) * (self.var + alpha * delta * delta)
        self.n_obs += 1

    def update_queue(self, wait_ms: float, alpha: float) -> None:
        """EWMA of the queue wait observed in front of this model's
        replica — the telemetry behind queue-aware budgets."""
        if self.queue_obs == 0:
            self.queue_mu = wait_ms
        else:
            self.queue_mu += alpha * (wait_ms - self.queue_mu)
        self.queue_obs += 1


class ProfileTable:
    """Structure-of-arrays snapshot of a :class:`ProfileStore`.

    Selection math (``core.policy`` / ``core.policy_vec``) runs over
    contiguous ``mu``/``sigma``/``accuracy``/``queue_mu`` arrays instead
    of a dict of dataclasses, and the accuracy-descending order — which
    every greedy stage needs — is computed once per snapshot instead of
    re-sorted per call.  Array positions follow the store's insertion
    order, so index ``i`` everywhere means "the i-th managed model".
    """

    __slots__ = ("names", "index", "accuracy", "mu", "sigma", "queue_mu",
                 "acc_order", "fastest", "_device", "_scalar")

    def __init__(self, names: Tuple[str, ...], accuracy: np.ndarray,
                 mu: np.ndarray, sigma: np.ndarray, queue_mu: np.ndarray,
                 acc_order: Optional[np.ndarray] = None,
                 index: Optional[Dict[str, int]] = None):
        self.names = tuple(names)
        self.index: Dict[str, int] = (
            index if index is not None
            else {n: i for i, n in enumerate(self.names)})
        self.accuracy = accuracy
        self.mu = mu
        self.sigma = sigma
        self.queue_mu = queue_mu
        # Stable sort ties on insertion order — matches
        # ``sorted(profiles, key=lambda p: -p.accuracy)`` exactly.
        self.acc_order = (np.argsort(-accuracy, kind="stable")
                          if acc_order is None else acc_order)
        self.fastest = int(np.argmin(mu))
        self._device = None
        self._scalar = None

    @classmethod
    def from_store(cls, store: "ProfileStore") -> "ProfileTable":
        ps = list(store.profiles.values())
        return cls(
            names=tuple(p.name for p in ps),
            accuracy=np.array([p.accuracy for p in ps], dtype=np.float64),
            mu=np.array([p.mu for p in ps], dtype=np.float64),
            sigma=np.array([p.sigma for p in ps], dtype=np.float64),
            queue_mu=np.array([p.queue_mu for p in ps], dtype=np.float64),
        )

    def shifted(self, shifts: np.ndarray) -> "ProfileTable":
        """Table with ``mu + shifts`` (the queue-aware view: waits folded
        into the location of the latency distribution).  Accuracy — and
        therefore the cached order — is unchanged; ``queue_mu`` is zeroed
        because the shift has consumed it.  The name index is shared
        with the base table (same names, same positions)."""
        return ProfileTable(self.names, self.accuracy, self.mu + shifts,
                            self.sigma, np.zeros_like(self.queue_mu),
                            acc_order=self.acc_order, index=self.index)

    def device_pool(self):
        """128-lane-padded device-side operands of the fused selection
        pipeline (``kernels.policy_select.DevicePool``), built once per
        snapshot — the freeze-time padding that keeps per-call dispatch
        free of host-side shape work."""
        if self._device is None:
            from repro.kernels.policy_select import DevicePool
            self._device = DevicePool(self.mu, self.sigma, self.accuracy,
                                      self.acc_order, self.fastest)
        return self._device

    def refresh(self, i: int, mu: float, sigma: float,
                queue_mu: float) -> None:
        """In-place profile update for position ``i`` — the observe hot
        path.  Accuracy never drifts, so ``acc_order`` is untouched;
        ``fastest`` is re-derived, the device-side padding is dropped
        (rebuilt lazily on the next fused selection) and the scalar-path
        float lists are patched to match."""
        self.mu[i] = mu
        self.sigma[i] = sigma
        self.queue_mu[i] = queue_mu
        # argmin only when the write can actually move the minimum:
        # a faster-than-fastest value, a tie that could re-rank by
        # index, or an update of the current minimum itself.
        if i == self.fastest or mu <= self.mu[self.fastest]:
            self.fastest = int(np.argmin(self.mu))
        self._device = None
        s = self._scalar
        if s is not None:
            m, g = float(mu), float(sigma)
            s[0][i] = m
            s[1][i] = g
            s[2][i] = m + g

    def scalar_cache(self):
        """Python-float views for the scalar selection hot path:
        ``(mu, sigma, mu_plus_sigma, accuracy, acc_order, names)`` as
        plain lists — element-for-element the same IEEE doubles as the
        numpy columns (``tolist`` round-trips exactly; the ``mu+sigma``
        list matches the elementwise array add the batched path uses)."""
        if self._scalar is None:
            mu = self.mu.tolist()
            sigma = self.sigma.tolist()
            self._scalar = (mu, sigma, (self.mu + self.sigma).tolist(),
                            self.accuracy.tolist(),
                            self.acc_order.tolist(), list(self.names))
        return self._scalar

    def __len__(self) -> int:
        return len(self.names)


class ProfileStore:
    """Pool of model profiles with ModiPick's maintenance rules."""

    # Class-level default so derived views that bypass ``__init__``
    # (``router.queueaware._ShiftedView``) still read 0; the in-place
    # increment creates the instance attribute on first rejection.
    n_rejected_samples = 0

    def __init__(self, models: Iterable[ModelProfile], *, alpha: float = 0.1,
                 cold_age: int = 500):
        self.profiles: Dict[str, ModelProfile] = {m.name: m for m in models}
        self.alpha = alpha
        self.cold_age = cold_age
        self.step = 0
        # Monotone mutation counter: derived snapshots beyond the one
        # cached table (per-class tables, stacked device pools) compare
        # against it to detect staleness without subscribing to every
        # observe call.  Bumped on accepted telemetry and invalidation.
        self.version = 0
        self._table: Optional[ProfileTable] = None
        # Identity root for derived views: ``router.queueaware.shifted_store``
        # points its per-selection views back at the store they shadow, so
        # store-identity semantics (StaticGreedy's freeze) survive wrapping.
        self.base: "ProfileStore" = self

    def names(self) -> List[str]:
        return list(self.profiles)

    def __getitem__(self, name: str) -> ModelProfile:
        return self.profiles[name]

    def table(self) -> ProfileTable:
        """SoA snapshot, rebuilt lazily after ``observe``/``observe_queue``
        (dirty flag) rather than re-derived per selection.  Callers that
        mutate ``ModelProfile`` fields directly must call
        :meth:`invalidate` themselves."""
        if self._table is None:
            self._table = ProfileTable.from_store(self)
        return self._table

    def invalidate(self) -> None:
        self.version += 1
        self._table = None

    def observe(self, name: str, latency_ms: float) -> None:
        if not _valid_sample(latency_ms):
            self.n_rejected_samples += 1
            return
        p = self.profiles[name]
        p.update(latency_ms, self.alpha)
        self.version += 1
        self._refresh(name, p)

    def observe_queue(self, name: str, wait_ms: float) -> None:
        if not _valid_sample(wait_ms):
            self.n_rejected_samples += 1
            return
        p = self.profiles[name]
        p.update_queue(wait_ms, self.alpha)
        self.version += 1
        # Queue telemetry touches only the queue_mu column: μ/σ, the
        # accuracy order, ``fastest`` and the device/scalar caches are
        # all unaffected, so the patch is a single element write.
        t = self._table
        if t is not None:
            t.queue_mu[t.index[name]] = p.queue_mu

    def _refresh(self, name: str, p: ModelProfile) -> None:
        """Telemetry hot path: patch the cached SoA snapshot in place
        (same floats a full rebuild would produce — accuracy, and with
        it the cached order, never drifts) instead of throwing the whole
        table away per observation."""
        if self._table is not None:
            self._table.refresh(self._table.index[name], p.mu, p.sigma,
                                p.queue_mu)

    def queue_wait(self, name: str) -> float:
        """Estimated queue wait W_queue(m) from telemetry (0 until the
        first observation)."""
        return self.profiles[name].queue_mu

    def mark_selected(self, name: str) -> None:
        self.step += 1
        self.profiles[name].last_selected = self.step

    def cold_models(self) -> List[str]:
        """Models whose profile is stale and due a re-probe."""
        return [
            m.name for m in self.profiles.values()
            if m.n_obs == 0 or (self.step - m.last_selected) > self.cold_age
        ]

    def warm_up(self, name: str, samples: Iterable[float]) -> None:
        for s in samples:
            self.observe(name, s)

    def snapshot(self) -> Dict[str, dict]:
        return {
            n: {"mu": p.mu, "sigma": p.sigma, "accuracy": p.accuracy,
                "n_obs": p.n_obs, "queue_mu": p.queue_mu}
            for n, p in self.profiles.items()
        }


class WindowedProfileStore(ProfileStore):
    """Sliding-window estimator with staleness-driven exploration — the
    self-healing profile mode for drifting worlds.

    Two failure modes of the EWMA base class under drift motivate this
    subclass (Taylor et al. 2018; ROADMAP item 3):

    - *Slow tracking*: an EWMA with small α takes hundreds of samples
      to cross an eligibility threshold after a step change.  Here μ/σ
      come from the last ``window`` samples only, and a window whose
      newest sample is older than ``stale_after`` selections is cleared
      before the next observation lands — after a long exile the first
      fresh sample speaks for the *current* world, not a mixture.
    - *Permanent exile*: once a drifted model's believed μ exceeds
      every budget it is never selected, never observed, and never
      forgiven — even after the drift recovers.  A UCB-style bonus
      fixes that: for a model unobserved for more than ``stale_after``
      selections, the *presented* μ decays linearly from the raw
      window estimate down to ``(1 − explore_bonus)·μ_raw`` over
      ``explore_ramp`` further selections.  Eventually the optimistic
      μ re-enters some budget, the model is re-probed, and the first
      real observation snaps the profile back to measured truth
      (still drifted → re-exiled; recovered → re-discovered).

    The presented (table) μ is the decayed one; the raw window estimate
    is kept separately so the decay is idempotent, not compounding.
    """

    def __init__(self, models: Iterable[ModelProfile], *,
                 alpha: float = 0.1, cold_age: int = 500,
                 window: int = 64, stale_after: int = 400,
                 explore_bonus: float = 0.9,
                 explore_ramp: Optional[int] = None):
        super().__init__(models, alpha=alpha, cold_age=cold_age)
        if window < 2:
            raise ValueError("window must be >= 2")
        if stale_after < 1:
            raise ValueError("stale_after must be >= 1")
        if not 0.0 <= explore_bonus < 1.0:
            raise ValueError("explore_bonus must be in [0, 1)")
        self.window = window
        self.stale_after = stale_after
        self.explore_bonus = explore_bonus
        self.explore_ramp = (explore_ramp if explore_ramp is not None
                             else stale_after)
        names = list(self.profiles)
        self._win: Dict[str, Deque[float]] = {n: deque() for n in names}
        self._sum: Dict[str, float] = {n: 0.0 for n in names}
        self._sumsq: Dict[str, float] = {n: 0.0 for n in names}
        self._raw: Dict[str, Tuple[float, float]] = {n: (0.0, 0.0)
                                                     for n in names}
        # Step (selection counter) at the last accepted observation.
        self._seen: Dict[str, int] = {n: 0 for n in names}

    def warm_seed(self, name: str, mu: float, var: float,
                  n_obs: int = 1000) -> None:
        """Install a trusted offline profile (the zoo's seeded truth)
        without fabricating window samples: the raw estimate is set
        directly and the window stays empty, so the first live sample
        after a drift is not diluted by synthetic history."""
        p = self.profiles[name]
        p.mu, p.var, p.n_obs = mu, var, n_obs
        self._raw[name] = (mu, var)
        self._seen[name] = self.step
        self.version += 1
        self._refresh(name, p)

    def observe(self, name: str, latency_ms: float) -> None:
        if not _valid_sample(latency_ms):
            self.n_rejected_samples += 1
            return
        win = self._win[name]
        if win and (self.step - self._seen[name]) > self.stale_after:
            # Returning from exile: the buffered samples describe a
            # world at least one drift epoch old.  Start fresh.
            win.clear()
            self._sum[name] = 0.0
            self._sumsq[name] = 0.0
        win.append(latency_ms)
        self._sum[name] += latency_ms
        self._sumsq[name] += latency_ms * latency_ms
        if len(win) > self.window:
            old = win.popleft()
            self._sum[name] -= old
            self._sumsq[name] -= old * old
        n = len(win)
        mu = self._sum[name] / n
        var = max(0.0, self._sumsq[name] / n - mu * mu)
        self._raw[name] = (mu, var)
        self._seen[name] = self.step
        p = self.profiles[name]
        p.mu, p.var = mu, var
        p.n_obs += 1
        self.version += 1
        self._refresh(name, p)

    def mark_selected(self, name: str) -> None:
        super().mark_selected(name)
        self._present_stale()

    def _present_stale(self) -> None:
        """Sweep the exploration decay: for every model whose last
        accepted observation is more than ``stale_after`` selections
        old, present an optimistically-shrunk μ.  O(models) per
        selection — the zoo is a handful of entries."""
        for name, (raw_mu, _) in self._raw.items():
            p = self.profiles[name]
            if p.n_obs == 0:
                continue      # never observed: the cold-probe path owns it
            age = self.step - self._seen[name]
            if age <= self.stale_after:
                presented = raw_mu
            else:
                frac = min(1.0, (age - self.stale_after)
                           / float(self.explore_ramp))
                presented = raw_mu * (1.0 - self.explore_bonus * frac)
            if presented != p.mu:
                p.mu = presented
                self._refresh(name, p)

    def staleness(self, name: str) -> int:
        """Selections since this model's last accepted observation."""
        return self.step - self._seen[name]


class FrozenProfileStore(ProfileStore):
    """Ablation baseline: profiles never move after construction.

    Observations are validated (rejects still counted — the hardening
    contract holds everywhere) and then dropped; cold-model re-probing
    is disabled.  Under drift this arm keeps routing on the seeded
    (μ, σ) forever — the degradation the adaptive stores are measured
    against in ``benchmarks/drift_resilience.py``."""

    def observe(self, name: str, latency_ms: float) -> None:
        if not _valid_sample(latency_ms):
            self.n_rejected_samples += 1

    def observe_queue(self, name: str, wait_ms: float) -> None:
        if not _valid_sample(wait_ms):
            self.n_rejected_samples += 1

    def cold_models(self) -> List[str]:
        return []
