"""Closed-loop ModiPick simulator (reproduces the paper's §4 experiments).

This is now a thin closed-loop driver over the unified
``repro.router.Router``: the paper's loop is exactly
``ClosedLoopArrivals`` over a single shared replica, routed through the
same Router object as the discrete-event engine and the live executor,
and the engine replays it draw-for-draw — same RNG, same order (uplink
sample → selection → true latency → EWMA feedback → cold-model probe),
so seeded results are unchanged by the refactor.  Open-loop traffic,
FIFO queues, heterogeneous replicas, queue-aware selection and admission
control live in ``repro.sim.engine.ServingSimulator``; an ``admission``
controller set here is passed straight through to the Router.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.netmodel import NetworkModel
from repro.core.policy import Policy
from repro.core.profiles import ProfileStore
from repro.core.zoo import ZooEntry
from repro.router.admission import AdmissionController


@dataclass
class SimResult:
    policy: str
    t_sla: float
    n: int
    sla_attainment: float       # fraction of requests meeting the SLA
    mean_accuracy: float        # expected accuracy of selected models
    mean_latency: float         # end-to-end ms
    p99_latency: float
    model_usage: Dict[str, float]  # fraction of requests per model

    @property
    def violation_rate(self) -> float:
        return 1.0 - self.sla_attainment


@dataclass
class Simulator:
    entries: Sequence[ZooEntry]
    network: NetworkModel
    seed: int = 0
    alpha: float = 0.1
    cold_age: int = 500
    cold_probe: bool = True
    # latency-spike process: with prob p, a request takes spike_mult × μ —
    # models the co-tenant interference the paper motivates exploration with
    spike_prob: float = 0.0
    spike_mult: float = 10.0
    # pluggable router-side admission (repro.router.admission); None is
    # AdmitAll — the paper's closed loop never sheds.
    admission: Optional[AdmissionController] = None

    @classmethod
    def from_scenario(cls, scenario) -> "Simulator":
        """Adapter: build the paper's closed-loop driver from a
        declarative :class:`repro.scenario.Scenario` (the scenario's
        workload must be ``closed_loop``)."""
        from repro.scenario.build import build_closed_loop
        return build_closed_loop(scenario)

    def _engine(self):
        from repro.sim.engine import ServingSimulator
        from repro.sim.replica import shared_replicas
        return ServingSimulator(
            entries=list(self.entries), network=self.network,
            replicas=shared_replicas(1), seed=self.seed, alpha=self.alpha,
            cold_age=self.cold_age, cold_probe=self.cold_probe,
            spike_prob=self.spike_prob, spike_mult=self.spike_mult,
            admission=self.admission)

    def run(self, policy: Policy, t_sla: float, n_requests: int = 10_000,
            warm: bool = True, store: Optional[ProfileStore] = None
            ) -> SimResult:
        from repro.sim.arrivals import ClosedLoopArrivals
        engine = self._engine()
        res = engine.run(policy, t_sla, n_requests,
                         arrivals=ClosedLoopArrivals(),
                         warm=warm, store=store)
        self.router = engine.router  # the run's Router (telemetry/tests)
        return SimResult(
            policy=res.policy,
            t_sla=res.t_sla,
            n=res.n_completed,
            sla_attainment=res.sla_attainment,
            mean_accuracy=res.mean_accuracy,
            mean_latency=res.mean_latency,
            p99_latency=res.p99_latency,
            model_usage=res.model_usage,
        )


def sla_sweep(sim: Simulator, policy_fn, slas: Sequence[float],
              n_requests: int = 10_000) -> List[SimResult]:
    """policy_fn(t_sla) -> Policy (static greedy needs the SLA at build)."""
    return [sim.run(policy_fn(s), s, n_requests) for s in slas]
