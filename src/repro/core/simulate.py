"""Closed-loop ModiPick simulator (reproduces the paper's §4 experiments).

Per request: sample the uplink transfer time, compute the budget (Eq. 1),
let the policy pick a model, sample that model's *true* inference latency,
feed the observation back into the EWMA profile store, and score SLA
attainment + accuracy.  Matches the paper's setup of 10k requests per
(SLA, network) point seeded from the empirical measurements in zoo.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.core.policy import Policy, budget
from repro.core.profiles import ProfileStore
from repro.core.zoo import ZooEntry, make_store, true_profiles


@dataclass
class SimResult:
    policy: str
    t_sla: float
    n: int
    sla_attainment: float       # fraction of requests meeting the SLA
    mean_accuracy: float        # expected accuracy of selected models
    mean_latency: float         # end-to-end ms
    p99_latency: float
    model_usage: Dict[str, float]  # fraction of requests per model

    @property
    def violation_rate(self) -> float:
        return 1.0 - self.sla_attainment


@dataclass
class Simulator:
    entries: Sequence[ZooEntry]
    network: NetworkModel
    seed: int = 0
    alpha: float = 0.1
    cold_age: int = 500
    cold_probe: bool = True
    # latency-spike process: with prob p, a request takes spike_mult × μ —
    # models the co-tenant interference the paper motivates exploration with
    spike_prob: float = 0.0
    spike_mult: float = 10.0

    def _true_latency(self, rng, entry: ZooEntry) -> float:
        t = max(0.05, rng.normal(entry.mu_ms, entry.sigma_ms))
        if self.spike_prob > 0 and rng.random() < self.spike_prob:
            t *= self.spike_mult
        return t

    def run(self, policy: Policy, t_sla: float, n_requests: int = 10_000,
            warm: bool = True, store: Optional[ProfileStore] = None) -> SimResult:
        rng = np.random.default_rng(self.seed)
        store = store or make_store(list(self.entries), alpha=self.alpha,
                                    cold_age=self.cold_age, warm=warm)
        truth = true_profiles(list(self.entries))

        met = 0
        acc_sum = 0.0
        lat: List[float] = []
        usage: Dict[str, int] = {}

        for _ in range(n_requests):
            t_input = float(self.network.sample(rng, 1)[0])
            t_budget = budget(t_sla, t_input)
            name = policy.select(store, t_budget, rng)
            store.mark_selected(name)
            t_inf = self._true_latency(rng, truth[name])
            store.observe(name, t_inf)
            # End-to-end: uplink + inference + downlink (≈ uplink is the
            # conservative 2·T_input estimate; actual downlink is smaller —
            # we charge half the uplink like a small response).
            e2e = 2.0 * t_input + t_inf
            met += e2e <= t_sla
            acc_sum += truth[name].top1 / 100.0
            lat.append(e2e)
            usage[name] = usage.get(name, 0) + 1

            # Cold-model refresh (§3.3 practical considerations): probe one
            # stale model out-of-band (does not affect request latency).
            if self.cold_probe:
                cold = store.cold_models()
                if cold:
                    probe = cold[int(rng.integers(len(cold)))]
                    store.observe(probe, self._true_latency(rng, truth[probe]))
                    store.profiles[probe].last_selected = store.step

        lat_arr = np.array(lat)
        return SimResult(
            policy=policy.name,
            t_sla=t_sla,
            n=n_requests,
            sla_attainment=met / n_requests,
            mean_accuracy=acc_sum / n_requests,
            mean_latency=float(lat_arr.mean()),
            p99_latency=float(np.percentile(lat_arr, 99)),
            model_usage={k: v / n_requests for k, v in sorted(usage.items())},
        )


def sla_sweep(sim: Simulator, policy_fn, slas: Sequence[float],
              n_requests: int = 10_000) -> List[SimResult]:
    """policy_fn(t_sla) -> Policy (static greedy needs the SLA at build)."""
    return [sim.run(policy_fn(s), s, n_requests) for s in slas]
