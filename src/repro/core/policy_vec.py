"""Vectorized policy engine: batched selection over a ProfileTable.

All of ModiPick's request-time math (§3.3 stages 1–3) and the paper's
baselines are batched over requests *and* over the model pool:

- **stage 1** is a masked argmax over the (batch × pool) Eq. 2
  eligibility matrix in accuracy order (first True per row = greedy base);
- **stage 2** is a broadcast window-membership matrix around each row's
  base model;
- **stage 3** evaluates the Eq. 3–4 utilities for every (request, model)
  pair at once and samples with the Gumbel-top-1 trick — argmax over
  ``log p + Gumbel`` draws exactly from the normalized utility
  distribution, so the batched path is distributionally identical to the
  scalar ``rng.choice`` loop (and the probability *vectors* are equal to
  the scalar ``ModiPick._probs`` output to float precision).

Deterministic policies (static/dynamic greedy, related-accurate) are
bit-identical to their scalar loops, including tie-breaking order.

Backends
--------
``select_batch(..., backend=...)`` accepts:

- ``"numpy"`` — the reference implementation, always available;
- ``"jax"``   — ModiPick's stage-3 utilities + sampling run jitted, with
  the fused eligibility-mask/utility/normalize step as a Pallas kernel
  (``repro.kernels.policy_select``; interpret mode off-TPU);
- ``"auto"``/``None`` — numpy below ``JAX_MIN_BATCH`` requests, jax at or
  above it (only for ModiPick — everything else is pure masked
  argmax/argmin, which numpy already does at memory bandwidth).

``REPRO_POLICY_BACKEND`` (env) overrides the default for a whole run —
set ``numpy`` to force the reference path, ``jax`` to force the kernel.
"""
from __future__ import annotations

import functools
import os
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.policy import (EPS, DynamicGreedy, ModiPick, Policy,
                               PureRandom, RelatedAccurate, RelatedRandom,
                               SelectionTrace, StaticGreedy)
from repro.core.profiles import ProfileStore, ProfileTable

# Batch size at which ModiPick's selection moves to the fused jitted
# path.  Re-tuned for the device-resident stages 1–3 pipeline: on this
# host's CPU the fused jit crosses numpy between 2k and 8k requests
# (see BENCH_policy_throughput.json); on TPU the Pallas path wins far
# earlier, but 4096 keeps the switch conservative everywhere.
JAX_MIN_BATCH = 4096

VALID_BACKENDS = ("auto", "numpy", "jax")


def _as_table(store: Union[ProfileStore, ProfileTable]) -> ProfileTable:
    return store if isinstance(store, ProfileTable) else store.table()


def _resolve_backend(backend: Optional[str], n_batch: int) -> str:
    if backend is None:
        env = os.environ.get("REPRO_POLICY_BACKEND")
        if env and env not in VALID_BACKENDS:
            raise ValueError(
                f"REPRO_POLICY_BACKEND={env!r} is not a recognised policy "
                f"backend; valid values: {', '.join(VALID_BACKENDS)}")
        backend = env or "auto"
    elif backend not in VALID_BACKENDS:
        raise ValueError(f"unknown policy backend {backend!r}; "
                         f"valid values: {', '.join(VALID_BACKENDS)}")
    if backend == "auto":
        # The fused device pipeline (stages 1–3 under one jit, Pallas
        # stage 3 on TPU / plain XLA elsewhere) beats numpy above the
        # measured crossover on CPU as well as TPU, so auto engages it
        # wherever jax can compile — no interpret-mode Pallas is left on
        # this path (see BENCH_policy_throughput.json).
        if n_batch >= JAX_MIN_BATCH and _jax_available():
            return "jax"
        return "numpy"
    return backend


def resolve_backend(backend: Optional[str], n_batch: int) -> str:
    """Public backend resolution (``auto``/env/threshold → ``numpy`` or
    ``jax``) — the Router uses it to decide whether a charged batch can
    ride the device-resident ``lax.scan`` pass in
    ``kernels.policy_select.charged_select`` under the same policy as
    the uncharged fused pipeline."""
    return _resolve_backend(backend, n_batch)


@functools.lru_cache(maxsize=1)
def _jax_available() -> bool:
    try:
        import jax
        jax.default_backend()
        return True
    except Exception:  # pragma: no cover - jax is baked into the container
        return False


# ----------------------------------------------------------------------
# stages 1–2: masked argmax + broadcast window membership (numpy)
# ----------------------------------------------------------------------

def modipick_masks(tab: ProfileTable, t_u: np.ndarray, t_l: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Batched stages 1–2.

    Returns ``(base, has_base, eligible, natural)`` where ``base[b]`` is
    the stage-1 pick's pool index (undefined where ``~has_base``),
    ``eligible`` is the (B, n) stage-2 membership matrix with the base
    forced in, and ``natural`` is the same matrix *before* forcing (the
    scalar path appends an out-of-window base at the end of its eligible
    list, which matters for deterministic tie-breaking)."""
    mu, sigma = tab.mu, tab.sigma
    order = tab.acc_order
    B = len(t_u)
    # Eq. 2 eligibility over the pool in accuracy order; argmax finds the
    # first True per row = most accurate feasible base.
    mu_o, sig_o = mu[order], sigma[order]
    elig1 = ((mu_o + sig_o)[None, :] < t_u[:, None]) \
        & ((mu_o - sig_o)[None, :] < t_l[:, None])
    has_base = elig1.any(axis=1)
    base = order[elig1.argmax(axis=1)]
    base[~has_base] = tab.fastest  # placeholder; masked by has_base

    # stage 2: window [T_L - half, T_L + half] around each row's base.
    half = np.abs(t_l - mu[base]) + sigma[base]
    lo, hi = t_l - half, t_l + half
    natural = (lo[:, None] <= mu[None, :]) & (mu[None, :] <= hi[:, None]) \
        & ((mu + sigma)[None, :] < t_u[:, None])
    eligible = natural.copy()
    eligible[np.arange(B), base] = True  # base always eligible
    eligible &= has_base[:, None]
    return base, has_base, eligible, natural


# ----------------------------------------------------------------------
# stage 3: batched Eq. 3–4 utilities → per-request probability vectors
# ----------------------------------------------------------------------

def modipick_probs(tab: ProfileTable, t_u: np.ndarray, t_l: np.ndarray,
                   eligible: np.ndarray, gamma: float) -> np.ndarray:
    """(B, n) probability matrix over the pool; zero where ineligible.
    Rows with no eligible models (fallback rows) come back all-zero."""
    num = t_u[:, None] - (tab.mu + tab.sigma)[None, :]
    den = np.maximum(np.abs(t_l[:, None] - tab.mu[None, :]), EPS)
    u = np.maximum(tab.accuracy, EPS)[None, :] ** gamma * num / den
    u = np.where(eligible, u, 0.0)
    total = u.sum(axis=1)
    counts = eligible.sum(axis=1)
    # Scalar-path degenerate case: non-finite or non-positive mass →
    # uniform over the eligible set.
    bad = (~np.isfinite(total)) | (total <= 0)
    safe = np.where(bad | (counts == 0), 1.0, total)
    probs = np.where(bad[:, None],
                     eligible / np.maximum(counts, 1)[:, None],
                     u / safe[:, None])
    return probs


def gumbel_top1(probs: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Sample one index per row from each row's probability vector via
    argmax(log p + Gumbel) — exact categorical sampling, one vectorized
    draw for the whole batch."""
    g = rng.gumbel(size=probs.shape)
    with np.errstate(divide="ignore"):
        logits = np.where(probs > 0, np.log(probs), -np.inf)
    return np.argmax(logits + g, axis=1)


# ----------------------------------------------------------------------
# per-policy batched selection
# ----------------------------------------------------------------------

def _modipick_batch(policy: ModiPick, tab: ProfileTable,
                    t_budgets: np.ndarray, rng: np.random.Generator,
                    backend: str, need_stages: bool = True):
    """Returns ``(idx, has_base, base, eligible, probs)``.

    On the jax backend with ``need_stages=False`` the whole pipeline —
    stages 1–2 masks, stage-3 utilities and the categorical draw — runs
    device-resident under one jit (``kernels.policy_select.select_fused``)
    and ``base``/``eligible``/``probs`` come back None: nothing but the
    budget rows crosses to the device and nothing but the sampled
    indices crosses back.  ``need_stages=True`` (detailed traces) keeps
    the host mask path; ``probs`` is None whenever the device samples
    without materialising the probability matrix host-side."""
    t_u = t_budgets
    t_l = t_u - policy.t_threshold
    if backend == "jax" and not need_stages:
        from repro.kernels import policy_select
        idx, has_base = policy_select.select_fused(
            tab.device_pool(), t_u, t_l, gamma=policy.gamma,
            seed=int(rng.integers(np.iinfo(np.int64).max)))
        return idx, has_base, None, None, None
    base, has_base, eligible, _ = modipick_masks(tab, t_u, t_l)
    probs = None
    if backend == "jax":
        from repro.kernels import policy_select
        choice = policy_select.sample_batch(
            tab.mu, tab.sigma, tab.accuracy, t_u, t_l, eligible,
            gamma=policy.gamma,
            seed=int(rng.integers(np.iinfo(np.int64).max)))
        choice = np.asarray(choice)
    else:
        probs = modipick_probs(tab, t_u, t_l, eligible, policy.gamma)
        choice = gumbel_top1(probs, rng)
    return np.where(has_base, choice, tab.fastest), has_base, base, \
        eligible, probs


def _related_random_batch(policy: RelatedRandom, tab: ProfileTable,
                          t_budgets: np.ndarray,
                          rng: np.random.Generator):
    t_u = t_budgets
    t_l = t_u - policy.t_threshold
    base, has_base, eligible, _ = modipick_masks(tab, t_u, t_l)
    g = rng.gumbel(size=eligible.shape)
    choice = np.argmax(np.where(eligible, g, -np.inf), axis=1)
    return np.where(has_base, choice, tab.fastest), has_base, base, eligible


def _related_accurate_batch(policy: RelatedAccurate, tab: ProfileTable,
                            t_budgets: np.ndarray):
    t_u = t_budgets
    t_l = t_u - policy.t_threshold
    base, has_base, eligible, natural = modipick_masks(tab, t_u, t_l)
    n = len(tab)
    B = len(t_u)
    # Scalar tie-break: max() keeps the *first* max of the eligible list,
    # which is pool order — except an out-of-window base is appended last.
    rank = np.broadcast_to(np.arange(n), (B, n)).copy()
    forced = ~natural[np.arange(B), base]
    rank[np.arange(B), base] = np.where(forced, n, base)
    acc = np.where(eligible, tab.accuracy[None, :], -np.inf)
    best = acc.max(axis=1)
    cand = eligible & (acc == best[:, None])
    choice = np.argmin(np.where(cand, rank, n + 1), axis=1)
    return np.where(has_base, choice, tab.fastest), has_base, base, eligible


def _dynamic_greedy_batch(tab: ProfileTable, t_budgets: np.ndarray):
    order = tab.acc_order
    elig = tab.mu[None, order] <= t_budgets[:, None]
    has = elig.any(axis=1)
    return np.where(has, order[elig.argmax(axis=1)], tab.fastest), has


def select_batch(policy: Policy, store: Union[ProfileStore, ProfileTable],
                 t_budgets: Sequence[float], rng: np.random.Generator, *,
                 backend: Optional[str] = None) -> List[str]:
    """Batched ``policy.select`` over ``t_budgets`` → list of model names.

    Deterministic policies return exactly what B scalar ``select`` calls
    would; ModiPick/RelatedRandom sample from the identical per-request
    distributions in one vectorized draw (so individual picks differ from
    the sequential RNG stream, but their law does not).
    """
    tab = _as_table(store)
    t = np.asarray(t_budgets, dtype=np.float64)
    if t.ndim != 1:
        raise ValueError("t_budgets must be one-dimensional")
    backend = _resolve_backend(backend, len(t))
    if len(t) == 1 and isinstance(store, ProfileStore):
        # A batch of one IS a scalar selection, whatever the (already
        # validated) backend says — backends shape batches of two or
        # more; the Router routes singletons the same way.  ModiPick
        # rides the lean scalar core (identical picks and RNG stream to
        # ``select_traced``, minus the trace materialisation); stochastic
        # policies therefore consume the scalar RNG pattern here, not
        # the batched one — same law, different stream, exactly like the
        # Router's singleton path.
        if type(policy) is ModiPick:
            return [policy.select_lean(store, float(t[0]), rng).chosen]
        return [policy.select(store, float(t[0]), rng)]

    # Exact-type dispatch: a subclass may override any stage, so only
    # the classes implemented here take the batched path — everything
    # else falls back to the (always-correct) scalar loop.
    kind = type(policy)
    if kind is RelatedRandom:
        idx = _related_random_batch(policy, tab, t, rng)[0]
    elif kind is RelatedAccurate:
        idx = _related_accurate_batch(policy, tab, t)[0]
    elif kind is ModiPick:
        idx = _modipick_batch(policy, tab, t, rng, backend,
                              need_stages=False)[0]
    elif kind is DynamicGreedy:
        idx = _dynamic_greedy_batch(tab, t)[0]
    elif kind is StaticGreedy:
        idx = np.full(len(t), tab.index[_static_greedy_pick(
            policy, store, tab, t, rng)])
    elif kind is PureRandom:
        idx = rng.integers(len(tab), size=len(t))
    else:
        if isinstance(store, ProfileTable):
            raise TypeError(f"no batched implementation for {policy!r} "
                            "and a bare ProfileTable cannot drive the "
                            "scalar path")
        return [policy.select(store, float(b), rng) for b in t]
    return [tab.names[int(i)] for i in idx]


def _static_greedy_pick(policy: StaticGreedy,
                        store: Union[ProfileStore, ProfileTable],
                        tab: ProfileTable, t: np.ndarray,
                        rng: np.random.Generator) -> str:
    if isinstance(store, ProfileTable):
        # No live store to freeze against: honour an existing frozen
        # pick, else derive the dev-time choice from the snapshot
        # (without thawing the policy's own state).
        name = policy._frozen
        if name is None or name not in tab.index:
            name = policy.freeze_pick(tab)
        return name
    return policy.select_traced(store, t[0] if len(t) else 0.0, rng).chosen


def _exploration_traces(tab: ProfileTable, idx, has_base, base, eligible,
                        probs, detail: bool) -> List[SelectionTrace]:
    """Assemble per-request traces from the batched stage outputs.
    Eligible sets (and their probability vectors) are reported in pool
    order — the scalar path appends an out-of-window base at the *end*
    of its list instead, but the set and per-model probabilities are
    identical.  ``detail=False`` skips the per-request eligible/probs
    tuple materialization (chosen + fallback only) — the hot-path mode
    for callers that don't consume the stage decomposition."""
    fastest = tab.names[tab.fastest]
    if not detail:
        return [SelectionTrace(chosen=tab.names[int(i)], fallback=not h)
                for i, h in zip(idx, has_base)]
    traces = []
    for b in range(len(idx)):
        if not has_base[b]:
            traces.append(SelectionTrace(chosen=fastest, fallback=True))
            continue
        members = np.flatnonzero(eligible[b])
        traces.append(SelectionTrace(
            chosen=tab.names[int(idx[b])],
            base=tab.names[int(base[b])],
            eligible=tuple(tab.names[int(i)] for i in members),
            probs=(tuple(float(p) for p in probs[b, members])
                   if probs is not None else ())))
    return traces


def select_batch_traced(policy: Policy,
                        store: Union[ProfileStore, ProfileTable],
                        t_budgets: Sequence[float],
                        rng: np.random.Generator, *,
                        backend: Optional[str] = None,
                        detail: bool = True) -> List[SelectionTrace]:
    """Batched ``policy.select_traced``: one :class:`SelectionTrace` per
    budget, produced by the same batched stages as :func:`select_batch`
    (identical picks for identical ``rng`` state).  ModiPick-family
    traces carry base/eligible/probs (probs only on the numpy backend);
    greedy traces carry the fallback flag.  ``detail=False`` returns
    chosen + fallback only — same picks, no per-request stage-tuple
    materialization (the event-loop hot path).
    """
    tab = _as_table(store)
    t = np.asarray(t_budgets, dtype=np.float64)
    if t.ndim != 1:
        raise ValueError("t_budgets must be one-dimensional")
    if not len(t):
        return []
    backend = _resolve_backend(backend, len(t))

    kind = type(policy)
    if kind is ModiPick:
        idx, has_base, base, eligible, probs = _modipick_batch(
            policy, tab, t, rng, backend, need_stages=detail)
        return _exploration_traces(tab, idx, has_base, base, eligible,
                                   probs, detail)
    if kind is RelatedRandom:
        idx, has_base, base, eligible = _related_random_batch(
            policy, tab, t, rng)
        return _exploration_traces(tab, idx, has_base, base, eligible,
                                   None, detail)
    if kind is RelatedAccurate:
        idx, has_base, base, eligible = _related_accurate_batch(
            policy, tab, t)
        return _exploration_traces(tab, idx, has_base, base, eligible,
                                   None, detail)
    if kind is DynamicGreedy:
        idx, has = _dynamic_greedy_batch(tab, t)
        return [SelectionTrace(chosen=tab.names[int(i)], fallback=not h)
                for i, h in zip(idx, has)]
    if kind is StaticGreedy:
        name = _static_greedy_pick(policy, store, tab, t, rng)
        return [SelectionTrace(chosen=name) for _ in t]
    if kind is PureRandom:
        picks = rng.integers(len(tab), size=len(t))
        return [SelectionTrace(chosen=tab.names[int(i)]) for i in picks]
    if isinstance(store, ProfileTable):
        raise TypeError(f"no batched implementation for {policy!r} and a "
                        "bare ProfileTable cannot drive the scalar path")
    return [policy.select_traced(store, float(b), rng) for b in t]
