"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.configs import (
    command_r_35b,
    dbrx_132b,
    gemma3_4b,
    internvl2_2b,
    mamba2_1_3b,
    moonshot_v1_16b_a3b,
    phi4_mini_3_8b,
    qwen2_1_5b,
    recurrentgemma_2b,
    whisper_tiny,
)
from repro.configs.base import ModelConfig, SHAPES, ShapeConfig

_FACTORIES: Dict[str, Callable[[], ModelConfig]] = {
    "recurrentgemma-2b": recurrentgemma_2b.config,
    "mamba2-1.3b": mamba2_1_3b.config,
    "qwen2-1.5b": qwen2_1_5b.config,
    "phi4-mini-3.8b": phi4_mini_3_8b.config,
    "command-r-35b": command_r_35b.config,
    "gemma3-4b": gemma3_4b.config,
    "whisper-tiny": whisper_tiny.config,
    "dbrx-132b": dbrx_132b.config,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.config,
    "internvl2-2b": internvl2_2b.config,
}

ARCH_IDS: List[str] = list(_FACTORIES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _FACTORIES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _FACTORIES[arch_id]()


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """The dry-run grid for one arch.

    long_500k requires sub-quadratic context handling — skipped for pure
    full-attention archs (see DESIGN.md §long_500k skip list).
    """
    out = []
    for shape in SHAPES.values():
        if shape.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(shape)
    return out


def dryrun_cells() -> List[tuple]:
    cells = []
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in applicable_shapes(cfg):
            cells.append((arch_id, shape.name))
    return cells
