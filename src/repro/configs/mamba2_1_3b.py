"""mamba2-1.3b [ssm]: SSD (state-space duality), attention-free.

48L d_model=2048 d_ff=0 vocab=50280 ssm_state=128  [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=1,       # unused (attention-free)
        n_kv_heads=1,    # unused
        d_ff=0,          # SSD blocks have no separate MLP (mamba2 style)
        vocab_size=50_280,
        pattern=("ssd",),
        norm="rms",
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256, conv_width=4),
        quality=0.55,
    )
