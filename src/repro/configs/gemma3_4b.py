"""gemma3-4b [dense]: 5 local : 1 global attention, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144  [hf:google/gemma-3]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10_240,
        vocab_size=262_144,
        # 5:1 local:global superblocks; 34 = 5 superblocks of 6 + 4 local tail
        pattern=("local", "local", "local", "local", "local", "attn"),
        window=1024,
        rope_theta=1_000_000.0,
        mlp="geglu",
        norm="rms",
        embed_scale=True,
        tie_embeddings=True,
        quality=0.70,
    )
