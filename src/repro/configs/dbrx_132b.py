"""dbrx-132b [moe]: 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352
[hf:databricks/dbrx-base]
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10_752,
        vocab_size=100_352,
        pattern=("attn",),
        rope_theta=500_000.0,
        mlp="swiglu",
        norm="layer",
        tie_embeddings=False,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10_752),
        quality=0.82,
    )
