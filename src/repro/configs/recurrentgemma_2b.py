"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1 attn : 2 rglru.

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000  [arXiv:2402.19427; hf]
"""
from repro.configs.base import ModelConfig, RGLRUConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        pattern=("rglru", "rglru", "local"),
        window=2048,
        mlp="geglu",
        norm="rms",
        embed_scale=True,
        tie_embeddings=True,
        rglru=RGLRUConfig(lru_width=2560, conv_width=4),
        quality=0.60,
    )
