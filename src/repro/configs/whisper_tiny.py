"""whisper-tiny [audio]: encoder-decoder; conv frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings.

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865  [arXiv:2212.04356]
"""
from repro.configs.base import EncDecConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51_865,
        pattern=("attn",),
        use_rope=False,  # whisper: sinusoidal absolute positions
        qkv_bias=True,
        mlp="gelu",
        norm="layer",
        tie_embeddings=True,
        encdec=EncDecConfig(n_encoder_layers=4, n_frames=1500),
        quality=0.50,
    )
