"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 experts top-6, fine-grained.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B]
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163_840,
        pattern=("attn",),
        rope_theta=50_000.0,
        mlp="swiglu",
        norm="rms",
        tie_embeddings=True,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408),
        quality=0.74,
    )
