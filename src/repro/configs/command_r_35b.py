"""command-r-35b [dense]: GQA, no-bias, LayerNorm.

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22_528,
        vocab_size=256_000,
        pattern=("attn",),
        rope_theta=8_000_000.0,
        mlp="swiglu",
        norm="layer",
        tie_embeddings=True,
        quality=0.80,
    )
