"""qwen2-1.5b [dense]: GQA with QKV bias.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936  [arXiv:2407.10671; hf]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151_936,
        pattern=("attn",),
        rope_theta=1_000_000.0,
        qkv_bias=True,
        mlp="swiglu",
        norm="rms",
        tie_embeddings=True,
        quality=0.62,
    )
