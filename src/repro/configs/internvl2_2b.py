"""internvl2-2b [vlm]: InternViT + InternLM2 backbone; the ViT frontend is a
STUB — ``input_specs()`` provides precomputed patch embeddings.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553  [arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig, VLMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92_553,
        pattern=("attn",),
        rope_theta=1_000_000.0,
        mlp="swiglu",
        norm="rms",
        tie_embeddings=False,
        vlm=VLMConfig(n_image_tokens=256),
        quality=0.64,
    )
