"""Config system for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig` built
from block *patterns* (superblocks) so that models with interleaved layer
types (gemma3 5:1 local:global, recurrentgemma 2:1 rglru:local) lower to a
`lax.scan` over superblocks plus a small unrolled tail — keeping HLO size
(and therefore XLA compile time) independent of depth.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# Block kinds understood by the model substrate.
BLOCK_KINDS = ("attn", "local", "rglru", "ssd")


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # Token-group size for the GShard-style one-hot dispatch einsum.  Kept
    # modest so the (g, E, C) dispatch tensor stays VMEM/HBM friendly.
    group_size: int = 512


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) mixer parameters."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256
    conv_width: int = 4
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block parameters."""
    lru_width: Optional[int] = None  # default: d_model
    conv_width: int = 4
    c_exponent: float = 8.0

    def width(self, d_model: int) -> int:
        return self.lru_width or d_model


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (whisper) extras; frontend is a stub that provides
    precomputed frame embeddings."""
    n_encoder_layers: int = 4
    n_frames: int = 1500  # whisper 30s @ 50Hz after conv frontend


@dataclass(frozen=True)
class VLMConfig:
    """VLM extras; ViT frontend is a stub providing patch embeddings."""
    n_image_tokens: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # Superblock pattern of block kinds; layers = pattern repeated + tail.
    pattern: Tuple[str, ...] = ("attn",)
    window: int = 1024  # sliding window for "local" blocks
    rope_theta: float = 10_000.0
    use_rope: bool = True  # False → sinusoidal absolute positions at embed
    qkv_bias: bool = False
    mlp: str = "swiglu"  # swiglu | gelu
    norm: str = "rms"  # rms | layer
    tie_embeddings: bool = True
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model)
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bf16"  # bf16 | int8 (per-slot-scaled quantized KV)
    # Accuracy proxy used by ModiPick pools (top-1-style score in [0,1]).
    quality: float = 0.0

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a lane-aligned multiple so it TP-shards over 16
        cleanly (vLLM/MaxText pad the same way)."""
        return _ceil_to(self.vocab_size, 256)

    @property
    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer kinds: pattern repeated with the remainder as a tail."""
        reps = self.n_layers // len(self.pattern)
        tail = self.n_layers - reps * len(self.pattern)
        return self.pattern * reps + self.pattern[:tail]

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        return self.pattern[: self.n_layers - self.n_superblocks * len(self.pattern)]

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("ssd", "rglru") for k in self.block_kinds)

    @property
    def has_global_attention(self) -> bool:
        return any(k == "attn" for k in self.block_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: no *dense* full-attention majority.

        SSM / hybrid / mostly-local archs qualify; sparse global layers
        (gemma3 1-in-6) are handled with context-parallel KV."""
        kinds = self.block_kinds
        n_global = sum(1 for k in kinds if k == "attn")
        return n_global == 0 or (n_global / len(kinds)) <= 0.25

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for rooflines."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        for kind in self.block_kinds:
            if kind in ("attn", "local"):
                n += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            elif kind == "ssd":
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                conv_ch = di + 2 * s.n_groups * s.d_state
                n += d * (2 * di + 2 * s.n_groups * s.d_state + nh)
                n += conv_ch * s.conv_width + nh + nh  # conv, A_log, D
                n += di * d  # out proj
            elif kind == "rglru":
                w = self.rglru.width(d)
                n += 2 * d * w + w * self.rglru.conv_width + 2 * w * w + 4 * w + w * d
            if kind != "ssd":  # MLP for every non-ssd block
                if self.moe is not None:
                    e = self.moe
                    n += d * e.n_experts  # router
                    n += e.n_experts * (3 * d * e.d_ff_expert)
                else:
                    mults = 3 if self.mlp == "swiglu" else 2
                    n += mults * d * self.d_ff
            n += 2 * d  # two norms
        if self.encdec is not None:
            enc_block = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            enc_block += (3 if self.mlp == "swiglu" else 2) * d * self.d_ff + 2 * d
            n += self.encdec.n_encoder_layers * enc_block
            # decoder cross-attention per layer
            n += self.n_layers * (d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d + d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        dense_experts = e.n_experts * 3 * self.d_model * e.d_ff_expert
        active_experts = e.top_k * 3 * self.d_model * e.d_ff_expert
        per_layer_delta = dense_experts - active_experts
        n_moe_layers = sum(1 for k in self.block_kinds if k != "ssd")
        return self.param_count() - n_moe_layers * per_layer_delta

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        pat = len(self.pattern)
        n_layers = max(2 * pat, pat + 1) if pat > 1 else 2
        kw = dict(
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            window=min(self.window, 64),
        )
        cfg = replace(self, **kw)
        if self.moe is not None:
            cfg = replace(cfg, moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, group_size=32))
        if self.ssm is not None:
            cfg = replace(cfg, ssm=SSMConfig(d_state=16, head_dim=16, chunk_size=32))
        if self.rglru is not None:
            cfg = replace(cfg, rglru=RGLRUConfig(lru_width=128))
        if self.encdec is not None:
            cfg = replace(cfg, encdec=EncDecConfig(n_encoder_layers=2, n_frames=64))
        if self.vlm is not None:
            cfg = replace(cfg, vlm=VLMConfig(n_image_tokens=16))
        return cfg

    def with_padded_heads(self, multiple: int) -> "ModelConfig":
        """Pad query heads up to a multiple so attention head-shards over a
        TP axis that doesn't divide the native head count (the same trick
        as vocab padding: spend a little extra compute to unlock even
        sharding).  KV heads are left as-is (small, replicated)."""
        padded = _ceil_to(self.n_heads, multiple)
        if padded == self.n_heads or padded > self.n_heads * 1.34:
            # only worth it when the extra attention FLOPs stay ≤ ~1/3
            # (qwen2 12→16, phi4 24→32; not whisper 6→16 or rg 10→16)
            return self
        return replace(self, n_heads=padded, head_dim=self.resolved_head_dim,
                       name=self.name + f"-hpad{padded}")

    def scaled(self, width_mult: float, depth_mult: float = 1.0, name: str = "") -> "ModelConfig":
        """Scale width/depth — used to build ModiPick accuracy/latency pools."""
        d_model = _ceil_to(int(self.d_model * width_mult), 64)
        return replace(
            self,
            name=name or f"{self.name}-x{width_mult:g}",
            d_model=d_model,
            n_layers=max(len(self.pattern), int(self.n_layers * depth_mult)),
            d_ff=_ceil_to(int(self.d_ff * width_mult), 64),
            head_dim=max(16, _ceil_to(int(self.resolved_head_dim * width_mult), 16)),
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"  # cosine | linear | constant
    remat: str = "full"  # none | full | dots
    grad_accum: int = 1
    opt_moments: str = "fp32"  # fp32 | int8 (8-bit Adam moments)
    compress_grads: bool = False  # int8 + error-feedback all-reduce
    seed: int = 0


def shape_for(name: str) -> ShapeConfig:
    return SHAPES[name]
