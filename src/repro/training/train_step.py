"""Train step: loss + grads + AdamW, with optional microbatch gradient
accumulation (fp32 accumulator, `lax.scan` over microbatches so HLO stays
small)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.api import make_forward_loss
from repro.training.optimizer import OptState, adamw_update, init_opt_state


def make_train_step(mcfg: ModelConfig, tcfg: TrainConfig):
    loss_fn = make_forward_loss(mcfg, remat=tcfg.remat != "none")
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, opt_state: OptState, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        params, opt_state, om = adamw_update(tcfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **om, "total_loss": loss}

    if tcfg.grad_accum <= 1:
        return single

    k = tcfg.grad_accum

    def accumulated(params, opt_state: OptState, batch):
        def reshape(x):
            return x.reshape((k, x.shape[0] // k) + x.shape[1:])

        micro = jax.tree.map(reshape, batch)
        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, loss_sum = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / k,
                               acc, grads)
            return (acc, loss_sum + loss / k), 0

        from repro.models import runtime_flags
        if runtime_flags.UNROLL_SCANS:
            carry = (acc0, jnp.zeros((), jnp.float32))
            for i in range(k):
                carry, _ = body(carry, jax.tree.map(lambda a: a[i], micro))
            grads, loss = carry
        else:
            (grads, loss), _ = jax.lax.scan(
                body, (acc0, jnp.zeros((), jnp.float32)), micro)
        params, opt_state, om = adamw_update(tcfg, params, grads, opt_state)
        return params, opt_state, {**om, "total_loss": loss, "loss": loss}

    return accumulated


def init_train_state(mcfg: ModelConfig, key, dtype=jnp.bfloat16,
                     tcfg: TrainConfig = None):
    from repro.models import model as M
    params = M.init_params(mcfg, key, dtype)
    moments = tcfg.opt_moments if tcfg else "fp32"
    return params, init_opt_state(params, moments)
