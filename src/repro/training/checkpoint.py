"""Mesh-agnostic checkpointing with atomic commits and keep-last-k.

Checkpoints store *logical* (unsharded) arrays keyed by param path plus a
JSON manifest (step, data-pipeline state, tree structure).  Restore
re-shards onto whatever mesh the restarted job has — the elastic-restart
path: save on 256 chips, resume on 512 (or on 1 CPU in tests).

Commit protocol: write to ``<dir>/tmp.<step>`` then ``os.rename`` to
``<dir>/step_<step>`` (atomic on POSIX), then prune.  A crash mid-write
leaves only a tmp dir that is ignored and garbage-collected.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, params, opt_state=None,
         extra: Optional[dict] = None, keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest: Dict[str, Any] = {"step": step, "extra": extra or {}, "arrays": {}}
    trees = {"params": params}
    if opt_state is not None:
        trees["opt"] = opt_state
    for prefix, tree in trees.items():
        for key, leaf in _flatten_with_paths(tree):
            name = f"{prefix}/{key}"
            arr = np.asarray(jax.device_get(leaf))
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["arrays"][name] = {"file": fn, "dtype": str(arr.dtype),
                                        "shape": list(arr.shape)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit

    _prune(ckpt_dir, keep_last)
    return final


def _prune(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d))
    for d in os.listdir(ckpt_dir):  # GC crashed partial writes
        if d.startswith("tmp."):
            shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, step: int, params_like, opt_like=None,
            shardings=None) -> Tuple[Any, Any, dict]:
    """Restore onto templates (`*_like` trees of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of
    NamedShardings for direct sharded device_put (elastic re-shard)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def load_tree(prefix, like, shard_tree):
        keys_and_leaves = _flatten_with_paths(like)
        treedef = jax.tree.structure(like)
        shard_leaves = (jax.tree.leaves(shard_tree)
                        if shard_tree is not None else [None] * len(keys_and_leaves))
        leaves = []
        for (key, leaf), shd in zip(keys_and_leaves, shard_leaves):
            meta = manifest["arrays"][f"{prefix}/{key}"]
            arr = np.load(os.path.join(path, meta["file"]))
            expect = tuple(leaf.shape)
            assert tuple(arr.shape) == expect, (key, arr.shape, expect)
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree.unflatten(treedef, leaves)

    p_shard = o_shard = None
    if shardings is not None:
        p_shard, o_shard = shardings
    params = load_tree("params", params_like, p_shard)
    opt = load_tree("opt", opt_like, o_shard) if opt_like is not None else None
    return params, opt, manifest["extra"]
