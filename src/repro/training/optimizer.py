"""AdamW + LR schedules in pure JAX (no optax dependency).

Optimizer state is kept in fp32 regardless of param dtype (bf16 training
with fp32 master moments).  The state tree mirrors the param tree so the
same sharding specs apply (FSDP shards optimizer state with the params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    step: jax.Array          # () int32
    mu: Any                  # first moment (param tree, fp32 or int8 dict)
    nu: Any                  # second moment (param tree, fp32 or int8 dict)


# ----------------------------------------------------------------------
# 8-bit moments (per-row dynamic quantization, bitsandbytes-style):
# moments are stored as int8 with an fp32 scale per leading row, so the
# scale tree shards exactly like the param minus its last dim.  Cuts
# optimizer-state HBM 4× — what lets dbrx-132b train fit v5e (see
# EXPERIMENTS.md §fit).
# ----------------------------------------------------------------------
def _q8(x: jax.Array) -> Dict[str, jax.Array]:
    """Linear per-row int8 — fine for the zero-mean first moment."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def _dq8(s: Dict[str, jax.Array]) -> jax.Array:
    return s["q"].astype(jnp.float32) * s["scale"]


_LOG_FLOOR = -46.0  # exp(-46) ≈ 1e-20: below any meaningful v


def _q8_log(x: jax.Array) -> Dict[str, jax.Array]:
    """Log-space per-row int8 for the (non-negative) second moment: v
    spans many decades within a row; linear int8 rounds small entries to
    zero and Adam's 1/√v̂ explodes.  Quantizing log v caps the relative
    error at ~e^(range/254) per step."""
    lg = jnp.log(jnp.maximum(x, 1e-20))
    hi = jnp.max(lg, axis=-1, keepdims=True)
    lo = jnp.maximum(jnp.min(lg, axis=-1, keepdims=True),
                     jnp.full_like(hi, _LOG_FLOOR))
    scale = (hi - lo) / 254.0 + 1e-12
    q = jnp.clip(jnp.round((lg - lo) / scale) - 127, -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale, "lo": lo}


def _dq8_log(s: Dict[str, jax.Array]) -> jax.Array:
    lg = (s["q"].astype(jnp.float32) + 127.0) * s["scale"] + s["lo"]
    v = jnp.exp(lg)
    return jnp.where(lg <= _LOG_FLOOR + 1e-6, 0.0, v)


def _is_q8(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q", "scale"}


def _is_q8_log(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q", "scale", "lo"}


def init_opt_state(params, moments: str = "fp32") -> OptState:
    if moments == "int8":
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(lambda p: _q8(jnp.zeros(p.shape, jnp.float32)), params),
            nu=jax.tree.map(lambda p: _q8_log(jnp.zeros(p.shape, jnp.float32)), params))
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: TrainConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 1.0 - frac
    else:  # cosine
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(cfg: TrainConfig, params, grads, state: OptState
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip_scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = lr_schedule(cfg, step)

    def upd(p, g, m, v):
        q8 = _is_q8(m)
        if q8:
            m, v = _dq8(m), _dq8_log(v)
        g = g.astype(jnp.float32) * clip_scale
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        if q8:
            m, v = _q8(m), _q8_log(v)
        return new_p.astype(p.dtype), m, v

    def apply_upd(p, g, m, v):
        # big stacked-layer leaves: run the elementwise update as a map
        # over the layer dim so fp32 (de)quant transients stay bounded
        # (one layer's moments live at a time, not the whole stack)
        if p.ndim >= 3 and p.shape[0] >= 8:
            return jax.lax.map(lambda t: upd(*t), (p, g, m, v))
        return upd(p, g, m, v)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [apply_upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
