"""Fault-tolerant training loop: periodic checkpoints, crash recovery,
failure injection for tests, elastic restart."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import TokenStream
from repro.training import checkpoint as ckpt
from repro.training.train_step import init_train_state, make_train_step


@dataclass
class TrainLoop:
    mcfg: ModelConfig
    tcfg: TrainConfig
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_last: int = 3
    dtype: object = jnp.float32
    # failure injection: raise at this step (tests crash/recovery)
    fail_at_step: Optional[int] = None
    log_every: int = 10
    history: List[Dict] = field(default_factory=list)

    def run(self, stream: TokenStream, n_steps: int,
            on_step: Optional[Callable[[int, Dict], None]] = None) -> Dict:
        step_fn = jax.jit(make_train_step(self.mcfg, self.tcfg), donate_argnums=(0, 1))
        key = jax.random.PRNGKey(self.tcfg.seed)
        params, opt_state = init_train_state(self.mcfg, key, self.dtype)

        start = 0
        if self.ckpt_dir:
            last = ckpt.latest_step(self.ckpt_dir)
            if last is not None:
                params, opt_state, extra = ckpt.restore(
                    self.ckpt_dir, last, params, opt_state)
                stream.restore(extra["data"])
                start = last

        metrics = {}
        for step in range(start, n_steps):
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_time_s"] = time.perf_counter() - t0
            if on_step:
                on_step(step, metrics)
            if step % self.log_every == 0:
                self.history.append({"step": step, **metrics})
            if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                ckpt.save(self.ckpt_dir, step + 1, params, opt_state,
                          extra={"data": stream.state()},
                          keep_last=self.keep_last)
        if self.ckpt_dir:
            ckpt.save(self.ckpt_dir, n_steps, params, opt_state,
                      extra={"data": stream.state()}, keep_last=self.keep_last)
        self._final_params = params
        return metrics
