"""Scenario API: declarative workload/deployment specs over the whole
serving stack.

One :class:`Scenario` — a validated, dict-round-trippable dataclass
tree (:class:`WorkloadSpec` / :class:`NetworkSpec` /
:class:`DeploymentSpec` / :class:`PolicySpec`) — describes an entire
experiment; ``build()`` compiles it into runnable harnesses over the
three entry points (closed-loop simulator, discrete-event engine, live
pool executor), which expose the same construction as ``from_scenario``
adapters.  The registry holds named scenarios (steady / diurnal / burst
/ class_mix / scale_up) that ``benchmarks/scenario_suite.py`` runs; the
autoscaler closes the replica loop from ``Router.stats()`` telemetry.

>>> from repro.scenario import get_scenario, build
>>> out = build(get_scenario("steady")).run()
>>> out.result.sla_attainment
"""
from repro.scenario.autoscale import QueueTargetAutoscaler
from repro.scenario.build import (EpochResult, ScenarioHarness,
                                  ScenarioResult, build, build_closed_loop,
                                  build_engine, build_executor, build_faults,
                                  build_retry)
from repro.scenario.registry import (drift_scenario, faulty_scenario,
                                     fleet_scenario, get_scenario,
                                     list_scenarios, register)
from repro.scenario.spec import (AutoscalerSpec, DeploymentSpec, DriftSpec,
                                 FaultSpec, NetworkSpec, PolicySpec,
                                 RetrySpec, Scenario, SlaClass, WorkloadSpec)

__all__ = [
    "Scenario", "WorkloadSpec", "NetworkSpec", "DeploymentSpec",
    "PolicySpec", "SlaClass", "AutoscalerSpec",
    "FaultSpec", "DriftSpec", "RetrySpec",
    "build", "build_engine", "build_closed_loop", "build_executor",
    "build_faults", "build_retry",
    "ScenarioHarness", "ScenarioResult", "EpochResult",
    "QueueTargetAutoscaler",
    "register", "get_scenario", "list_scenarios",
    "drift_scenario", "faulty_scenario", "fleet_scenario",
]
