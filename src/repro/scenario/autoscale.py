"""Closed-loop replica autoscaling from router telemetry.

The ROADMAP loop this closes: ``Router.stats()`` (shed rate, fallback
rate, mean batch) plus the engine's queue-wait summary are exactly the
control signal a replica autoscaler needs.  ``QueueTargetAutoscaler``
consumes one epoch's *windowed* readings (the scenario harness builds a
fresh engine — and with it a fresh router — per epoch; long-running
routers get per-window deltas from ``Router.window_stats()`` without
zeroing, or the same effect via ``Router.reset()`` at each boundary)
and answers the replica count for the next epoch:

- **scale up** (by ``step``, capped at ``max_replicas``) when the epoch
  missed its queue target — mean queue wait above ``target_queue_ms``,
  the router shedding more than ``max_shed_rate`` of traffic, or the
  policy falling back (no model fit the budget) on more than
  ``max_fallback_rate`` of requests;
- **scale down** (by ``step``, floored at ``min_replicas``) only when
  the epoch was comfortably idle: no shedding, queue wait under a
  quarter of target, and mean replica utilization below
  ``low_utilization`` — hysteresis so the pool does not flap around the
  target.

The utilization read prefers ``LoadSimResult.mean_live_utilization``
(busy time over each replica's *alive* window).  Averaging the raw
``replica_utilization`` dict over all replicas dilutes the signal
*downward* when the epoch carried killed/decommissioned replicas — a
dead replica contributes ≈0 busy fraction, dragging the mean under
``low_utilization`` and promoting spurious scale-in while the survivors
are saturated (verified in ``tests/test_elastic.py``; the ISSUE's
"blocks legitimate scale-in" suspicion had the direction inverted).
On static fault-free pools the two reads are bit-identical, so every
epoch-boundary golden is preserved.

This is the *degenerate* control path — one decision per epoch,
instantaneous and free.  ``AutoscalerSpec.control_interval_ms > 0``
instead arms the engine-side mid-run controllers
(``sim.elastic``): cold-start-paying provisioning, drain-based
scale-in, windowed per-tick telemetry.

The policy is deliberately a deterministic function of one epoch's
telemetry: scenario runs stay reproducible, and the SLA-vs-cost
trade-off it makes is auditable per epoch in ``BENCH_scenario_suite``
rows (replicas, attainment, shed rate per epoch).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.scenario.spec import AutoscalerSpec


@dataclass
class QueueTargetAutoscaler:
    """Queue-depth-target scaling policy over ``Router.stats()``."""
    spec: AutoscalerSpec

    def decide(self, n_replicas: int, router_stats: Dict[str, float],
               result) -> int:
        """Next epoch's replica count from this epoch's telemetry.

        ``router_stats`` is a windowed ``Router.stats()`` reading;
        ``result`` is the epoch's ``LoadSimResult``.
        """
        s = self.spec
        routed = max(router_stats.get("n_routed", 0), 1)
        shed_rate = router_stats.get("n_shed", 0) / routed
        fallback_rate = router_stats.get("n_fallback", 0) / routed
        overloaded = (result.mean_queue_wait > s.target_queue_ms
                      or shed_rate > s.max_shed_rate
                      or fallback_rate > s.max_fallback_rate)
        if overloaded:
            return min(n_replicas + s.step, s.max_replicas)
        # Prefer the alive-window-normalized read: the all-replica mean
        # is diluted toward 0 by dead (killed/decommissioned) replicas,
        # which would trigger spurious scale-in while the survivors are
        # saturated.  Falsy covers results predating the field (and the
        # genuinely-idle pool, where the fallback computes ~0 anyway).
        mean_util = getattr(result, "mean_live_utilization", None)
        if not mean_util:
            util = result.replica_utilization
            mean_util = float(np.mean(list(util.values()))) if util else 0.0
        idle = (shed_rate == 0.0
                and result.mean_queue_wait < 0.25 * s.target_queue_ms
                and mean_util < s.low_utilization)
        if idle:
            return max(n_replicas - s.step, s.min_replicas)
        return n_replicas
