"""Named-scenario registry: the library of declarative experiments.

``register()`` any :class:`~repro.scenario.spec.Scenario` under its
name; ``get_scenario()`` / ``list_scenarios()`` look them up.  The
built-ins cover the shapes the ROADMAP calls for, and every one is run
at toy scale by ``benchmarks/run.py --smoke`` (tier-1's bit-rot guard)
and at full scale by ``benchmarks/scenario_suite.py``:

- ``steady`` — open-loop Poisson at a rate the pool absorbs; the
  config mirrors the seeded queue-aware engine golden, so the Scenario
  path is pinned bit-identical to the historical kwargs path;
- ``diurnal`` — sinusoidal day/night load through the diurnal trace
  synthesizer: the pool is sized for the valley, the peak exercises
  queue-aware spreading;
- ``burst`` — flash-crowd square wave with SLA-aware admission:
  shed-vs-degrade under a 20x load spike;
- ``class_mix`` — interactive/batch SLA mix under overload with
  class-aware admission: weighted shedding protects the interactive
  class at the batch class's expense;
- ``scale_up`` — a 10x load step under a queue-target autoscaler: SLA
  attainment collapses at the step and recovers as replicas are added,
  with no manual pool edits;
- ``elastic_step`` / ``elastic_proportional`` / ``elastic_cost_weighted``
  — the same 10x step under *mid-run* controllers ticking on the event
  queue (``sim.elastic``): cold-start-paying provisioning, drain-based
  scale-in, and the SLA-vs-replica-seconds frontier swept by
  ``benchmarks/elastic_controllers.py``.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.scenario.spec import (AutoscalerSpec, DeploymentSpec, DriftSpec,
                                 FaultSpec, InputClassSpec, NetworkSpec,
                                 PolicySpec, RetrySpec, Scenario, SlaClass,
                                 WorkloadSpec)

_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario, *, replace: bool = False) -> Scenario:
    """Add a scenario under its name; re-registration requires
    ``replace=True`` (guards against accidental shadowing)."""
    if scenario.name in _REGISTRY and not replace:
        raise ValueError(f"scenario {scenario.name!r} already registered "
                         "(pass replace=True to overwrite)")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r} "
                       f"(registered: {', '.join(sorted(_REGISTRY))})")


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# ----------------------------------------------------------------------
# built-ins
# ----------------------------------------------------------------------

# The load-sweep network model used by every serving benchmark.
_NET = NetworkSpec(mean_ms=50.0, std_ms=25.0)

# Mirrors the seeded golden `test_golden_queue_aware_open_loop_unchanged`
# (engine kwargs: seed=3, per-model replicas, queue-aware ModiPick,
# Poisson 30 rps, 600 requests, 250 ms SLA) — the round-trip test pins
# the Scenario path bit-identical to it.
register(Scenario(
    name="steady",
    workload=WorkloadSpec(arrival="poisson", rate_rps=30.0,
                          n_requests=600, t_sla_ms=250.0),
    network=_NET,
    deployment=DeploymentSpec(topology="per_model"),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=3))

register(Scenario(
    name="diurnal",
    workload=WorkloadSpec(arrival="diurnal", rate_rps=12.0,
                          period_ms=20_000.0, amplitude=0.9,
                          n_requests=1500, t_sla_ms=250.0),
    network=_NET,
    deployment=DeploymentSpec(topology="per_model"),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=5))

register(Scenario(
    name="burst",
    workload=WorkloadSpec(arrival="burst", rate_rps=4.0,
                          burst_rate_rps=80.0, burst_every_ms=10_000.0,
                          burst_len_ms=1_500.0, n_requests=1500,
                          t_sla_ms=250.0),
    network=_NET,
    deployment=DeploymentSpec(topology="per_model", admission="sla_aware"),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=5))

# One shared replica at 60 rps is genuinely saturated.  Class-blind
# admission sheds the *interactive* class first (its tighter budget goes
# non-viable first); class-aware weighted shedding inverts that — batch
# (protect 0.35) drains early, interactive keeps most of its attainment.
register(Scenario(
    name="class_mix",
    workload=WorkloadSpec(
        arrival="poisson", rate_rps=60.0, n_requests=1500, t_sla_ms=250.0,
        classes=(SlaClass("interactive", t_sla_ms=250.0, weight=0.5),
                 SlaClass("batch", t_sla_ms=400.0, weight=0.5))),
    network=_NET,
    deployment=DeploymentSpec(
        topology="shared", replicas=1,
        admission="class_aware",
        admission_kwargs={"classes": {
            "interactive": {"protect": 1.0},
            "batch": {"protect": 0.35, "max_share": 0.6},
        }}),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=7))

register(Scenario(
    name="scale_up",
    workload=WorkloadSpec(arrival="poisson", rate_rps=4.0,
                          rate_schedule=(4.0, 40.0, 40.0, 40.0, 40.0),
                          epochs=5, n_requests=2000, t_sla_ms=250.0),
    network=_NET,
    deployment=DeploymentSpec(
        topology="shared", replicas=1,
        autoscaler=AutoscalerSpec(target_queue_ms=25.0, max_shed_rate=0.02,
                                  min_replicas=1, max_replicas=8, step=2)),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=9))


# ----------------------------------------------------------------------
# the elastic family (mid-run controllers on the event queue)
# ----------------------------------------------------------------------

def elastic_scenario(*, kind: str = "proportional",
                     control_interval_ms: float = 1_000.0,
                     cold_start_ms: float = 500.0,
                     target_queue_ms: float = 25.0,
                     cost_per_replica_s: float = 0.0,
                     n_requests: int = 2000, epochs: int = 5,
                     seed: int = 9, name: Optional[str] = None) -> Scenario:
    """The ``scale_up`` 10x load step under a MID-RUN elastic controller
    (``sim.elastic``): identical workload shape, network, policy and
    seed as the epoch-boundary ``scale_up`` registry entry, so the two
    paths are an apples-to-apples comparison — same arrival draws, only
    the control law differs.  The controller ticks every
    ``control_interval_ms`` inside each epoch, scale-up pays
    ``cold_start_ms`` per WARMING replica, and scale-in drains before
    decommissioning (zero in-flight requests lost)."""
    return Scenario(
        name=name or f"elastic_{kind}",
        workload=WorkloadSpec(
            arrival="poisson", rate_rps=4.0,
            rate_schedule=(4.0,) + (40.0,) * (epochs - 1),
            epochs=epochs, n_requests=n_requests, t_sla_ms=250.0),
        network=_NET,
        deployment=DeploymentSpec(
            topology="shared", replicas=1,
            autoscaler=AutoscalerSpec(
                target_queue_ms=target_queue_ms, max_shed_rate=0.02,
                min_replicas=1, max_replicas=8, step=2,
                kind=kind, control_interval_ms=control_interval_ms,
                cold_start_ms=cold_start_ms,
                cost_per_replica_s=cost_per_replica_s)),
        policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                          queue_aware=True),
        seed=seed)


register(elastic_scenario(kind="step", name="elastic_step"))
register(elastic_scenario(kind="proportional", name="elastic_proportional"))
register(elastic_scenario(kind="cost_weighted", cost_per_replica_s=0.5,
                          name="elastic_cost_weighted"))


# ----------------------------------------------------------------------
# the drift/faulty family (fault injection & drift resilience)
# ----------------------------------------------------------------------

# A tight (low-variance) uplink so the drift experiment's budget is
# sharp: 2·40 = 80 ms of network under a 250 ms SLA leaves a 170 ms
# budget — NasNet-Large (μ 112.61) fits, its 2x-drifted self (225.22)
# does not, and a drifted pick's true e2e (~305 ms) is a certain miss.
_DRIFT_NET = NetworkSpec(mean_ms=40.0, std_ms=10.0)


def drift_scenario(*, mu_mult: float = 2.0, profile: str = "window",
                   n_requests: int = 2400, rate_rps: float = 12.0,
                   drift_at_ms: float = 40_000.0,
                   recover_at_ms: float = 120_000.0,
                   window: int = 64, stale_after: int = 250,
                   seed: int = 11, name: Optional[str] = None) -> Scenario:
    """Mid-run latency drift on the most accurate model, with recovery.

    NasNet-Large's true μ is multiplied by ``mu_mult`` at
    ``drift_at_ms`` and restored at ``recover_at_ms``.  Replicas are
    per-model and plentiful (queue waits ~0), so the *only* signal that
    the world changed is the observed inference latency — exactly the
    telemetry a profile estimator owns.  ``profile`` picks the arm:
    ``"window"`` (self-healing sliding window + staleness exploration)
    recovers; ``"frozen"`` (the ablation) keeps routing on the seeded
    profile and stays degraded.  Cold probing is off so re-discovery is
    attributable to the staleness bonus alone.
    """
    return Scenario(
        name=name or f"drift_{profile}",
        workload=WorkloadSpec(arrival="poisson", rate_rps=rate_rps,
                              n_requests=n_requests, t_sla_ms=250.0),
        network=_DRIFT_NET,
        deployment=DeploymentSpec(
            topology="per_model", replicas=4,
            drifts=(DriftSpec(kind="latency", at_ms=drift_at_ms,
                              model="NasNet-Large", mu_mult=mu_mult),
                    DriftSpec(kind="latency", at_ms=recover_at_ms,
                              model="NasNet-Large", mu_mult=1.0)),
            retry=RetrySpec(max_attempts=2)),
        policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                          queue_aware=True, cold_probe=False,
                          profile=profile, window=window,
                          stale_after=stale_after),
        seed=seed)


def faulty_scenario(*, retry: bool = True, n_requests: int = 1500,
                    rate_rps: float = 15.0,
                    kill_at_ms: float = 20_000.0,
                    revive_at_ms: float = 60_000.0,
                    degrade_at_ms: float = 45_000.0,
                    degrade_factor: float = 2.5,
                    recover_at_ms: float = 75_000.0,
                    seed: int = 13, name: Optional[str] = None) -> Scenario:
    """Replica-lifecycle churn on a shared pool: one replica killed
    mid-run (its in-flight and queued requests hit the recovery path),
    a second degraded, both eventually restored.  ``retry=False``
    disables the recovery path — the victims are simply rejected
    (the retry-ablation arm)."""
    return Scenario(
        name=name or ("faulty" if retry else "faulty_noretry"),
        workload=WorkloadSpec(arrival="poisson", rate_rps=rate_rps,
                              n_requests=n_requests, t_sla_ms=250.0),
        network=_DRIFT_NET,
        deployment=DeploymentSpec(
            topology="shared", replicas=3,
            admission="sla_aware",
            faults=(FaultSpec(kind="kill", replica="r0", at_ms=kill_at_ms),
                    FaultSpec(kind="degrade", replica="r1",
                              at_ms=degrade_at_ms, factor=degrade_factor),
                    FaultSpec(kind="recover", replica="r0",
                              at_ms=revive_at_ms),
                    FaultSpec(kind="recover", replica="r1",
                              at_ms=recover_at_ms)),
            retry=RetrySpec(max_attempts=3) if retry else None),
        policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                          queue_aware=True),
        seed=seed)


register(drift_scenario(name="drift"))
register(drift_scenario(profile="frozen", name="drift_frozen"))
register(faulty_scenario(name="faulty"))


# ----------------------------------------------------------------------
# the fleet family (sharded multi-cell serving)
# ----------------------------------------------------------------------

def fleet_scenario(*, n_cells: int = 4, rate_rps: float = 120.0,
                   n_requests: int = 20_000, rtt_ms: float = 40.0,
                   spill: bool = True, spill_threshold_ms: float = 0.0,
                   replicas: int = 1, subset: tuple = (),
                   trace_path: str = "",
                   rotate_phases: bool = False,
                   weights: Optional[tuple] = None,
                   epoch_ms: float = 10_000.0, period_ms: float = 60_000.0,
                   t_sla_ms: float = 250.0, seed: int = 17,
                   name: Optional[str] = None) -> Scenario:
    """A multi-cell fleet over the steady per-model deployment.

    ``rate_rps`` is the FLEET-wide offered load; each cell receives its
    weighted share on its own arrival timeline.  ``rotate_phases``
    spreads the cells' diurnal peaks evenly around the day (cell i at
    phase i/n — the time-zone ring), which only matters with a
    ``trace_path`` or diurnal workload.  ``spill_threshold_ms`` arms
    load-triggered spill on top of the default no-viable-variant
    trigger."""
    from repro.fleet.spec import CellSpec, FleetSpec
    w = weights if weights is not None else (1.0,) * n_cells
    cells = tuple(
        CellSpec(name=f"cell{i}", weight=w[i],
                 phase=(i / n_cells) if rotate_phases else 0.0)
        for i in range(n_cells))
    return Scenario(
        name=name or f"fleet_{n_cells}cell",
        workload=WorkloadSpec(arrival="poisson", rate_rps=rate_rps,
                              n_requests=n_requests, t_sla_ms=t_sla_ms,
                              period_ms=period_ms),
        network=_NET,
        deployment=DeploymentSpec(
            topology="per_model", replicas=replicas, subset=subset,
            fleet=FleetSpec(cells=cells, rtt_ms=rtt_ms, spill=spill,
                            spill_threshold_ms=spill_threshold_ms,
                            epoch_ms=epoch_ms, trace_path=trace_path)),
        policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                          queue_aware=True),
        seed=seed)


# ----------------------------------------------------------------------
# the premodel family (input-conditional profiles & tail-SLA budgets)
# ----------------------------------------------------------------------

def premodel_scenario(*, premodel: str = "centroid",
                      easy_scale: float = 0.25, hard_scale: float = 3.0,
                      easy_weight: float = 0.5, feature_noise: float = 0.2,
                      n_requests: int = 4000, rate_rps: float = 12.0,
                      t_sla_ms: float = 250.0, seed: int = 23,
                      name: Optional[str] = None) -> Scenario:
    """Heterogeneous-difficulty inputs under one SLA: half the requests
    are easy (true service = ``easy_scale`` x the model's draw), half
    hard (``hard_scale`` x), separable by a cheap 1-D feature.

    The tight uplink (2·40 = 80 ms under a 250 ms SLA) leaves a 170 ms
    budget.  Unconditional profiles see each model as the bimodal
    mixture — the inflated spread pushes every accurate model out of
    eligibility and the router converges to one mid-tier compromise for
    *everyone*.  With ``premodel="centroid"`` (or the ``"oracle"``
    ablation) the conditional store routes easy inputs to the most
    accurate model while hard inputs keep the mid-tier pick — strictly
    more accuracy at the same attainment.  ``premodel="none"`` is the
    unconditional arm over the *identical* workload (same salted
    class/feature/scale assignment, same arrival and service draws)."""
    return Scenario(
        name=name or f"premodel_{premodel}",
        workload=WorkloadSpec(
            arrival="poisson", rate_rps=rate_rps, n_requests=n_requests,
            t_sla_ms=t_sla_ms,
            input_classes=(
                InputClassSpec("easy", weight=easy_weight,
                               latency_scale=easy_scale,
                               feature_center=(0.0,),
                               feature_noise=feature_noise),
                InputClassSpec("hard", weight=1.0 - easy_weight,
                               latency_scale=hard_scale,
                               feature_center=(1.0,),
                               feature_noise=feature_noise))),
        network=_DRIFT_NET,
        deployment=DeploymentSpec(topology="per_model", replicas=2),
        policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                          queue_aware=True, premodel=premodel),
        seed=seed)


def tail_sla_scenario(*, quantile: Optional[float] = 0.95,
                      spike_prob: float = 0.2, spike_mult: float = 3.5,
                      n_requests: int = 3000, rate_rps: float = 15.0,
                      t_sla_ms: float = 250.0, seed: int = 29,
                      name: Optional[str] = None) -> Scenario:
    """Co-tenant latency spikes vs the budget the router believes.

    A fifth of inferences run 3.5x slow — far more probability mass
    than a p95 budget tolerates.  The mean arm (``quantile=None``)
    keeps spiky mid-heavy models eligible (their EWMA mean + σ still
    fits the 170 ms budget) and eats a tail of certain SLA misses; the
    quantile arm presents each model's streaming p95, which lands in
    the spike region and excludes exactly the models whose spikes
    cannot fit — buying back the tail attainment."""
    return Scenario(
        name=name or ("tail_sla" if quantile is not None
                      else "tail_sla_mean"),
        workload=WorkloadSpec(arrival="poisson", rate_rps=rate_rps,
                              n_requests=n_requests, t_sla_ms=t_sla_ms),
        network=_DRIFT_NET,
        deployment=DeploymentSpec(topology="per_model", replicas=2,
                                  spike_prob=spike_prob,
                                  spike_mult=spike_mult),
        policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                          queue_aware=True, latency_quantile=quantile),
        seed=seed)


register(premodel_scenario(name="premodel_mix"))
register(tail_sla_scenario(name="tail_sla"))
register(tail_sla_scenario(quantile=None, name="tail_sla_mean"))


# Balanced 4-cell fleet at the steady per-cell operating point (each
# cell sees ~30 rps — the seeded golden's load): the healthy baseline.
register(fleet_scenario(n_cells=4, rate_rps=120.0, n_requests=20_000,
                        seed=17, name="fleet_steady"))

# Six time zones replaying the same recorded day (Azure-Functions-style
# rate trace, peak ≈ 2.1× mean), peaks rotated 4 h apart.  Cells run a
# mid/heavy zoo slice sized for the *valley* (≈144 rps capacity vs a
# ≈180 rps peak), so at any instant the cell at local evening runs hot
# while the antipodal cells idle — the shape cross-cell spill exists
# for.  Load-triggered spill is armed at a 40 ms queue-wait signal.
register(fleet_scenario(n_cells=6, rate_rps=510.0, n_requests=30_000,
                        subset=("DenseNet", "NasNet-Mobile", "InceptionV3",
                                "InceptionV4", "NasNet-Large"),
                        trace_path="examples/azure_functions_day.csv",
                        rotate_phases=True, spill_threshold_ms=40.0,
                        epoch_ms=5_000.0, seed=19, name="fleet_diurnal"))
