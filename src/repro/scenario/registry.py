"""Named-scenario registry: the library of declarative experiments.

``register()`` any :class:`~repro.scenario.spec.Scenario` under its
name; ``get_scenario()`` / ``list_scenarios()`` look them up.  The
built-ins cover the shapes the ROADMAP calls for, and every one is run
at toy scale by ``benchmarks/run.py --smoke`` (tier-1's bit-rot guard)
and at full scale by ``benchmarks/scenario_suite.py``:

- ``steady`` — open-loop Poisson at a rate the pool absorbs; the
  config mirrors the seeded queue-aware engine golden, so the Scenario
  path is pinned bit-identical to the historical kwargs path;
- ``diurnal`` — sinusoidal day/night load through the diurnal trace
  synthesizer: the pool is sized for the valley, the peak exercises
  queue-aware spreading;
- ``burst`` — flash-crowd square wave with SLA-aware admission:
  shed-vs-degrade under a 20x load spike;
- ``class_mix`` — interactive/batch SLA mix under overload with
  class-aware admission: weighted shedding protects the interactive
  class at the batch class's expense;
- ``scale_up`` — a 10x load step under a queue-target autoscaler: SLA
  attainment collapses at the step and recovers as replicas are added,
  with no manual pool edits.
"""
from __future__ import annotations

from typing import Dict, List

from repro.scenario.spec import (AutoscalerSpec, DeploymentSpec,
                                 NetworkSpec, PolicySpec, Scenario, SlaClass,
                                 WorkloadSpec)

_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario, *, replace: bool = False) -> Scenario:
    """Add a scenario under its name; re-registration requires
    ``replace=True`` (guards against accidental shadowing)."""
    if scenario.name in _REGISTRY and not replace:
        raise ValueError(f"scenario {scenario.name!r} already registered "
                         "(pass replace=True to overwrite)")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r} "
                       f"(registered: {', '.join(sorted(_REGISTRY))})")


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# ----------------------------------------------------------------------
# built-ins
# ----------------------------------------------------------------------

# The load-sweep network model used by every serving benchmark.
_NET = NetworkSpec(mean_ms=50.0, std_ms=25.0)

# Mirrors the seeded golden `test_golden_queue_aware_open_loop_unchanged`
# (engine kwargs: seed=3, per-model replicas, queue-aware ModiPick,
# Poisson 30 rps, 600 requests, 250 ms SLA) — the round-trip test pins
# the Scenario path bit-identical to it.
register(Scenario(
    name="steady",
    workload=WorkloadSpec(arrival="poisson", rate_rps=30.0,
                          n_requests=600, t_sla_ms=250.0),
    network=_NET,
    deployment=DeploymentSpec(topology="per_model"),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=3))

register(Scenario(
    name="diurnal",
    workload=WorkloadSpec(arrival="diurnal", rate_rps=12.0,
                          period_ms=20_000.0, amplitude=0.9,
                          n_requests=1500, t_sla_ms=250.0),
    network=_NET,
    deployment=DeploymentSpec(topology="per_model"),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=5))

register(Scenario(
    name="burst",
    workload=WorkloadSpec(arrival="burst", rate_rps=4.0,
                          burst_rate_rps=80.0, burst_every_ms=10_000.0,
                          burst_len_ms=1_500.0, n_requests=1500,
                          t_sla_ms=250.0),
    network=_NET,
    deployment=DeploymentSpec(topology="per_model", admission="sla_aware"),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=5))

# One shared replica at 60 rps is genuinely saturated.  Class-blind
# admission sheds the *interactive* class first (its tighter budget goes
# non-viable first); class-aware weighted shedding inverts that — batch
# (protect 0.35) drains early, interactive keeps most of its attainment.
register(Scenario(
    name="class_mix",
    workload=WorkloadSpec(
        arrival="poisson", rate_rps=60.0, n_requests=1500, t_sla_ms=250.0,
        classes=(SlaClass("interactive", t_sla_ms=250.0, weight=0.5),
                 SlaClass("batch", t_sla_ms=400.0, weight=0.5))),
    network=_NET,
    deployment=DeploymentSpec(
        topology="shared", replicas=1,
        admission="class_aware",
        admission_kwargs={"classes": {
            "interactive": {"protect": 1.0},
            "batch": {"protect": 0.35, "max_share": 0.6},
        }}),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=7))

register(Scenario(
    name="scale_up",
    workload=WorkloadSpec(arrival="poisson", rate_rps=4.0,
                          rate_schedule=(4.0, 40.0, 40.0, 40.0, 40.0),
                          epochs=5, n_requests=2000, t_sla_ms=250.0),
    network=_NET,
    deployment=DeploymentSpec(
        topology="shared", replicas=1,
        autoscaler=AutoscalerSpec(target_queue_ms=25.0, max_shed_rate=0.02,
                                  min_replicas=1, max_replicas=8, step=2)),
    policy=PolicySpec(policy="modipick", kwargs={"t_threshold": 20.0},
                      queue_aware=True),
    seed=9))
