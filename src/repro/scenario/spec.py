"""The declarative Scenario spec: one plain-data tree describing an
entire ModiPick experiment.

Every experiment in this repo used to be wired by hand — a dozen kwargs
spread over three entry points (``core.simulate.Simulator``,
``sim.engine.ServingSimulator``, ``serving.executor.PoolExecutor``).  A
:class:`Scenario` captures the same degrees of freedom as one validated,
serializable record:

- :class:`WorkloadSpec` — what arrives: the arrival process (closed
  loop, Poisson, explicit trace, or the diurnal/burst synthesizers),
  how many requests, the SLA, an optional per-class SLA mix
  (:class:`SlaClass` weights), and an optional per-epoch rate schedule
  (the load-step shape the autoscaler study needs);
- :class:`NetworkSpec` — the mobile uplink model (§4's truncated
  normal);
- :class:`DeploymentSpec` — what serves: zoo subset, replica topology
  and speeds, queue caps, admission mode, lookahead batching window,
  and the optional :class:`AutoscalerSpec` closing the replica loop;
- :class:`PolicySpec` — what decides: policy + kwargs, queue-aware
  budgets, vectorized backend, and the profile-learning knobs.

Specs are frozen dataclasses that validate at construction and
round-trip losslessly through plain dicts (``to_dict``/``from_dict``):
every leaf is JSON/TOML-representable, so a scenario can live in a
config file, a benchmark registry, or a service request body.
``scenario.build()`` (``repro.scenario.build``) compiles the spec into
runnable harnesses over any of the three entry points.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, TYPE_CHECKING, Tuple

if TYPE_CHECKING:                    # pragma: no cover - type-only import
    from repro.fleet.spec import FleetSpec

ARRIVAL_KINDS = ("closed_loop", "poisson", "trace", "diurnal", "burst")
TOPOLOGIES = ("per_model", "shared")
ZOOS = ("table2", "prototype")
ADMISSION_MODES = ("none", "admit_all", "depth_cap", "sla_aware",
                   "class_aware")
FAULT_KINDS = ("kill", "degrade", "drain", "recover")
DRIFT_KINDS = ("latency", "network")
PROFILE_MODES = ("ewma", "window", "frozen")
PREMODEL_MODES = ("none", "centroid", "oracle")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclass(frozen=True)
class SlaClass:
    """One class in a per-request SLA mix: requests are labelled
    ``name``, carry ``t_sla_ms``, and arrive in proportion to
    ``weight``."""
    name: str
    t_sla_ms: float
    weight: float = 1.0

    def __post_init__(self):
        _require(bool(self.name), "SlaClass needs a non-empty name")
        _require(self.t_sla_ms > 0.0,
                 f"SlaClass {self.name!r}: t_sla_ms must be positive")
        _require(self.weight > 0.0,
                 f"SlaClass {self.name!r}: weight must be positive")


@dataclass(frozen=True)
class InputClassSpec:
    """One input class in a heterogeneous-difficulty workload: requests
    of this class arrive in proportion to ``weight``, their true
    service time is the model's draw times ``latency_scale`` (easy
    inputs < 1, hard inputs > 1), and each carries a cheap feature
    vector drawn at ``feature_center`` ± ``feature_noise`` — what the
    premodel classifier sees."""
    name: str
    weight: float = 1.0
    latency_scale: float = 1.0
    feature_center: Tuple[float, ...] = ()
    feature_noise: float = 0.25

    def __post_init__(self):
        _require(bool(self.name), "InputClassSpec needs a non-empty name")
        _require(self.weight > 0.0,
                 f"input class {self.name!r}: weight must be positive")
        _require(self.latency_scale > 0.0,
                 f"input class {self.name!r}: latency_scale must be "
                 "positive")
        _require(len(self.feature_center) > 0,
                 f"input class {self.name!r}: feature_center must be "
                 "non-empty")
        _require(self.feature_noise >= 0.0,
                 f"input class {self.name!r}: feature_noise must be "
                 "non-negative")


@dataclass(frozen=True)
class WorkloadSpec:
    """What arrives, how fast, and under which SLAs."""
    arrival: str = "poisson"
    n_requests: int = 1000
    t_sla_ms: float = 250.0          # run-level SLA / reporting label
    rate_rps: float = 10.0           # poisson / diurnal / burst base rate
    rate_schedule: Tuple[float, ...] = ()  # per-epoch poisson rates
    epochs: int = 1
    think_ms: float = 0.0            # closed_loop
    times_ms: Tuple[float, ...] = ()  # trace (n_requests derives from it)
    period_ms: float = 60_000.0      # diurnal day length
    amplitude: float = 0.8           # diurnal swing, [0, 1)
    burst_rate_rps: float = 0.0      # burst peak
    burst_every_ms: float = 10_000.0
    burst_len_ms: float = 1_000.0
    classes: Tuple[SlaClass, ...] = ()  # per-class SLA mix ((): single SLA)
    # Per-input-class difficulty mix ((): homogeneous inputs — the
    # historical workload).  Drives true service-time scaling and the
    # feature vectors the premodel classifies on.
    input_classes: Tuple[InputClassSpec, ...] = ()

    def __post_init__(self):
        _require(self.arrival in ARRIVAL_KINDS,
                 f"arrival must be one of {ARRIVAL_KINDS}, "
                 f"got {self.arrival!r}")
        _require(self.n_requests > 0, "n_requests must be positive")
        _require(self.t_sla_ms > 0.0, "t_sla_ms must be positive")
        _require(self.epochs >= 1, "epochs must be >= 1")
        if self.arrival in ("poisson", "diurnal", "burst"):
            _require(self.rate_rps > 0.0,
                     f"{self.arrival} arrivals need rate_rps > 0")
        if self.arrival == "trace":
            _require(len(self.times_ms) > 0,
                     "trace arrivals need explicit times_ms")
            # A trace IS the workload: its length defines the request
            # count (n_requests is derived, never independently set).
            object.__setattr__(self, "n_requests", len(self.times_ms))
        if self.arrival == "diurnal":
            _require(0.0 <= self.amplitude < 1.0,
                     f"amplitude must be in [0, 1), got {self.amplitude}")
            _require(self.period_ms > 0.0, "period_ms must be positive")
        if self.arrival == "burst":
            _require(self.burst_rate_rps >= self.rate_rps,
                     "burst_rate_rps must be >= rate_rps")
            _require(0.0 < self.burst_len_ms <= self.burst_every_ms,
                     "need 0 < burst_len_ms <= burst_every_ms")
        if self.rate_schedule:
            _require(self.arrival == "poisson",
                     "rate_schedule only applies to poisson arrivals")
            _require(len(self.rate_schedule) == self.epochs,
                     f"rate_schedule has {len(self.rate_schedule)} entries "
                     f"for {self.epochs} epochs")
            _require(all(r > 0.0 for r in self.rate_schedule),
                     "rate_schedule rates must be positive")
        _require(self.n_requests >= self.epochs,
                 f"n_requests ({self.n_requests}) must cover every epoch "
                 f"({self.epochs}) — empty epochs are not runnable")
        names = [c.name for c in self.classes]
        _require(len(names) == len(set(names)),
                 f"duplicate SLA class names: {names}")
        if self.input_classes:
            inames = [c.name for c in self.input_classes]
            _require(len(inames) == len(set(inames)),
                     f"duplicate input class names: {inames}")
            dims = {len(c.feature_center) for c in self.input_classes}
            _require(len(dims) == 1,
                     "every input class must use the same feature "
                     f"dimensionality, got {sorted(dims)}")


@dataclass(frozen=True)
class NetworkSpec:
    """Mobile uplink model: truncated normal, ms (Fig. 1 / §4)."""
    mean_ms: float = 57.87           # campus WiFi (Table: CAMPUS_WIFI)
    std_ms: float = 30.78
    floor_ms: float = 0.1

    def __post_init__(self):
        _require(self.mean_ms > 0.0, "mean_ms must be positive")
        _require(self.std_ms >= 0.0, "std_ms must be non-negative")


CONTROLLER_KINDS = ("step", "proportional", "cost_weighted")


@dataclass(frozen=True)
class AutoscalerSpec:
    """Closed-loop replica scaling targets.

    ``control_interval_ms == 0`` (the default) keeps the historical
    epoch-boundary path: ``QueueTargetAutoscaler.decide`` resizes the
    pool between epochs, instantaneously and for free.  A positive
    interval arms the *mid-run* elastic lifecycle
    (``sim.elastic.ElasticConfig``): a controller of ``kind`` ticks on
    the engine's event queue every interval, scale-up pays
    ``cold_start_ms`` per replica (WARMING -> UP), scale-in drains
    before decommissioning, and replica-seconds are priced at
    ``cost_per_replica_s`` on the bench frontier."""
    target_queue_ms: float = 50.0    # scale up above this mean queue wait
    max_shed_rate: float = 0.02      # ... or above this router shed rate
    max_fallback_rate: float = 0.25  # ... or above this router fallback rate
    min_replicas: int = 1
    max_replicas: int = 8
    step: int = 1                    # replicas added/removed per decision
    low_utilization: float = 0.3     # scale down below this mean busy frac
    kind: str = "step"               # controller family (mid-run path)
    control_interval_ms: float = 0.0  # 0 = epoch-boundary (historical)
    cold_start_ms: float = 0.0       # WARMING -> UP delay per new replica
    cost_per_replica_s: float = 0.0  # frontier price per replica-second

    def __post_init__(self):
        _require(self.target_queue_ms > 0.0, "target_queue_ms must be > 0")
        _require(0.0 <= self.max_shed_rate <= 1.0,
                 "max_shed_rate must be in [0, 1]")
        _require(0.0 <= self.max_fallback_rate <= 1.0,
                 "max_fallback_rate must be in [0, 1]")
        _require(1 <= self.min_replicas <= self.max_replicas,
                 "need 1 <= min_replicas <= max_replicas")
        _require(self.step >= 1, "step must be >= 1")
        _require(self.kind in CONTROLLER_KINDS,
                 f"controller kind must be one of {CONTROLLER_KINDS}, "
                 f"got {self.kind!r}")
        _require(self.control_interval_ms >= 0.0,
                 "control_interval_ms must be non-negative "
                 "(0 = epoch-boundary scaling)")
        _require(self.cold_start_ms >= 0.0,
                 "cold_start_ms must be non-negative")
        _require(self.cost_per_replica_s >= 0.0,
                 "cost_per_replica_s must be non-negative")
        if self.control_interval_ms == 0.0:
            _require(self.kind == "step",
                     f"controller kind {self.kind!r} needs a mid-run tick "
                     "(control_interval_ms > 0); the epoch-boundary path "
                     "is the step policy")
            _require(self.cold_start_ms == 0.0,
                     "cold_start_ms needs control_interval_ms > 0 "
                     "(epoch-boundary scaling is instantaneous by "
                     "construction)")


@dataclass(frozen=True)
class FaultSpec:
    """One replica-lifecycle fault (``sim.faults.ReplicaFault``):
    ``kind`` transition on ``replica`` at ``at_ms`` into the run
    (engine timeline; ``factor`` is the degrade slowdown)."""
    kind: str
    replica: str
    at_ms: float
    factor: float = 2.0

    def __post_init__(self):
        _require(self.kind in FAULT_KINDS,
                 f"fault kind must be one of {FAULT_KINDS}, "
                 f"got {self.kind!r}")
        _require(bool(self.replica), "FaultSpec needs a replica name")
        _require(self.at_ms >= 0.0, "at_ms must be non-negative")
        _require(self.factor > 0.0, "factor must be positive")


@dataclass(frozen=True)
class DriftSpec:
    """One ground-truth drift event: ``latency`` shifts one model's
    service process (μ/σ multiplied vs the seeded truth — absolute, not
    cumulative, so ``mu_mult=1.0`` later is the recovery); ``network``
    scales the RTT by ``rtt_mult``."""
    kind: str = "latency"
    at_ms: float = 0.0
    model: str = ""                  # latency drifts only
    mu_mult: float = 1.0
    sigma_mult: float = 1.0
    rtt_mult: float = 1.0            # network drifts only

    def __post_init__(self):
        _require(self.kind in DRIFT_KINDS,
                 f"drift kind must be one of {DRIFT_KINDS}, "
                 f"got {self.kind!r}")
        _require(self.at_ms >= 0.0, "at_ms must be non-negative")
        if self.kind == "latency":
            _require(bool(self.model), "latency drift needs a model name")
            _require(self.mu_mult > 0.0 and self.sigma_mult > 0.0,
                     "mu_mult/sigma_mult must be positive")
        else:
            _require(self.rtt_mult > 0.0, "rtt_mult must be positive")


@dataclass(frozen=True)
class RetrySpec:
    """Router recovery policy (``router.retry.RetryPolicy``):
    ``max_attempts`` total placements per request including the first;
    ``reroute_on_overrun`` arms the deadline-overrun hedge at service
    start, with ``overrun_margin_ms`` slack before it triggers."""
    max_attempts: int = 2
    reroute_on_overrun: bool = True
    overrun_margin_ms: float = 0.0

    def __post_init__(self):
        _require(self.max_attempts >= 1,
                 "max_attempts must be >= 1 (it counts the first "
                 "placement)")
        _require(self.overrun_margin_ms >= 0.0,
                 "overrun_margin_ms must be non-negative")


@dataclass(frozen=True)
class DeploymentSpec:
    """What serves: zoo subset, replica topology, admission, batching."""
    zoo: str = "table2"              # "table2" | "prototype"
    subset: Tuple[str, ...] = ()     # () = the whole zoo
    topology: str = "per_model"      # "per_model" | "shared"
    replicas: int = 1                # per model, or total when shared
    speeds: Tuple[float, ...] = ()   # shared only; () = all 1.0
    max_queue_depth: Optional[int] = None
    admission: str = "none"
    admission_kwargs: Dict[str, Any] = field(default_factory=dict)
    batch_window_ms: float = 0.0
    spike_prob: float = 0.0          # co-tenant latency spikes
    spike_mult: float = 10.0
    autoscaler: Optional[AutoscalerSpec] = None
    # Fault injection & recovery (() / None = the fair-weather world;
    # runs stay bit-identical to the pre-fault engine).
    faults: Tuple[FaultSpec, ...] = ()
    drifts: Tuple[DriftSpec, ...] = ()
    retry: Optional[RetrySpec] = None
    # Multi-cell fleet layer (``repro.fleet``): None = a single cell,
    # the historical deployment.  The cell list, inter-cell RTT and
    # spill policy live in the FleetSpec; per-cell overrides fall back
    # to this deployment's zoo/topology/replicas.
    fleet: Optional["FleetSpec"] = None

    def __post_init__(self):
        _require(self.zoo in ZOOS,
                 f"zoo must be one of {ZOOS}, got {self.zoo!r}")
        _require(self.topology in TOPOLOGIES,
                 f"topology must be one of {TOPOLOGIES}, "
                 f"got {self.topology!r}")
        _require(self.replicas >= 1, "replicas must be >= 1")
        if self.speeds:
            _require(self.topology == "shared",
                     "speeds only apply to the shared topology")
            _require(len(self.speeds) == self.replicas,
                     f"{len(self.speeds)} speeds for {self.replicas} "
                     "replicas")
        _require(self.admission in ADMISSION_MODES,
                 f"admission must be one of {ADMISSION_MODES}, "
                 f"got {self.admission!r}")
        _require(self.max_queue_depth is None or self.max_queue_depth >= 1,
                 "max_queue_depth must be >= 1 (or None)")
        _require(self.batch_window_ms >= 0.0,
                 "batch_window_ms must be non-negative")
        _require(0.0 <= self.spike_prob <= 1.0,
                 "spike_prob must be in [0, 1]")


# Kwargs a bare PolicySpec(policy=...) resolves to: the repo-wide
# benchmark settings (ModiPick's 20 ms window, StaticGreedy frozen at
# the suite's default SLA).
_POLICY_DEFAULT_KWARGS: Dict[str, Dict[str, Any]] = {
    "modipick": {"t_threshold": 20.0},
    "related_random": {"t_threshold": 20.0},
    "related_accurate": {"t_threshold": 20.0},
    "static_greedy": {"t_sla": 250.0},
}


@dataclass(frozen=True)
class PolicySpec:
    """What decides, and how its profiles learn.  Empty ``kwargs``
    normalize to the policy's defaults (``_POLICY_DEFAULT_KWARGS``) at
    construction, so specs always serialize fully resolved."""
    policy: str = "modipick"
    kwargs: Dict[str, Any] = field(default_factory=dict)
    queue_aware: bool = False
    backend: Optional[str] = None    # policy_vec backend override
    alpha: float = 0.1               # EWMA step for profile updates
    cold_age: int = 500
    cold_probe: bool = True
    warm: bool = True                # seed profiles at the true (mu, sigma)
    # Profile estimator family (``core.zoo.make_store``): "ewma" (the
    # paper's), "window" (sliding window + staleness exploration — the
    # self-healing mode), "frozen" (drift-ablation baseline).  The
    # window knobs are ignored outside "window" mode.
    profile: str = "ewma"
    window: int = 64
    stale_after: int = 400
    explore_bonus: float = 0.9
    # Tail-aware budgets: present each model's latency as this quantile
    # of its observed distribution instead of the EWMA mean (None = the
    # paper's mean-based presentation).  Eligibility, utilities and
    # SLA-aware admission all judge against the presented value, so a
    # 0.95 here makes the whole pipeline rank models by their p95.
    latency_quantile: Optional[float] = None
    # Premodel input classifier ("repro.premodel"): "none" (historical),
    # "centroid" (online nearest-centroid learned from the feature
    # stream), "oracle" (frozen true-center ablation).  Anything but
    # "none" needs workload.input_classes.
    premodel: str = "none"

    def __post_init__(self):
        from repro.core.policy import POLICIES, make_policy
        _require(self.policy in POLICIES,
                 f"policy must be one of {tuple(sorted(POLICIES))}, "
                 f"got {self.policy!r}")
        _require(self.backend in (None, "auto", "numpy", "jax"),
                 f"backend must be None, auto, numpy or jax, "
                 f"got {self.backend!r}")
        _require(0.0 < self.alpha <= 1.0, "alpha must be in (0, 1]")
        _require(self.cold_age >= 1, "cold_age must be >= 1")
        _require(self.profile in PROFILE_MODES,
                 f"profile must be one of {PROFILE_MODES}, "
                 f"got {self.profile!r}")
        _require(self.window >= 2, "window must be >= 2")
        _require(self.stale_after >= 1, "stale_after must be >= 1")
        _require(0.0 <= self.explore_bonus < 1.0,
                 "explore_bonus must be in [0, 1)")
        if self.latency_quantile is not None:
            _require(0.5 <= self.latency_quantile < 1.0,
                     "latency_quantile must be in [0.5, 1), "
                     f"got {self.latency_quantile}")
        _require(self.premodel in PREMODEL_MODES,
                 f"premodel must be one of {PREMODEL_MODES}, "
                 f"got {self.premodel!r}")
        if self.premodel != "none" or self.latency_quantile is not None:
            _require(self.profile == "ewma",
                     "premodel / latency_quantile stores extend the EWMA "
                     f"profile family (profile={self.profile!r})")
        if not self.kwargs:
            object.__setattr__(
                self, "kwargs",
                dict(_POLICY_DEFAULT_KWARGS.get(self.policy, {})))
        try:
            # fail at construction, not at build()/run() time
            make_policy(self.policy, **self.kwargs)
        except TypeError as e:
            raise ValueError(
                f"kwargs {self.kwargs!r} do not construct policy "
                f"{self.policy!r}: {e}") from e


@dataclass(frozen=True)
class Scenario:
    """One named, self-contained experiment description."""
    name: str
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    network: NetworkSpec = field(default_factory=NetworkSpec)
    deployment: DeploymentSpec = field(default_factory=DeploymentSpec)
    policy: PolicySpec = field(default_factory=PolicySpec)
    seed: int = 0

    def __post_init__(self):
        _require(bool(self.name), "Scenario needs a non-empty name")
        asc = self.deployment.autoscaler
        if asc is not None:
            if asc.control_interval_ms == 0.0:
                _require(self.workload.epochs > 1,
                         "an epoch-boundary autoscaler needs "
                         "workload.epochs > 1 (it acts between epochs; "
                         "set control_interval_ms > 0 for a mid-run "
                         "controller)")
            else:
                # Mid-run provisioning creates shared replicas (they
                # serve the whole zoo); a per_model pool would change
                # topology semantics mid-run.
                _require(self.deployment.topology == "shared",
                         "a mid-run controller "
                         "(control_interval_ms > 0) needs the shared "
                         "topology (provisioned replicas serve every "
                         "model)")
        if self.deployment.faults or self.deployment.drifts:
            # Fault times reference one engine timeline; multi-epoch
            # runs re-zero time per epoch, which would replay every
            # fault each epoch.
            _require(self.workload.epochs == 1,
                     "fault/drift injection needs workload.epochs == 1 "
                     "(fault times reference the single-run timeline)")
        if self.policy.premodel != "none":
            _require(bool(self.workload.input_classes),
                     "a premodel classifier needs workload.input_classes "
                     "(it has nothing to classify otherwise)")
        fl = self.deployment.fleet
        if fl is not None and fl.n_cells > 1:
            # The fleet engine owns the clock (FleetSpec.epoch_ms) and
            # synthesizes per-cell arrivals, so the workload must be a
            # generative open-loop shape with a single logical epoch.
            _require(self.workload.epochs == 1,
                     "a multi-cell fleet needs workload.epochs == 1 "
                     "(FleetSpec.epoch_ms is the rebalancing clock)")
            _require(self.workload.arrival in ("poisson", "diurnal"),
                     "a multi-cell fleet needs poisson or diurnal "
                     f"arrivals, got {self.workload.arrival!r}")
            _require(self.deployment.autoscaler is None,
                     "fleet + autoscaler is not supported (cells have "
                     "fixed replica topologies); run one elastic "
                     "scenario per cell instead — a shared-topology "
                     "Scenario with autoscaler.control_interval_ms > 0 "
                     "gives each cell its own mid-run controller")
            _require(not self.deployment.faults
                     and not self.deployment.drifts,
                     "fleet + fault/drift injection is not supported")
            _require(not self.workload.classes,
                     "fleet + per-class SLA mixes is not supported yet")
            _require(not self.workload.input_classes,
                     "fleet + input-class mixes is not supported yet")
            _require(self.policy.latency_quantile is None,
                     "fleet + quantile budgets is not supported yet")

    # -- serialization -------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Plain-data form: nested dicts/lists of JSON/TOML scalars."""
        return _plain(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Scenario":
        """Inverse of :meth:`to_dict`:
        ``Scenario.from_dict(s.to_dict()) == s``."""
        d = dict(d)
        unknown = set(d) - {"name", "workload", "network", "deployment",
                            "policy", "seed"}
        _require(not unknown,
                 f"unknown scenario keys: {sorted(unknown)} (a typo'd "
                 "section would otherwise be silently dropped)")
        wl = dict(d.get("workload", {}))
        if "classes" in wl:
            wl["classes"] = tuple(SlaClass(**c) for c in wl["classes"])
        if "input_classes" in wl:
            wl["input_classes"] = tuple(
                InputClassSpec(**{**c, "feature_center":
                                  tuple(c.get("feature_center", ()))})
                for c in wl["input_classes"])
        _tupled(wl, "rate_schedule", "times_ms")
        dep = dict(d.get("deployment", {}))
        if dep.get("autoscaler") is not None:
            dep["autoscaler"] = AutoscalerSpec(**dep["autoscaler"])
        if "faults" in dep:
            dep["faults"] = tuple(FaultSpec(**f) for f in dep["faults"])
        if "drifts" in dep:
            dep["drifts"] = tuple(DriftSpec(**s) for s in dep["drifts"])
        if dep.get("retry") is not None:
            dep["retry"] = RetrySpec(**dep["retry"])
        if dep.get("fleet") is not None:
            from repro.fleet.spec import FleetSpec
            dep["fleet"] = FleetSpec.from_dict(dep["fleet"])
        _tupled(dep, "subset", "speeds")
        return cls(
            name=d["name"],
            workload=WorkloadSpec(**wl),
            network=NetworkSpec(**d.get("network", {})),
            deployment=DeploymentSpec(**dep),
            policy=PolicySpec(**d.get("policy", {})),
            seed=int(d.get("seed", 0)))

    @classmethod
    def from_file(cls, path) -> "Scenario":
        """Load a scenario from a ``.toml`` or ``.json`` config file
        (anything else is parsed as JSON).  Fault/drift/retry specs
        round-trip like every other field."""
        p = str(path)
        if p.endswith(".toml"):
            try:
                import tomllib          # 3.11+
            except ImportError:         # pragma: no cover - env-dependent
                import tomli as tomllib
            with open(p, "rb") as f:
                return cls.from_dict(tomllib.load(f))
        import json
        with open(p, "r", encoding="utf-8") as f:
            return cls.from_dict(json.load(f))

    # -- compilation ---------------------------------------------------
    def build(self):
        """Compile into a runnable :class:`repro.scenario.build.ScenarioHarness`."""
        from repro.scenario.build import build
        return build(self)


def _plain(x: Any) -> Any:
    """asdict leaves tuples as tuples; JSON/TOML want lists."""
    if isinstance(x, dict):
        return {k: _plain(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_plain(v) for v in x]
    return x


def _tupled(d: Dict[str, Any], *keys: str) -> None:
    for k in keys:
        if k in d:
            d[k] = tuple(d[k])
