"""Compile a :class:`~repro.scenario.spec.Scenario` into runnable
harnesses over the three entry points.

``build(scenario)`` returns a :class:`ScenarioHarness`; the factory
functions it rides (``build_engine`` / ``build_closed_loop`` /
``build_executor``) are also what the entry points' ``from_scenario``
adapters delegate to, so the declarative spec and the historical kwargs
construct *identical* objects — a steady/Poisson scenario matching the
seeded engine goldens reproduces them bit-identically.

``ScenarioHarness.run()`` executes the whole scenario: single-epoch
scenarios are one engine run; multi-epoch scenarios carry the profile
store across epochs, slice the workload (rate schedule, or an even split
of a synthesized trace), and — when the deployment declares an
:class:`~repro.scenario.spec.AutoscalerSpec` — let the
:class:`~repro.scenario.autoscale.QueueTargetAutoscaler` resize the
replica pool between epochs from the previous epoch's ``Router.stats()``
window.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.core.policy import Policy, make_policy
from repro.core.profiles import ModelProfile
from repro.core.zoo import PROTOTYPE_POOL, TABLE2, ZooEntry, make_store
from repro.router.admission import AdmissionController, make_admission
from repro.router.retry import RetryPolicy
from repro.scenario.autoscale import QueueTargetAutoscaler
from repro.scenario.spec import Scenario
from repro.sim.faults import (FaultEvent, LatencyDrift, NetworkDrift,
                              ReplicaFault)
from repro.sim.arrivals import (ArrivalProcess, ClosedLoopArrivals,
                                PoissonArrivals, TraceArrivals, burst_trace,
                                diurnal_trace)
from repro.sim.replica import (ReplicaPool, per_model_replicas,
                               shared_replicas)

# Class labels are assigned from a seed stream decoupled from the
# engine's, so a labelled run replays the same service/network draws.
_CLASS_SEED_SALT = 0x5C3
# Likewise for synthesized arrival traces: an unsalted seed would feed
# the thinning sampler and the engine the *same* PCG64 stream,
# correlating inter-arrival gaps with network/service noise.
_TRACE_SEED_SALT = 0xA221
# And for input-class/feature assignment (the premodel workload mix):
# its own stream keeps classed runs replaying the same arrival,
# network and service draws as their unclassed twins.
_INPUT_SEED_SALT = 0x1C7F


# ----------------------------------------------------------------------
# leaf factories
# ----------------------------------------------------------------------

def build_entries(scenario: Scenario) -> List[ZooEntry]:
    dep = scenario.deployment
    zoo = list(TABLE2 if dep.zoo == "table2" else PROTOTYPE_POOL)
    if not dep.subset:
        return zoo
    by_name = {e.name: e for e in zoo}
    missing = [n for n in dep.subset if n not in by_name]
    if missing:
        raise ValueError(f"subset names {missing} not in the {dep.zoo} zoo "
                         f"(members: {sorted(by_name)})")
    return [by_name[n] for n in dep.subset]


def build_network(scenario: Scenario) -> NetworkModel:
    net = scenario.network
    return NetworkModel(net.mean_ms, net.std_ms, net.floor_ms)


def build_policy(scenario: Scenario) -> Policy:
    return make_policy(scenario.policy.policy, **scenario.policy.kwargs)


def build_admission(scenario: Scenario) -> Optional[AdmissionController]:
    dep = scenario.deployment
    if dep.admission == "none":
        return None             # Router defaults to AdmitAll
    return make_admission(dep.admission, **dep.admission_kwargs)


def build_replicas(scenario: Scenario,
                   n_replicas: Optional[int] = None) -> ReplicaPool:
    dep = scenario.deployment
    n = dep.replicas if n_replicas is None else n_replicas
    if dep.topology == "shared":
        # Explicit speeds only make sense at the declared count; an
        # autoscaler-resized pool falls back to homogeneous replicas.
        speeds = list(dep.speeds) if (dep.speeds and n == dep.replicas) \
            else None
        return shared_replicas(n, speeds=speeds,
                               max_queue_depth=dep.max_queue_depth)
    return per_model_replicas(build_entries(scenario),
                              replicas_per_model=n,
                              max_queue_depth=dep.max_queue_depth)


def build_faults(scenario: Scenario) -> List[FaultEvent]:
    """Compile the deployment's declarative fault/drift specs into the
    engine's ``sim.faults`` records, sorted by fire time."""
    dep = scenario.deployment
    out: List[FaultEvent] = []
    for f in dep.faults:
        out.append(ReplicaFault(at_ms=f.at_ms, kind=f.kind,
                                replica=f.replica, factor=f.factor))
    for s in dep.drifts:
        if s.kind == "latency":
            out.append(LatencyDrift(at_ms=s.at_ms, model=s.model,
                                    mu_mult=s.mu_mult,
                                    sigma_mult=s.sigma_mult))
        else:
            out.append(NetworkDrift(at_ms=s.at_ms, rtt_mult=s.rtt_mult))
    out.sort(key=lambda e: e.at_ms)
    return out


def build_retry(scenario: Scenario) -> Optional[RetryPolicy]:
    r = scenario.deployment.retry
    if r is None:
        return None
    return RetryPolicy(max_attempts=r.max_attempts,
                       reroute_on_overrun=r.reroute_on_overrun,
                       overrun_margin_ms=r.overrun_margin_ms)


def build_arrival_times(scenario: Scenario) -> Optional[np.ndarray]:
    """Full-run timestamps for trace-shaped workloads (trace / diurnal /
    burst); None for the generative processes (poisson / closed_loop)."""
    wl = scenario.workload
    if wl.arrival == "trace":
        return np.asarray(wl.times_ms, dtype=np.float64)
    if wl.arrival == "diurnal":
        return np.asarray(diurnal_trace(
            wl.n_requests, wl.rate_rps, period_ms=wl.period_ms,
            amplitude=wl.amplitude,
            seed=scenario.seed ^ _TRACE_SEED_SALT).times_ms)
    if wl.arrival == "burst":
        return np.asarray(burst_trace(
            wl.n_requests, wl.rate_rps, burst_rate_rps=wl.burst_rate_rps,
            burst_every_ms=wl.burst_every_ms, burst_len_ms=wl.burst_len_ms,
            seed=scenario.seed ^ _TRACE_SEED_SALT).times_ms)
    return None


# ----------------------------------------------------------------------
# entry-point adapters (the from_scenario implementations)
# ----------------------------------------------------------------------

def build_elastic(scenario: Scenario):
    """Compile the deployment's ``AutoscalerSpec`` into the engine's
    ``sim.elastic.ElasticConfig`` — None when there is no autoscaler or
    its ``control_interval_ms`` is 0 (the epoch-boundary degenerate
    path, which builds no engine-side controller at all and so keeps
    those goldens bit-identical)."""
    asc = scenario.deployment.autoscaler
    if asc is None or asc.control_interval_ms == 0.0:
        return None
    from repro.sim.elastic import ElasticConfig
    return ElasticConfig(
        kind=asc.kind, control_interval_ms=asc.control_interval_ms,
        cold_start_ms=asc.cold_start_ms,
        target_queue_ms=asc.target_queue_ms,
        max_shed_rate=asc.max_shed_rate,
        max_fallback_rate=asc.max_fallback_rate,
        min_replicas=asc.min_replicas, max_replicas=asc.max_replicas,
        step=asc.step, low_utilization=asc.low_utilization,
        cost_per_replica_s=asc.cost_per_replica_s)


def build_engine(scenario: Scenario, *, n_replicas: Optional[int] = None,
                 seed: Optional[int] = None):
    """Scenario -> ``sim.engine.ServingSimulator`` (any workload)."""
    from repro.sim.engine import ServingSimulator
    pol = scenario.policy
    dep = scenario.deployment
    return ServingSimulator(
        build_entries(scenario), build_network(scenario),
        build_replicas(scenario, n_replicas),
        seed=scenario.seed if seed is None else seed,
        alpha=pol.alpha, cold_age=pol.cold_age, cold_probe=pol.cold_probe,
        spike_prob=dep.spike_prob, spike_mult=dep.spike_mult,
        queue_aware=pol.queue_aware, admission=build_admission(scenario),
        batch_window_ms=dep.batch_window_ms, backend=pol.backend,
        faults=build_faults(scenario), retry=build_retry(scenario),
        elastic=build_elastic(scenario))


def build_closed_loop(scenario: Scenario):
    """Scenario -> ``core.simulate.Simulator`` (closed-loop workloads)."""
    from repro.core.simulate import Simulator
    if scenario.workload.arrival != "closed_loop":
        raise ValueError(
            "core.simulate.Simulator replays the paper's closed loop; "
            f"scenario {scenario.name!r} has "
            f"arrival={scenario.workload.arrival!r} — build the "
            "discrete-event engine for open-loop workloads")
    pol = scenario.policy
    dep = scenario.deployment
    return Simulator(
        entries=build_entries(scenario), network=build_network(scenario),
        seed=scenario.seed, alpha=pol.alpha, cold_age=pol.cold_age,
        cold_probe=pol.cold_probe, spike_prob=dep.spike_prob,
        spike_mult=dep.spike_mult, admission=build_admission(scenario))


def build_executor(scenario: Scenario, variants, **overrides):
    """Scenario -> ``serving.executor.PoolExecutor`` over a real pool."""
    from repro.serving.executor import PoolExecutor
    pol = scenario.policy
    kw = dict(seed=scenario.seed, alpha=pol.alpha,
              queue_aware=pol.queue_aware,
              admission=build_admission(scenario), backend=pol.backend)
    kw.update(overrides)
    return PoolExecutor(list(variants), build_network(scenario),
                        build_policy(scenario), **kw)


# ----------------------------------------------------------------------
# the runnable harness
# ----------------------------------------------------------------------

@dataclass
class EpochResult:
    """One epoch of a scenario run."""
    epoch: int
    n_replicas: int
    result: object               # sim.engine.LoadSimResult
    router_stats: dict


@dataclass
class ScenarioResult:
    """A full scenario run: per-epoch results plus pooled headlines."""
    scenario: Scenario
    epochs: List[EpochResult] = field(default_factory=list)
    # The full FleetResult when the scenario ran on the multi-cell
    # fleet engine (spill counters, per-cell slices); None for the
    # single-cell path.
    fleet: Optional[object] = None

    @property
    def result(self):
        """The last epoch's engine result (the whole run when
        single-epoch)."""
        return self.epochs[-1].result

    @property
    def replica_history(self) -> List[int]:
        return [e.n_replicas for e in self.epochs]

    @property
    def attainment_history(self) -> List[float]:
        return [e.result.sla_attainment for e in self.epochs]

    @property
    def sla_attainment(self) -> float:
        """Arrival-weighted attainment across epochs."""
        return self._pooled("sla_attainment", "n_arrived")

    # Latency/accuracy/queue statistics only cover completed requests,
    # so their run-level pooling weights by completions.
    @property
    def mean_latency(self) -> float:
        return self._pooled("mean_latency", "n_completed")

    @property
    def mean_accuracy(self) -> float:
        return self._pooled("mean_accuracy", "n_completed")

    @property
    def mean_queue_wait(self) -> float:
        return self._pooled("mean_queue_wait", "n_completed")

    def _pooled(self, attr: str, weight: str) -> float:
        n = sum(getattr(e.result, weight) for e in self.epochs)
        return sum(getattr(e.result, attr) * getattr(e.result, weight)
                   for e in self.epochs) / max(n, 1)


class ScenarioHarness:
    """A compiled scenario: entry-point factories plus ``run()``."""

    def __init__(self, scenario: Scenario):
        self.scenario = scenario
        self._class_names, self._class_slas, self._class_ids = \
            self._assign_classes()
        self._input_ids, self._input_feats, self._input_scales = \
            self._assign_input_classes()
        # Synthesized diurnal/burst traces are per-run constants; render
        # once here instead of re-thinning per epoch.
        self._times = build_arrival_times(scenario)

    # -- per-request SLA-class assignment ------------------------------
    def _assign_classes(self):
        wl = self.scenario.workload
        if not wl.classes:
            return (), np.empty(0), np.empty(0, dtype=np.int64)
        names = tuple(c.name for c in wl.classes)
        slas = np.array([c.t_sla_ms for c in wl.classes])
        w = np.array([c.weight for c in wl.classes])
        rng = np.random.default_rng(self.scenario.seed ^ _CLASS_SEED_SALT)
        ids = rng.choice(len(names), size=wl.n_requests, p=w / w.sum())
        return names, slas, ids

    def sla_for(self, offset: int = 0) -> Optional[Callable[[int], float]]:
        """Per-request SLA override from the class mix (None without
        one).  ``offset`` re-bases request ids for epoch slices."""
        if not self._class_names:
            return None
        return lambda rid: float(self._class_slas[
            self._class_ids[offset + rid]])

    def class_for(self, offset: int = 0) -> Optional[Callable[[int], str]]:
        if not self._class_names:
            return None
        return lambda rid: self._class_names[self._class_ids[offset + rid]]

    # -- per-request input-class assignment (the premodel workload) ----
    def _assign_input_classes(self):
        wl = self.scenario.workload
        if not wl.input_classes:
            return (np.empty(0, dtype=np.int64), np.empty((0, 0)),
                    np.empty(0))
        w = np.array([c.weight for c in wl.input_classes])
        centers = np.array([c.feature_center for c in wl.input_classes],
                           dtype=np.float64)
        noise = np.array([c.feature_noise for c in wl.input_classes])
        scales = np.array([c.latency_scale for c in wl.input_classes])
        rng = np.random.default_rng(self.scenario.seed ^ _INPUT_SEED_SALT)
        ids = rng.choice(len(w), size=wl.n_requests, p=w / w.sum())
        feats = centers[ids] + noise[ids, None] * rng.standard_normal(
            (wl.n_requests, centers.shape[1]))
        return ids, feats, scales[ids]

    def input_features(self, offset: int, n: int) -> Optional[np.ndarray]:
        """This epoch slice's (n, d) feature rows (None without an
        input-class mix)."""
        if not len(self._input_ids):
            return None
        return self._input_feats[offset:offset + n]

    def service_scales(self, offset: int, n: int) -> Optional[np.ndarray]:
        """This epoch slice's true per-request service-time multipliers
        (None without an input-class mix)."""
        if not len(self._input_ids):
            return None
        return self._input_scales[offset:offset + n]

    def premodel(self):
        """A fresh premodel classifier per run (None when the policy
        says "none").  Carried across epochs by ``run()`` like the
        profile store, so what it learned keeps paying off."""
        pol = self.scenario.policy
        wl = self.scenario.workload
        if pol.premodel == "none" or not wl.input_classes:
            return None
        from repro.premodel import make_classifier
        centers = tuple(c.feature_center for c in wl.input_classes)
        return make_classifier(pol.premodel, len(wl.input_classes),
                               len(centers[0]), centers=centers)

    # -- entry-point factories -----------------------------------------
    def engine(self, n_replicas: Optional[int] = None,
               seed: Optional[int] = None):
        return build_engine(self.scenario, n_replicas=n_replicas, seed=seed)

    def closed_loop(self):
        return build_closed_loop(self.scenario)

    def executor(self, variants, **overrides):
        return build_executor(self.scenario, variants, **overrides)

    def store(self):
        pol = self.scenario.policy
        wl = self.scenario.workload
        entries = build_entries(self.scenario)
        q = pol.latency_quantile
        if pol.premodel != "none" and wl.input_classes:
            from repro.premodel import ConditionalProfileStore
            store = ConditionalProfileStore(
                [ModelProfile(name=e.name, accuracy=e.top1 / 100.0)
                 for e in entries],
                n_classes=len(wl.input_classes), q=q,
                alpha=pol.alpha, cold_age=pol.cold_age)
        elif q is not None:
            from repro.premodel import QuantileProfileStore
            store = QuantileProfileStore(
                [ModelProfile(name=e.name, accuracy=e.top1 / 100.0)
                 for e in entries],
                q=q, alpha=pol.alpha, cold_age=pol.cold_age)
        else:
            return make_store(entries, alpha=pol.alpha,
                              cold_age=pol.cold_age, warm=pol.warm,
                              profile=pol.profile, window=pol.window,
                              stale_after=pol.stale_after,
                              explore_bonus=pol.explore_bonus)
        if pol.warm:
            # Same 1000-request warm-up as make_store: the trackers stay
            # cold, so quantile presentation starts at the Gaussian
            # μ + z_q·σ of the seeded truth and hands over to measured
            # quantiles as observations arrive.
            for e in entries:
                p = store[e.name]
                p.mu = e.mu_ms
                p.var = e.sigma_ms ** 2
                p.n_obs = 1000
            store.invalidate()
        return store

    # -- workload slicing ----------------------------------------------
    def epoch_sizes(self) -> List[int]:
        wl = self.scenario.workload
        base, extra = divmod(wl.n_requests, wl.epochs)
        return [base + (1 if e < extra else 0) for e in range(wl.epochs)]

    def arrivals(self, epoch: int = 0) -> ArrivalProcess:
        """The arrival process for one epoch (the whole run when
        single-epoch)."""
        wl = self.scenario.workload
        if wl.arrival == "closed_loop":
            return ClosedLoopArrivals(think_ms=wl.think_ms)
        if wl.arrival == "poisson":
            rate = (wl.rate_schedule[epoch] if wl.rate_schedule
                    else wl.rate_rps)
            return PoissonArrivals(rate)
        times = self._times
        sizes = self.epoch_sizes()
        lo = sum(sizes[:epoch])
        chunk = times[lo:lo + sizes[epoch]]
        # Each epoch replays its slice from t=0: epochs are consecutive
        # observation windows, not one shared timeline.
        return TraceArrivals(chunk - chunk[0])

    # -- execution -----------------------------------------------------
    def run(self) -> ScenarioResult:
        """Run the scenario end to end on the discrete-event engine."""
        sc = self.scenario
        wl = sc.workload
        fl = sc.deployment.fleet
        if fl is not None and (fl.n_cells > 1 or fl.trace_path):
            # Multi-cell (or trace-replaying) fleets run on the fleet
            # engine; a 1-cell generative fleet stays on this path —
            # that is the bit-identity guarantee the parity golden pins.
            from repro.fleet.engine import FleetEngine
            return FleetEngine(sc).run().as_scenario_result()
        policy = build_policy(sc)
        store = self.store()
        premodel = self.premodel()
        asc = sc.deployment.autoscaler
        # Epoch-boundary autoscaling only when there is no mid-run
        # controller: with control_interval_ms > 0 the engine's own
        # elastic tick owns the pool size, and the harness merely
        # carries the committed count into the next epoch's engine.
        mid_run = asc is not None and asc.control_interval_ms > 0.0
        scaler = (QueueTargetAutoscaler(asc)
                  if asc is not None and not mid_run else None)
        n_replicas = sc.deployment.replicas
        out = ScenarioResult(scenario=sc)
        offset = 0
        for epoch, n_epoch in enumerate(self.epoch_sizes()):
            # Epoch 0 runs at the scenario seed (bit-identical to the
            # equivalent single-epoch run); later epochs shift it so the
            # windows draw fresh network/service noise.
            eng = self.engine(n_replicas=n_replicas,
                              seed=sc.seed + epoch)
            res = eng.run(policy, wl.t_sla_ms, n_epoch,
                          arrivals=self.arrivals(epoch),
                          warm=sc.policy.warm, store=store,
                          sla_for=self.sla_for(offset),
                          class_for=self.class_for(offset),
                          feature_for=self.input_features(offset, n_epoch),
                          premodel=premodel,
                          service_scale_for=self.service_scales(
                              offset, n_epoch))
            stats = eng.router.stats()
            out.epochs.append(EpochResult(epoch=epoch, n_replicas=n_replicas,
                                          result=res, router_stats=stats))
            if scaler is not None:
                n_replicas = scaler.decide(n_replicas, stats, res)
            elif mid_run:
                n_replicas = min(max(eng.committed_replica_count(),
                                     asc.min_replicas), asc.max_replicas)
            offset += n_epoch
        return out


def build(scenario: Scenario) -> ScenarioHarness:
    """Compile a scenario; ``Scenario.build()`` delegates here."""
    return ScenarioHarness(scenario)
