"""Unified Router API: one request/decision surface over every serving
substrate (closed-loop simulator, discrete-event engine, live executor).

The package owns ModiPick's runtime decision end to end:

- ``api``: the canonical :class:`InferenceRequest` /
  :class:`RouterDecision` schema (per-request SLAs are first-class);
- ``admission``: pluggable SLA-aware admission control
  (:class:`SlaAwareAdmission` sheds requests no pool member can serve
  inside the remaining budget);
- ``queueaware``: the shifted-μ store view that folds ``W_queue(m)``
  into Eq. 1 budgets without touching any policy;
- ``router``: the :class:`Router` object — batched, admission-gated,
  substrate-independent selection riding ``policy_vec.select_batch``.
"""
from repro.router.admission import (AdmissionController, AdmitAll,
                                    ClassAwareAdmission, ClassPolicy,
                                    DepthCapAdmission, SlaAwareAdmission,
                                    make_admission)
from repro.router.api import (BudgetBreakdown, InferenceRequest,
                              RouterDecision)
from repro.router.queueaware import (QueueAwareSelector, queue_aware_budget,
                                     shifted_store)
from repro.router.router import Router

__all__ = [
    "AdmissionController", "AdmitAll", "ClassAwareAdmission", "ClassPolicy",
    "DepthCapAdmission", "SlaAwareAdmission", "make_admission",
    "BudgetBreakdown",
    "InferenceRequest", "RouterDecision", "QueueAwareSelector",
    "queue_aware_budget", "shifted_store", "Router",
]
