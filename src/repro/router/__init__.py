"""Unified Router API: one request/decision surface over every serving
substrate (closed-loop simulator, discrete-event engine, live executor).

The package owns ModiPick's runtime decision end to end:

- ``api``: the canonical :class:`InferenceRequest` /
  :class:`RouterDecision` schema (per-request SLAs are first-class);
- ``admission``: pluggable SLA-aware admission control
  (:class:`SlaAwareAdmission` sheds requests no pool member can serve
  inside the remaining budget);
- ``queueaware``: the shifted-μ store view that folds ``W_queue(m)``
  into Eq. 1 budgets without touching any policy;
- ``charging``: the :class:`ChargedWaits` intra-batch ledger — the
  per-replica wait state the router charges each admitted pick into so
  a burst is not judged against one stale snapshot;
- ``router``: the :class:`Router` object — batched, admission-gated,
  substrate-independent selection with the array-native
  ``route_batch_arrays`` hot path (:class:`BatchDecisions` columns out).
"""
from repro.router.admission import (AdmissionController, AdmitAll,
                                    ClassAwareAdmission, ClassPolicy,
                                    DepthCapAdmission, SlaAwareAdmission,
                                    make_admission)
from repro.router.api import (BatchDecisions, BudgetBreakdown,
                              InferenceRequest, RouterDecision)
from repro.router.charging import ChargedWaits
from repro.router.queueaware import (QueueAwareSelector, queue_aware_budget,
                                     shifted_store)
from repro.router.retry import RetryPolicy, cheapest_viable
from repro.router.router import Router

__all__ = [
    "AdmissionController", "AdmitAll", "ClassAwareAdmission", "ClassPolicy",
    "DepthCapAdmission", "SlaAwareAdmission", "make_admission",
    "BatchDecisions", "BudgetBreakdown", "ChargedWaits",
    "InferenceRequest", "RouterDecision", "QueueAwareSelector",
    "queue_aware_budget", "shifted_store", "Router",
    "RetryPolicy", "cheapest_viable",
]
