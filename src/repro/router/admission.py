"""Admission control: decide *whether* to serve before deciding *what*
serves it.

The discrete-event engine historically only had a substrate-level knob —
``Replica.max_queue_depth`` sheds a request after selection, once its
replica's FIFO is full.  Router-side admission runs *before* selection,
against the same telemetry the policy sees, so a request that cannot
possibly meet its SLA is rejected without spending a selection (or a
replica slot) on it:

- :class:`AdmitAll` — the default; every request proceeds to selection
  (substrate caps, if any, still apply downstream).  With this
  controller the router is behaviourally identical to the pre-router
  call sites.
- :class:`DepthCapAdmission` — router-side mirror of the hard cap:
  reject when every model's least-loaded serving queue is at depth.
- :class:`SlaAwareAdmission` — the ROADMAP item: reject when
  ``W_queue(m)`` already exceeds the remaining budget
  ``T_sla − 2·T_input`` for *every* model, i.e. no pool member can
  start serving inside the SLA no matter what the policy picks.
  ``include_service_time=True`` additionally charges each model's mean
  inference time μ(m), shedding requests that could *start* but not
  *finish* in time.

Controllers return ``(admitted, reason)``; the reason string lands in
``RouterDecision.reject_reason`` and, from there, in shed-vs-degrade
frontier reports.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

from repro.core.profiles import ProfileTable

from repro.router.api import InferenceRequest
from repro.router.queueaware import WQueueFn

DepthFn = Callable[[str], int]


class AdmissionController:
    """Base controller: admit everything."""
    name = "admit_all"
    # Routers snapshot W_queue telemetry once per batch only when either
    # queue-aware selection or the controller actually consumes it.
    needs_w_queue = False

    def admit(self, request: InferenceRequest, t_budget_ms: float,
              table: ProfileTable, w_queue_fn: Optional[WQueueFn] = None,
              depth_fn: Optional[DepthFn] = None) -> Tuple[bool, str]:
        return True, ""


class AdmitAll(AdmissionController):
    """Explicit alias for the default behaviour."""


@dataclass
class DepthCapAdmission(AdmissionController):
    """Reject when the least-loaded serving queue of every model is at
    ``max_depth`` — router-side back-pressure applied before selection.

    Depth telemetry is a per-``route_batch`` snapshot: requests admitted
    earlier in the same batch are not yet queued when later ones are
    judged, so a simultaneous burst can sail past the cap wholesale.
    This controller is advisory load-shedding, not a hard bound — pair
    it with ``Replica.max_queue_depth`` (enforced per request at
    placement time) when the cap must hold exactly."""
    max_depth: int

    name = "depth_cap"

    def admit(self, request, t_budget_ms, table, w_queue_fn=None,
              depth_fn=None) -> Tuple[bool, str]:
        if depth_fn is None:
            return True, ""
        if any(depth_fn(n) < self.max_depth for n in table.names):
            return True, ""
        return False, f"every serving queue at depth >= {self.max_depth}"


@dataclass
class SlaAwareAdmission(AdmissionController):
    """Reject when no model can meet the request's remaining budget.

    A model ``m`` is viable when ``W_queue(m) + slack < T_budget``
    (plus ``μ(m)`` when ``include_service_time``).  A request whose
    budget is already non-positive — the network alone ate the SLA — is
    always shed: every ``W_queue ≥ 0`` exceeds it.
    """
    slack_ms: float = 0.0
    include_service_time: bool = False

    name = "sla_aware"
    needs_w_queue = True

    def admit(self, request, t_budget_ms, table, w_queue_fn=None,
              depth_fn=None) -> Tuple[bool, str]:
        if w_queue_fn is None:
            return True, ""      # no telemetry: nothing to shed against
        for i, name in enumerate(table.names):
            cost = float(w_queue_fn(name)) + self.slack_ms
            if self.include_service_time:
                cost += float(table.mu[i])
            if cost < t_budget_ms:
                return True, ""
        return False, "W_queue exceeds the remaining budget for every model"


_MODES = {
    "none": AdmitAll,
    "admit_all": AdmitAll,
    "sla_aware": SlaAwareAdmission,
}


def make_admission(mode: str, **kwargs) -> AdmissionController:
    """Build a controller from a mode string (``none`` / ``admit_all`` /
    ``depth_cap`` / ``sla_aware``) — the benchmark/CLI axis."""
    if mode == "depth_cap":
        return DepthCapAdmission(**kwargs)
    try:
        return _MODES[mode](**kwargs)
    except KeyError:
        raise ValueError(f"unknown admission mode {mode!r} "
                         f"(valid: none, admit_all, depth_cap, sla_aware)")
