"""Admission control: decide *whether* to serve before deciding *what*
serves it.

The discrete-event engine historically only had a substrate-level knob —
``Replica.max_queue_depth`` sheds a request after selection, once its
replica's FIFO is full.  Router-side admission runs *before* selection,
against the same telemetry the policy sees, so a request that cannot
possibly meet its SLA is rejected without spending a selection (or a
replica slot) on it:

- :class:`AdmitAll` — the default; every request proceeds to selection
  (substrate caps, if any, still apply downstream).  With this
  controller the router is behaviourally identical to the pre-router
  call sites.
- :class:`DepthCapAdmission` — router-side mirror of the hard cap:
  reject when every model's least-loaded serving queue is at depth.
- :class:`SlaAwareAdmission` — the ROADMAP item: reject when
  ``W_queue(m)`` already exceeds the remaining budget
  ``T_sla − 2·T_input`` for *every* model, i.e. no pool member can
  start serving inside the SLA no matter what the policy picks.
  ``include_service_time=True`` additionally charges each model's mean
  inference time μ(m), shedding requests that could *start* but not
  *finish* in time.

Controllers return ``(admitted, reason)``; the reason string lands in
``RouterDecision.reject_reason`` and, from there, in shed-vs-degrade
frontier reports.

W_queue telemetry within a batch: under charged batch routing (the
``route_batch_arrays`` default) the ``w_queue_fn`` a controller sees for
request *i* reads the :class:`~repro.router.charging.ChargedWaits`
ledger *after* picks 0..i−1 of the same batch were charged — admission
judges the load the batch itself is creating, so shedding stays honest
under simultaneous bursts.  Under ``charge=False`` (and in the
historical object path) every request in the batch sees the same frozen
snapshot, which under-sheds exactly when shedding matters most.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Tuple, Union

from repro.core.profiles import ProfileTable

from repro.router.api import InferenceRequest
from repro.router.queueaware import WQueueFn

DepthFn = Callable[[str], int]


class AdmissionController:
    """Base controller: admit everything."""
    name = "admit_all"
    # Routers snapshot W_queue telemetry once per batch only when either
    # queue-aware selection or the controller actually consumes it.
    needs_w_queue = False

    def admit(self, request: InferenceRequest, t_budget_ms: float,
              table: ProfileTable, w_queue_fn: Optional[WQueueFn] = None,
              depth_fn: Optional[DepthFn] = None) -> Tuple[bool, str]:
        return True, ""

    def reset(self) -> None:
        """Clear any windowed state (share counters etc.).  Stateless
        controllers are no-ops; ``Router.reset()`` calls this so epoch
        windows start clean."""


class AdmitAll(AdmissionController):
    """Explicit alias for the default behaviour."""


@dataclass
class DepthCapAdmission(AdmissionController):
    """Reject when the least-loaded serving queue of every model is at
    ``max_depth`` — router-side back-pressure applied before selection.

    Depth telemetry is a per-``route_batch`` snapshot: requests admitted
    earlier in the same batch are not yet queued when later ones are
    judged, so a simultaneous burst can sail past the cap wholesale.
    This controller is advisory load-shedding, not a hard bound — pair
    it with ``Replica.max_queue_depth`` (enforced per request at
    placement time) when the cap must hold exactly."""
    max_depth: int

    name = "depth_cap"

    def admit(self, request, t_budget_ms, table, w_queue_fn=None,
              depth_fn=None) -> Tuple[bool, str]:
        if depth_fn is None:
            return True, ""
        if any(depth_fn(n) < self.max_depth for n in table.names):
            return True, ""
        return False, f"every serving queue at depth >= {self.max_depth}"


@dataclass
class SlaAwareAdmission(AdmissionController):
    """Reject when no model can meet the request's remaining budget.

    A model ``m`` is viable when ``W_queue(m) + slack < T_budget``
    (plus ``μ(m)`` when ``include_service_time``).  A request whose
    budget is already non-positive — the network alone ate the SLA — is
    always shed: every ``W_queue ≥ 0`` exceeds it.

    The charged ``lax.scan`` kernel
    (:func:`repro.kernels.policy_select.charged_select`) inlines this
    exact viability test against the in-scan charged waits, which is why
    the Router's scan fast path dispatches only for this controller (or
    :class:`AdmitAll`) — their verdicts are reproducible inside the
    kernel.
    """
    slack_ms: float = 0.0
    include_service_time: bool = False

    name = "sla_aware"
    needs_w_queue = True

    def admit(self, request, t_budget_ms, table, w_queue_fn=None,
              depth_fn=None) -> Tuple[bool, str]:
        if w_queue_fn is None:
            return True, ""      # no telemetry: nothing to shed against
        for i, name in enumerate(table.names):
            cost = float(w_queue_fn(name)) + self.slack_ms
            if self.include_service_time:
                cost += float(table.mu[i])
            if cost < t_budget_ms:
                return True, ""
        return False, "W_queue exceeds the remaining budget for every model"


@dataclass(frozen=True)
class ClassPolicy:
    """Per-SLA-class admission terms.

    ``protect`` scales how much of the remaining budget the class may
    spend queueing before it is shed: a model is viable for the class
    when ``W_queue(m) + slack < protect · T_budget``.  ``protect=1.0``
    is exactly :class:`SlaAwareAdmission` viability (shed only requests
    that cannot make the SLA at all); ``protect<1`` sheds the class
    pre-emptively once queues eat that fraction of its budget — weighted
    shedding that frees capacity for protected classes.

    ``max_share`` (optional) is an admitted-traffic quota: once queues
    are non-trivially backed up (``W_queue`` pressure), the class may
    not exceed this fraction of the controller's admissions in the
    current window.
    """
    protect: float = 1.0
    max_share: Optional[float] = None

    def __post_init__(self):
        if not 0.0 < self.protect <= 1.0:
            raise ValueError(f"protect must be in (0, 1], got {self.protect}")
        if self.max_share is not None and not 0.0 < self.max_share <= 1.0:
            raise ValueError(
                f"max_share must be in (0, 1], got {self.max_share}")


@dataclass
class ClassAwareAdmission(AdmissionController):
    """SLA-class-differentiated shedding: protect "interactive" by
    shedding "batch" first.

    ``InferenceRequest.sla_class`` picks the request's
    :class:`ClassPolicy` (``default`` for unknown/unset classes).  Two
    mechanisms compose, both judged against the same per-batch telemetry
    snapshot every other controller sees:

    - **weighted viability** — class ``c`` needs a model with
      ``W_queue(m) + slack < protect(c) · T_budget``, so low-``protect``
      classes shed earlier as queues build, leaving headroom for
      protected ones;
    - **admitted-share quota** — under pressure (minimum ``W_queue``
      above ``pressure_ms``), a class with ``max_share`` set may not
      exceed that fraction of this window's admissions.

    The share window is the controller's lifetime until ``reset()`` —
    autoscaler epochs (and ``Router.reset()``) clear it.
    """
    classes: Mapping[str, Union[ClassPolicy, Mapping]] = field(
        default_factory=dict)
    default: Union[ClassPolicy, Mapping] = field(default_factory=ClassPolicy)
    slack_ms: float = 0.0
    pressure_ms: float = 0.0

    name = "class_aware"
    needs_w_queue = True

    def __post_init__(self):
        coerce = lambda p: p if isinstance(p, ClassPolicy) else ClassPolicy(**p)
        self.classes = {c: coerce(p) for c, p in dict(self.classes).items()}
        self.default = coerce(self.default)
        self.reset()

    def reset(self) -> None:
        self.n_admitted = 0
        self.admitted_by_class: Dict[str, int] = {}

    def admit(self, request, t_budget_ms, table, w_queue_fn=None,
              depth_fn=None) -> Tuple[bool, str]:
        cls = request.sla_class or ""
        cp = self.classes.get(cls, self.default)
        if w_queue_fn is None:
            self._record(cls)
            return True, ""      # no telemetry: nothing to shed against
        waits = [float(w_queue_fn(n)) for n in table.names]
        if not any(w + self.slack_ms < cp.protect * t_budget_ms
                   for w in waits):
            return False, (f"W_queue exceeds {cp.protect:g}x the remaining "
                           f"budget for every model (class {cls or 'default'!r})")
        if cp.max_share is not None and min(waits) > self.pressure_ms \
                and self.n_admitted > 0:
            share = (self.admitted_by_class.get(cls, 0) + 1) \
                / (self.n_admitted + 1)
            if share > cp.max_share:
                return False, (f"class {cls or 'default'!r} over its "
                               f"{cp.max_share:g} admitted-share quota "
                               f"under queue pressure")
        self._record(cls)
        return True, ""

    def _record(self, cls: str) -> None:
        self.n_admitted += 1
        self.admitted_by_class[cls] = self.admitted_by_class.get(cls, 0) + 1


_MODES = {
    "none": AdmitAll,
    "admit_all": AdmitAll,
    "sla_aware": SlaAwareAdmission,
    "class_aware": ClassAwareAdmission,
}


def make_admission(mode: str, **kwargs) -> AdmissionController:
    """Build a controller from a mode string (``none`` / ``admit_all`` /
    ``depth_cap`` / ``sla_aware`` / ``class_aware``) — the benchmark,
    CLI and ``DeploymentSpec.admission`` axis."""
    if mode == "depth_cap":
        return DepthCapAdmission(**kwargs)
    try:
        return _MODES[mode](**kwargs)
    except KeyError:
        raise ValueError(
            f"unknown admission mode {mode!r} "
            f"(valid: none, admit_all, depth_cap, sla_aware, class_aware)")
