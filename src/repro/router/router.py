"""The unified Router: one request/decision surface for every substrate.

ModiPick's entire runtime contribution is a single decision — pick the
model that maximises accuracy within ``T_budget = T_sla − 2·T_input
(− W_queue)`` — and this object is that decision's only implementation.
The closed-loop paper simulator (``core.simulate``), the discrete-event
engine (``sim.engine``) and the live pool executor
(``serving.executor``) all route through a :class:`Router`; what differs
between them is purely the execution substrate around the decision.

Two entry surfaces share one implementation:

- :meth:`Router.route_batch_arrays` — the array-native hot path: budget
  / SLA-class / input-time *columns* in, a :class:`BatchDecisions`
  column set (picked model indices, admission verdicts, charged replica
  placements) out.  No per-request ``InferenceRequest`` /
  ``RouterDecision`` object is constructed.  This is what the
  discrete-event engine calls.
- :meth:`Router.route` / :meth:`Router.route_batch` — the object
  schema (``InferenceRequest`` → ``RouterDecision``) for callers that
  want the full budget breakdown and stage traces; a thin adapter over
  the array core.

Intra-batch load charging (the staleness fix)
---------------------------------------------
A batch routed against one frozen ``W_queue`` snapshot degenerates: all
B requests see the same idle-looking accurate models and pile onto
them.  When the caller hands over a :class:`ChargedWaits` state (the
engine builds one per batch from its replica pool), the batch is routed
*sequentially-greedily*: each admitted pick's mean service time μ is
charged to its chosen replica before the next request is judged, so
request ``i+1`` sees waits that include requests ``0..i`` — admission
verdicts and selection budgets both consult the charged waits, making
shedding honest under bursts.  The charged batch is pick-for-pick what
B sequential singleton ``route`` calls (the trusted scalar path) would
produce.  ``charge=False`` keeps the historical one-snapshot semantics
(the speculative-lookahead contract, and the ablation baseline).

Per batch, the router:

1. resolves the wait telemetry once — a live :class:`ChargedWaits`
   state, a frozen ``w_queue_map`` snapshot, a ``w_queue_fn`` estimator,
   or the store's own EWMA queue telemetry;
2. runs the pluggable :class:`AdmissionController` per request *before*
   selection — shed requests never spend a selection (nor a charge);
3. selects for the admitted requests: a singleton batch rides the scalar
   ``policy.select_traced``/``select_lean`` (draw-for-draw identical to
   the historical per-request call sites, which is what keeps seeded
   single-SLA goldens bit-identical); a charged batch rides the same
   scalar core sequentially (or the device-resident ``lax.scan`` pass in
   ``kernels.policy_select`` on the jax backend); an uncharged batch
   rides the vectorized ``policy_vec.select_batch_traced``.

Queue-aware mode presents the policy with the shifted-μ store view
(``router.queueaware.shifted_store``), exactly as the per-call-site
wrappers used to.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import policy_vec
from repro.core.policy import ModiPick, Policy, budget
from repro.core.profiles import ProfileStore

from repro.router.admission import (AdmissionController, AdmitAll, DepthFn,
                                    SlaAwareAdmission)
from repro.router.api import (BatchDecisions, BudgetBreakdown,
                              InferenceRequest, RouterDecision)
from repro.router.charging import ChargedWaits
from repro.router.queueaware import WQueueFn, shifted_store
from repro.router.retry import cheapest_viable


class Router:
    """Substrate-independent SLA-aware model router.

    Owns the :class:`ProfileStore` (profiles, queue telemetry, selection
    bookkeeping), a pluggable :class:`Policy` and a pluggable
    :class:`AdmissionController`.
    """

    def __init__(self, store: ProfileStore, policy: Policy, *,
                 admission: Optional[AdmissionController] = None,
                 queue_aware: bool = False,
                 backend: Optional[str] = None,
                 trace_detail: bool = True):
        self.store = store
        self.policy = policy
        self.admission = admission if admission is not None else AdmitAll()
        # Controllers that never overrode the base no-op verdict can be
        # skipped wholesale on the batch hot path (method identity, so
        # any subclass with a real ``admit`` is detected automatically).
        self._admits_all = (type(self.admission).admit
                            is AdmissionController.admit)
        self.queue_aware = queue_aware
        self.backend = backend
        # False: batched decisions carry chosen + fallback only (no
        # per-request eligible/probs tuples) — the event-loop hot-path
        # mode.  Singleton batches always return the full scalar trace.
        self.trace_detail = trace_detail
        base_name = getattr(policy, "name", str(policy))
        self.name = f"qa_{base_name}" if queue_aware else base_name
        # Router-side telemetry no pre-router entry point could express.
        self.n_routed = 0
        self.n_admitted = 0
        self.n_shed = 0
        self.n_fallback = 0
        self.n_batches = 0
        # Recovery path (router.retry): re-route requests and outcomes.
        self.n_retries = 0
        self.n_retry_routed = 0
        self.n_retry_exhausted = 0
        # window_stats() baseline: the lifetime counters at the last
        # window boundary (empty == window starts at construction).
        self._win_base: Dict[str, float] = {}

    # ------------------------------------------------------------------
    # object surface (adapters over the array core)
    # ------------------------------------------------------------------
    def route(self, request: InferenceRequest, rng: np.random.Generator, *,
              w_queue_fn: Optional[WQueueFn] = None,
              depth_fn: Optional[DepthFn] = None) -> RouterDecision:
        """Route one request (a batch of one: scalar selection path)."""
        return self.route_batch([request], rng, w_queue_fn=w_queue_fn,
                                depth_fn=depth_fn)[0]

    def route_batch(self, requests: Sequence[InferenceRequest],
                    rng: np.random.Generator, *,
                    w_queue_fn: Optional[WQueueFn] = None,
                    depth_fn: Optional[DepthFn] = None,
                    w_queue_map: Optional[Dict[str, float]] = None,
                    charge: bool = False
                    ) -> List[RouterDecision]:
        """Route a batch of requests; returns one decision per request.

        ``w_queue_fn`` maps a model name to its estimated queue wait
        (ms) *now*; when omitted in queue-aware mode the store's own
        EWMA queue telemetry is used.  ``w_queue_map`` hands over the
        whole snapshot at once — a complete name -> wait mapping of
        clamped non-negative floats.  By default all requests in the
        batch see the same snapshot (the historical speculative-lookahead
        contract); ``charge=True`` switches to intra-batch load charging
        — each admitted pick's μ is charged to its model's queue before
        the next request is judged (see :meth:`route_batch_arrays`, the
        array-native entry this adapter wraps).
        """
        reqs = list(requests)
        if not reqs:
            return []
        res = self.route_batch_arrays(
            [r.t_sla_ms for r in reqs], [r.t_input_ms for r in reqs], rng,
            w_queue_fn=w_queue_fn, w_queue_map=w_queue_map,
            depth_fn=depth_fn, charge=charge, _requests=reqs)
        decisions: List[RouterDecision] = []
        traces = res.traces or [None] * len(reqs)
        for i, req in enumerate(reqs):
            bd = BudgetBreakdown(t_sla_ms=req.t_sla_ms,
                                 t_network_ms=2.0 * req.t_input_ms,
                                 w_queue_ms=float(res.w_queue_ms[i]))
            if res.admitted[i]:
                decisions.append(RouterDecision(
                    request=req, variant=res.names[int(res.model_idx[i])],
                    admitted=True, budget=bd, trace=traces[i]))
            else:
                decisions.append(RouterDecision(
                    request=req, variant="", admitted=False,
                    reject_reason=res.reason_of(i), budget=bd))
        return decisions

    # ------------------------------------------------------------------
    # array-native core
    # ------------------------------------------------------------------
    def route_batch_arrays(self, t_sla_ms, t_input_ms,
                           rng: np.random.Generator, *,
                           sla_class: Optional[Sequence[Optional[str]]] = None,
                           charged: Optional[ChargedWaits] = None,
                           w_queue_map: Optional[Dict[str, float]] = None,
                           w_queue_fn: Optional[WQueueFn] = None,
                           depth_fn: Optional[DepthFn] = None,
                           charge: bool = True,
                           _requests: Optional[Sequence[InferenceRequest]]
                           = None) -> BatchDecisions:
        """Array-in/array-out routing: the hot-path entry point.

        ``t_sla_ms`` / ``t_input_ms``: (B,) per-request columns (the
        budget is ``T_sla − 2·T_input`` per row); ``sla_class``: optional
        (B,) label column consumed by class-aware admission.  Wait
        telemetry, in precedence order: ``charged`` (a live
        :class:`ChargedWaits` replica-column state — required for true
        per-replica charging and placement), ``w_queue_map`` (frozen
        name → wait snapshot), ``w_queue_fn``, the store's EWMA.

        With ``charge=True`` (default) and more than one request, the
        batch is routed sequentially-greedily against the charged waits;
        a snapshot-only wait source is promoted to model-granularity
        pseudo-replica charging.  A batch of one always rides the
        scalar path, bit-identical to :meth:`route` — charging cannot
        perturb it (there is nothing within the batch to charge
        against).

        Returns a :class:`BatchDecisions` column set.  No per-request
        objects are created unless a non-trivial admission controller
        needs a request record to judge (``_requests`` lets the object
        adapter pass the real ones through).
        """
        t_sla = np.asarray(t_sla_ms, dtype=np.float64)
        t_input = np.asarray(t_input_ms, dtype=np.float64)
        B = len(t_sla)
        tab = self.store.table()
        want_traces = _requests is not None
        res = BatchDecisions.empty(B, tab.names, traces=want_traces)
        if B == 0:
            return res

        # -- resolve the wait telemetry once per batch ------------------
        needs_waits = self.queue_aware or self.admission.needs_w_queue
        state: Optional[ChargedWaits] = None
        waits: Optional[Dict[str, float]] = None
        if needs_waits:
            if charged is not None:
                state = charged
            elif w_queue_map is not None:
                waits = w_queue_map
            else:
                # No injected snapshot: query per model, falling back to
                # the store's own EWMA queue telemetry (0 until the
                # first observation) absent an estimator.
                fn = w_queue_fn or self.store.queue_wait
                waits = {n: max(0.0, float(fn(n)))
                         for n in self.store.profiles}

        if B == 1:
            self._route_singleton(
                res, float(t_sla[0]), float(t_input[0]), rng, state, waits,
                depth_fn,
                _requests[0] if _requests is not None else None,
                sla_class[0] if sla_class is not None else None)
        elif charge and needs_waits:
            if state is None:
                # Snapshot-only telemetry: charge at model granularity
                # (each model its own queue — the per-model-endpoint
                # topology) so the fix does not require a replica pool.
                state = ChargedWaits.per_model(
                    tab.names, [waits[n] for n in tab.names], tab.mu)
            self._route_charged(res, t_sla, t_input, rng, state, depth_fn,
                                _requests, sla_class)
        else:
            self._route_snapshot(res, t_sla, t_input, rng,
                                 state.as_map() if state is not None
                                 else waits,
                                 depth_fn, _requests, sla_class)

        self.n_batches += 1
        self.n_routed += B
        n_admitted = int(res.admitted.sum())
        self.n_admitted += n_admitted
        self.n_shed += B - n_admitted
        return res

    # ------------------------------------------------------------------
    def _admission_request(self, requests, sla_class, i,
                           t_sla: float, t_input: float) -> InferenceRequest:
        if requests is not None:
            return requests[i]
        return InferenceRequest(
            t_sla_ms=t_sla, t_input_ms=t_input, rid=i,
            sla_class=sla_class[i] if sla_class is not None else None)

    def _shed(self, res: BatchDecisions, i: int, reason: str,
              w_min: float) -> None:
        try:
            code = res.reasons.index(reason)
        except ValueError:
            code = len(res.reasons)
            res.reasons.append(reason)
        res.reject_code[i] = code
        res.w_queue_ms[i] = w_min

    def _route_scalar(self, t_sla, t_input, rng, waits, depth_fn,
                      request, cls):
        """The scalar core — draw-for-draw identical to the historical
        per-request call sites (python-float budget math, one shifted
        view, ``select_traced``/``select_lean``).  Returns
        ``(mid, fallback, w_queue_ms, reason, trace)`` with ``mid == -1``
        (and the shed reason) when admission rejects."""
        b0 = budget(t_sla, t_input)
        w_fn = waits.__getitem__ if waits is not None else None
        if not self._admits_all:
            req = (request if request is not None else
                   self._admission_request(None, (cls,), 0, t_sla, t_input))
            ok, reason = self.admission.admit(req, b0, self.store.table(),
                                              w_fn, depth_fn)
            if not ok:
                return (-1, False,
                        min(waits.values()) if waits else 0.0, reason, None)
        # ``waits`` is already the clamped per-batch snapshot, so the
        # shifted view reuses it instead of re-querying.
        sel_store = (shifted_store(self.store, w_fn, shifts=waits)
                     if (self.queue_aware and w_fn is not None)
                     else self.store)
        select = (self.policy.select_traced if self.trace_detail
                  else self.policy.select_lean)
        trace = select(sel_store, b0, rng)
        self.store.mark_selected(trace.chosen)
        mid = self.store.table().index[trace.chosen]
        return (mid, trace.fallback,
                waits[trace.chosen] if waits else 0.0, None, trace)

    def route_one(self, t_sla_ms: float, t_input_ms: float,
                  rng: np.random.Generator, *,
                  w_queue_map: Optional[Dict[str, float]] = None,
                  w_queue_fn: Optional[WQueueFn] = None,
                  depth_fn: Optional[DepthFn] = None,
                  sla_class: Optional[str] = None):
        """Scalar fast path for hot event loops: one request in, a plain
        ``(model_idx, fallback, w_queue_ms, reject_reason)`` tuple out —
        no column set, no per-request objects.  ``model_idx == -1``
        means shed.  Same floats, same RNG draws as a batch of one
        through :meth:`route_batch_arrays` (which allocates a
        :class:`BatchDecisions` the caller of a singleton batch rarely
        wants — the engine's continuous-arrival runs are ~all singleton
        batches)."""
        waits = None
        if self.queue_aware or self.admission.needs_w_queue:
            if w_queue_map is not None:
                waits = w_queue_map
            else:
                fn = w_queue_fn or self.store.queue_wait
                waits = {n: max(0.0, float(fn(n)))
                         for n in self.store.profiles}
        mid, fb, w_q, reason, _ = self._route_scalar(
            float(t_sla_ms), float(t_input_ms), rng, waits, depth_fn,
            None, sla_class)
        self.n_batches += 1
        self.n_routed += 1
        if mid < 0:
            self.n_shed += 1
        else:
            self.n_admitted += 1
            if fb:
                self.n_fallback += 1
        return mid, fb, w_q, reason

    def _route_singleton(self, res, t_sla, t_input, rng, state, waits,
                         depth_fn, request, cls) -> None:
        """Batch-of-one adapter over :meth:`_route_scalar` writing into
        a :class:`BatchDecisions` column set."""
        if state is not None:
            waits = state.as_map()
        mid, fb, w_q, reason, trace = self._route_scalar(
            t_sla, t_input, rng, waits, depth_fn, request, cls)
        if mid < 0:
            self._shed(res, 0, reason, w_q)
            return
        res.model_idx[0] = mid
        res.admitted[0] = True
        res.fallback[0] = fb
        res.w_queue_ms[0] = w_q
        if fb:
            self.n_fallback += 1
        if res.traces is not None:
            res.traces[0] = trace

    def _route_snapshot(self, res, t_sla, t_input, rng, waits, depth_fn,
                        requests, sla_class) -> None:
        """The historical one-snapshot batch: every request judged and
        selected against the same waits (speculative-lookahead
        contract; the ``snapshot`` ablation arm)."""
        B = len(t_sla)
        budgets = t_sla - 2.0 * t_input
        tab = self.store.table()
        w_fn = waits.__getitem__ if waits is not None else None
        if self._admits_all:
            # The base no-op verdict: skip the per-request call.
            admitted = list(range(B))
        else:
            admitted = []
            w_min = min(waits.values()) if waits else 0.0
            for i in range(B):
                req = self._admission_request(requests, sla_class, i,
                                              float(t_sla[i]),
                                              float(t_input[i]))
                ok, reason = self.admission.admit(req, float(budgets[i]),
                                                  tab, w_fn, depth_fn)
                if ok:
                    admitted.append(i)
                else:
                    self._shed(res, i, reason, w_min)
        if not admitted:
            return
        # ``waits`` is already the clamped per-batch snapshot, so the
        # shifted view reuses it instead of re-querying.
        sel_store = (shifted_store(self.store, w_fn, shifts=waits)
                     if (self.queue_aware and w_fn is not None)
                     else self.store)
        if len(admitted) == 1:
            # Scalar path: draw-for-draw identical to a historical
            # per-request ``select_traced`` call site.  Without trace
            # detail the lean core skips the eligible/probs tuple
            # materialisation — same stages, same RNG stream.
            i = admitted[0]
            select = (self.policy.select_traced if self.trace_detail
                      else self.policy.select_lean)
            traces = [select(sel_store, float(budgets[i]), rng)]
        else:
            traces = policy_vec.select_batch_traced(
                self.policy, sel_store, budgets[admitted], rng,
                backend=self.backend, detail=self.trace_detail)
        for i, trace in zip(admitted, traces):
            self.store.mark_selected(trace.chosen)
            res.model_idx[i] = tab.index[trace.chosen]
            res.admitted[i] = True
            res.fallback[i] = trace.fallback
            res.w_queue_ms[i] = waits[trace.chosen] if waits else 0.0
            if trace.fallback:
                self.n_fallback += 1
            if res.traces is not None:
                res.traces[i] = trace

    def _route_charged(self, res, t_sla, t_input, rng, state: ChargedWaits,
                       depth_fn, requests, sla_class) -> None:
        """Sequential-greedy charged routing: request ``i`` is admitted
        and selected against waits that already include the charges of
        requests ``0..i-1`` — pick-for-pick what B sequential singleton
        ``route`` calls with live wait updates would produce."""
        B = len(t_sla)
        budgets = t_sla - 2.0 * t_input
        tab = self.store.table()
        if self._use_charged_scan(B):
            self._route_charged_jax(res, budgets, rng, state)
            return
        names = tab.names
        index = tab.index
        select = (self.policy.select_traced if self.trace_detail
                  else self.policy.select_lean)
        check_admission = not self._admits_all
        for i in range(B):
            wq = state.model_waits()
            # The live charged snapshot this request is judged against —
            # same keys, same clamped floats a singleton route would
            # build, but including every charge so far.
            waits = dict(zip(names, wq.tolist()))
            if check_admission:
                req = self._admission_request(requests, sla_class, i,
                                              float(t_sla[i]),
                                              float(t_input[i]))
                ok, reason = self.admission.admit(
                    req, float(budgets[i]), tab, waits.__getitem__,
                    depth_fn)
                if not ok:
                    self._shed(res, i, reason, float(wq.min()))
                    continue
            sel_store = (shifted_store(self.store, waits.__getitem__,
                                       shifts=waits)
                         if self.queue_aware else self.store)
            trace = select(sel_store, float(budgets[i]), rng)
            self.store.mark_selected(trace.chosen)
            mid = index[trace.chosen]
            res.model_idx[i] = mid
            res.admitted[i] = True
            res.fallback[i] = trace.fallback
            res.w_queue_ms[i] = float(wq[mid])
            if trace.fallback:
                self.n_fallback += 1
            if res.traces is not None:
                res.traces[i] = trace
            # Charge the pick before the next request is judged; the
            # returned replica is where a placement-consistent caller
            # should enqueue it.
            ridx = state.charge(mid)
            if not state.pseudo:
                res.replica_idx[i] = ridx

    # ------------------------------------------------------------------
    # premodel surface (class-conditional batch routing)
    # ------------------------------------------------------------------
    def route_batch_classed(self, t_sla_ms, t_input_ms, cls,
                            rng: np.random.Generator, *,
                            w_queue_map: Optional[Dict[str, float]] = None,
                            depth_fn: Optional[DepthFn] = None
                            ) -> BatchDecisions:
        """Array-native batch routing with per-request input-class ids.

        The store must be a ``premodel.conditional.
        ConditionalProfileStore``: each request is selected against its
        class's shrunk profile view.  With a ModiPick policy the whole
        batch is judged in ONE device call — the (K × npad) stacked
        class tables with per-request class rows gathered inside the
        fused jit (``kernels.policy_select.select_classed``); other
        policies ride the scalar core per request with the class cursor
        set.  Admission judges against the POOLED table (snapshot
        semantics — the premodel refines *selection*, not the
        shed-or-serve verdict), and queue-wait shifts apply uniformly to
        every class row (waits live at replicas, not input classes).
        """
        t_sla = np.asarray(t_sla_ms, dtype=np.float64)
        t_input = np.asarray(t_input_ms, dtype=np.float64)
        cls = np.asarray(cls, dtype=np.int32)
        B = len(t_sla)
        store = self.store
        pooled = store.pooled_table()
        res = BatchDecisions.empty(B, pooled.names)
        if B == 0:
            return res

        waits: Optional[Dict[str, float]] = None
        if self.queue_aware or self.admission.needs_w_queue:
            if w_queue_map is not None:
                waits = w_queue_map
            else:
                waits = {n: max(0.0, float(store.queue_wait(n)))
                         for n in store.profiles}
        w_fn = waits.__getitem__ if waits is not None else None

        budgets = t_sla - 2.0 * t_input
        if self._admits_all:
            admitted = list(range(B))
        else:
            admitted = []
            w_min = min(waits.values()) if waits else 0.0
            for i in range(B):
                req = self._admission_request(None, None, i,
                                              float(t_sla[i]),
                                              float(t_input[i]))
                ok, reason = self.admission.admit(req, float(budgets[i]),
                                                  pooled, w_fn, depth_fn)
                if ok:
                    admitted.append(i)
                else:
                    self._shed(res, i, reason, w_min)
        if admitted:
            if type(self.policy) is ModiPick:
                self._route_classed_jax(res, admitted, budgets, cls, rng,
                                        waits, pooled)
            else:
                self._route_classed_scalar(res, admitted, budgets, cls,
                                           rng, waits)
        self.n_batches += 1
        self.n_routed += B
        n_admitted = int(res.admitted.sum())
        self.n_admitted += n_admitted
        self.n_shed += B - n_admitted
        return res

    def _route_classed_jax(self, res, admitted, budgets, cls, rng, waits,
                           pooled) -> None:
        from repro.kernels import policy_select
        store = self.store
        names = pooled.names
        shifts = ([waits[n] for n in names]
                  if (self.queue_aware and waits is not None) else None)
        idx = np.asarray(admitted, dtype=np.int64)
        picks, has_base = policy_select.select_classed(
            store.stacked_pool(), cls[idx], budgets[idx],
            budgets[idx] - self.policy.t_threshold, shifts=shifts,
            gamma=self.policy.gamma,
            seed=int(rng.integers(np.iinfo(np.int64).max)))
        for j, i in enumerate(admitted):
            mid = int(picks[j])
            store.mark_selected(names[mid])
            res.model_idx[i] = mid
            res.admitted[i] = True
            res.fallback[i] = not has_base[j]
            res.w_queue_ms[i] = waits[names[mid]] if waits else 0.0
            if not has_base[j]:
                self.n_fallback += 1

    def _route_classed_scalar(self, res, admitted, budgets, cls, rng,
                              waits) -> None:
        """Per-request scalar fallback for non-ModiPick policies: the
        class cursor flips the store's presented table around the
        historical scalar core."""
        store = self.store
        w_fn = waits.__getitem__ if waits is not None else None
        select = (self.policy.select_traced if self.trace_detail
                  else self.policy.select_lean)
        for i in admitted:
            store.set_class(int(cls[i]))
            try:
                sel_store = (shifted_store(store, w_fn, shifts=waits)
                             if (self.queue_aware and w_fn is not None)
                             else store)
                trace = select(sel_store, float(budgets[i]), rng)
                mid = store.table().index[trace.chosen]
            finally:
                store.set_class(-1)
            store.mark_selected(trace.chosen)
            res.model_idx[i] = mid
            res.admitted[i] = True
            res.fallback[i] = trace.fallback
            res.w_queue_ms[i] = waits[trace.chosen] if waits else 0.0
            if trace.fallback:
                self.n_fallback += 1

    # -- device path ---------------------------------------------------
    def _use_charged_scan(self, B: int) -> bool:
        """The ``lax.scan`` charged pass engages under the same backend
        policy as the uncharged fused pipeline (ModiPick, large batch or
        an explicit jax backend), for controllers whose verdict is the
        pure viability test the kernel can evaluate in-scan."""
        if type(self.policy) is not ModiPick or not self.queue_aware \
                or self.trace_detail:
            return False
        if not (self._admits_all
                or type(self.admission) is SlaAwareAdmission):
            return False
        return policy_vec.resolve_backend(self.backend, B) == "jax"

    def _route_charged_jax(self, res, budgets, rng,
                           state: ChargedWaits) -> None:
        from repro.kernels import policy_select
        adm = self.admission
        if self._admits_all:
            adm_limit, slack, include_mu = None, 0.0, False
        else:
            adm_limit = budgets
            slack = adm.slack_ms
            include_mu = adm.include_service_time
        tab = self.store.table()
        out = policy_select.charged_select(
            tab.device_pool(), budgets,
            budgets - self.policy.t_threshold,
            state, gamma=self.policy.gamma,
            adm_limit=adm_limit, adm_slack=slack,
            adm_include_mu=include_mu,
            seed=int(rng.integers(np.iinfo(np.int64).max)))
        picks, admitted, has_base, replica, w_chosen = out
        names = tab.names
        for i in range(len(budgets)):
            if not admitted[i]:
                self._shed(res, i,
                           "W_queue exceeds the remaining budget for "
                           "every model", float(w_chosen[i]))
                continue
            mid = int(picks[i])
            self.store.mark_selected(names[mid])
            res.model_idx[i] = mid
            res.admitted[i] = True
            res.fallback[i] = not has_base[i]
            res.w_queue_ms[i] = float(w_chosen[i])
            if not state.pseudo:
                res.replica_idx[i] = int(replica[i])
        self.n_fallback += int((res.admitted & res.fallback).sum())

    # ------------------------------------------------------------------
    # recovery surface (router.retry)
    # ------------------------------------------------------------------
    def reroute_one(self, remaining_budget_ms: float, *,
                    w_queue_map: Optional[Dict[str, float]] = None) -> int:
        """Recovery pick for one in-flight request: the cheapest
        still-viable model (smallest believed ``W_queue + μ`` fitting
        the *remaining* budget — see ``router.retry.cheapest_viable``).
        Returns the model index, or −1 when nothing fits (the request
        is dropped as a deadline miss).  Deterministic and draw-free:
        retries never perturb the seeded primary-selection stream."""
        self.n_retries += 1
        mid = cheapest_viable(self.store.table(), w_queue_map,
                              float(remaining_budget_ms))
        if mid < 0:
            self.n_retry_exhausted += 1
            return -1
        self.n_retry_routed += 1
        self.store.mark_selected(self.store.table().names[mid])
        return mid

    def reroute(self, decision: RouterDecision,
                remaining_budget_ms: float, *,
                w_queue_map: Optional[Dict[str, float]] = None
                ) -> RouterDecision:
        """Object-path recovery: a new :class:`RouterDecision` with
        ``attempts`` bumped and the abandoned variant appended to
        ``fallback_chain``.  Not admitted (``variant == ""``) when no
        model fits the remaining budget."""
        chain = decision.fallback_chain + ((decision.variant,)
                                           if decision.variant else ())
        mid = self.reroute_one(remaining_budget_ms,
                               w_queue_map=w_queue_map)
        bd = BudgetBreakdown(
            t_sla_ms=decision.budget.t_sla_ms,
            t_network_ms=decision.budget.t_network_ms,
            w_queue_ms=(w_queue_map.get(
                self.store.table().names[mid], 0.0)
                if (mid >= 0 and w_queue_map is not None) else 0.0))
        if mid < 0:
            return RouterDecision(
                request=decision.request, variant="", admitted=False,
                budget=bd, reject_reason="no viable model within the "
                "remaining budget", attempts=decision.attempts + 1,
                fallback_chain=chain)
        return RouterDecision(
            request=decision.request,
            variant=self.store.table().names[mid], admitted=True,
            budget=bd, attempts=decision.attempts + 1,
            fallback_chain=chain)

    # ------------------------------------------------------------------
    def observe(self, name: str, latency_ms: float) -> None:
        """Feed a measured inference latency back into the profiles."""
        self.store.observe(name, latency_ms)

    def observe_queue(self, name: str, wait_ms: float) -> None:
        """Feed an observed queue wait back into the profiles."""
        self.store.observe_queue(name, wait_ms)

    def reset(self) -> None:
        """Zero the ``stats()`` counters (and the admission controller's
        windowed state, e.g. class-share quotas).

        Counters are lifetime by default; a closed-loop consumer that
        needs *windowed* rates — the queue-target autoscaler reading
        shed/fallback rates per epoch — calls ``reset()`` at each window
        boundary so ``stats()`` reflects only the traffic since."""
        self.n_routed = 0
        self.n_admitted = 0
        self.n_shed = 0
        self.n_fallback = 0
        self.n_batches = 0
        self.n_retries = 0
        self.n_retry_routed = 0
        self.n_retry_exhausted = 0
        self._win_base = {}
        self.admission.reset()

    def window_stats(self) -> Dict[str, float]:
        """One control window's counter deltas: ``stats()`` since the
        previous ``window_stats()`` call (or construction/``reset()``),
        WITHOUT zeroing the lifetime counters — the mid-run elastic
        controller reads per-tick rates while epoch-level consumers keep
        seeing their lifetime totals.  ``mean_batch`` is recomputed from
        the window's own deltas."""
        cur = self.stats()
        base = self._win_base
        out = {k: cur[k] - base.get(k, 0.0) for k in cur
               if k != "mean_batch"}
        out["mean_batch"] = (out["n_routed"] / out["n_batches"]
                             if out["n_batches"] else 0.0)
        self._win_base = cur
        return out

    def stats(self) -> Dict[str, float]:
        """Router-side counters: routed/admitted/shed/fallback/batches
        plus the mean routed batch size.  Lifetime totals since
        construction or the last ``reset()`` — see ``reset()`` for
        windowed consumption."""
        return {
            "n_routed": self.n_routed,
            "n_admitted": self.n_admitted,
            "n_shed": self.n_shed,
            "n_fallback": self.n_fallback,
            "n_batches": self.n_batches,
            "n_retries": self.n_retries,
            "n_retry_routed": self.n_retry_routed,
            "n_retry_exhausted": self.n_retry_exhausted,
            "mean_batch": (self.n_routed / self.n_batches
                           if self.n_batches else 0.0),
        }
