"""The unified Router: one request/decision surface for every substrate.

ModiPick's entire runtime contribution is a single decision — pick the
model that maximises accuracy within ``T_budget = T_sla − 2·T_input
(− W_queue)`` — and this object is that decision's only implementation.
The closed-loop paper simulator (``core.simulate``), the discrete-event
engine (``sim.engine``) and the live pool executor
(``serving.executor``) all construct a :class:`Router` and feed it
:class:`~repro.router.api.InferenceRequest` records; what differs
between them is purely the execution substrate around the returned
:class:`~repro.router.api.RouterDecision`.

Per batch, the router:

1. snapshots ``W_queue`` telemetry once (when queue-aware selection or
   the admission controller consumes it);
2. runs the pluggable :class:`AdmissionController` per request *before*
   selection — shed requests never spend a selection;
3. selects for the admitted requests: a singleton batch rides the scalar
   ``policy.select_traced`` (draw-for-draw identical to the historical
   per-request call sites, which is what keeps seeded single-SLA goldens
   bit-identical), larger batches ride the vectorized
   ``policy_vec.select_batch_traced`` — heterogeneous per-request SLAs
   are just another column of the batched budget vector.

Queue-aware mode presents the policy with the shifted-μ store view
(``router.queueaware.shifted_store``), exactly as the per-call-site
wrappers used to.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import policy_vec
from repro.core.policy import Policy, budget
from repro.core.profiles import ProfileStore

from repro.router.admission import AdmissionController, AdmitAll, DepthFn
from repro.router.api import BudgetBreakdown, InferenceRequest, RouterDecision
from repro.router.queueaware import WQueueFn, shifted_store


class Router:
    """Substrate-independent SLA-aware model router.

    Owns the :class:`ProfileStore` (profiles, queue telemetry, selection
    bookkeeping), a pluggable :class:`Policy` and a pluggable
    :class:`AdmissionController`.
    """

    def __init__(self, store: ProfileStore, policy: Policy, *,
                 admission: Optional[AdmissionController] = None,
                 queue_aware: bool = False,
                 backend: Optional[str] = None,
                 trace_detail: bool = True):
        self.store = store
        self.policy = policy
        self.admission = admission if admission is not None else AdmitAll()
        # Controllers that never overrode the base no-op verdict can be
        # skipped wholesale on the batch hot path (method identity, so
        # any subclass with a real ``admit`` is detected automatically).
        self._admits_all = (type(self.admission).admit
                            is AdmissionController.admit)
        self.queue_aware = queue_aware
        self.backend = backend
        # False: batched decisions carry chosen + fallback only (no
        # per-request eligible/probs tuples) — the event-loop hot-path
        # mode.  Singleton batches always return the full scalar trace.
        self.trace_detail = trace_detail
        base_name = getattr(policy, "name", str(policy))
        self.name = f"qa_{base_name}" if queue_aware else base_name
        # Router-side telemetry no pre-router entry point could express.
        self.n_routed = 0
        self.n_admitted = 0
        self.n_shed = 0
        self.n_fallback = 0
        self.n_batches = 0

    # ------------------------------------------------------------------
    def route(self, request: InferenceRequest, rng: np.random.Generator, *,
              w_queue_fn: Optional[WQueueFn] = None,
              depth_fn: Optional[DepthFn] = None) -> RouterDecision:
        """Route one request (a batch of one: scalar selection path)."""
        return self.route_batch([request], rng, w_queue_fn=w_queue_fn,
                                depth_fn=depth_fn)[0]

    def route_batch(self, requests: Sequence[InferenceRequest],
                    rng: np.random.Generator, *,
                    w_queue_fn: Optional[WQueueFn] = None,
                    depth_fn: Optional[DepthFn] = None,
                    w_queue_map: Optional[Dict[str, float]] = None
                    ) -> List[RouterDecision]:
        """Route a batch of requests against one telemetry snapshot.

        ``w_queue_fn`` maps a model name to its estimated queue wait
        (ms) *now*; when omitted in queue-aware mode the store's own
        EWMA queue telemetry is used.  ``w_queue_map`` hands over the
        whole snapshot at once — a complete name -> wait mapping of
        clamped non-negative floats (the engine computes each replica's
        wait exactly once per batch and passes it here, skipping the
        per-model query round).  All requests in the batch see the same
        snapshot — the engine's speculative-lookahead contract.
        """
        reqs = list(requests)
        if not reqs:
            return []
        if len(reqs) == 1:
            # Singleton hot path: one scalar budget, no array churn.
            budgets = (budget(reqs[0].t_sla_ms, reqs[0].t_input_ms),)
        else:
            budgets = np.array([budget(r.t_sla_ms, r.t_input_ms)
                                for r in reqs])

        needs_waits = self.queue_aware or self.admission.needs_w_queue
        waits: Optional[Dict[str, float]] = None
        if needs_waits:
            if w_queue_map is not None:
                waits = w_queue_map
            else:
                # No injected snapshot: query per model, falling back to
                # the store's own EWMA queue telemetry (0 until the
                # first observation) absent an estimator.
                fn = w_queue_fn or self.store.queue_wait
                waits = {n: max(0.0, float(fn(n)))
                         for n in self.store.profiles}
        w_fn = waits.__getitem__ if waits is not None else None

        tab = self.store.table()
        decisions: List[Optional[RouterDecision]] = [None] * len(reqs)
        if self._admits_all:
            # The base no-op verdict: skip the per-request call.
            admitted = list(range(len(reqs)))
        else:
            admitted = []
            for i, req in enumerate(reqs):
                ok, reason = self.admission.admit(req, float(budgets[i]),
                                                  tab, w_fn, depth_fn)
                if ok:
                    admitted.append(i)
                else:
                    decisions[i] = RouterDecision(
                        request=req, variant="", admitted=False,
                        reject_reason=reason,
                        budget=BudgetBreakdown(
                            t_sla_ms=req.t_sla_ms,
                            t_network_ms=2.0 * req.t_input_ms,
                            w_queue_ms=min(waits.values()) if waits else 0.0))

        if admitted:
            # ``waits`` is already the clamped per-batch snapshot, so
            # the shifted view reuses it instead of re-querying.
            sel_store = (shifted_store(self.store, w_fn, shifts=waits)
                         if (self.queue_aware and w_fn is not None)
                         else self.store)
            if len(admitted) == 1:
                # Scalar path: draw-for-draw identical to a historical
                # per-request ``select_traced`` call site.  Without
                # trace detail the lean core skips the eligible/probs
                # tuple materialisation — same stages, same RNG stream.
                i = admitted[0]
                select = (self.policy.select_traced if self.trace_detail
                          else self.policy.select_lean)
                traces = [select(sel_store, float(budgets[i]), rng)]
            else:
                traces = policy_vec.select_batch_traced(
                    self.policy, sel_store, budgets[admitted], rng,
                    backend=self.backend, detail=self.trace_detail)
            for i, trace in zip(admitted, traces):
                self.store.mark_selected(trace.chosen)
                req = reqs[i]
                decisions[i] = RouterDecision(
                    request=req, variant=trace.chosen, admitted=True,
                    budget=BudgetBreakdown(
                        t_sla_ms=req.t_sla_ms,
                        t_network_ms=2.0 * req.t_input_ms,
                        w_queue_ms=waits[trace.chosen] if waits else 0.0),
                    trace=trace)
                if trace.fallback:
                    self.n_fallback += 1

        self.n_batches += 1
        self.n_routed += len(reqs)
        self.n_admitted += len(admitted)
        self.n_shed += len(reqs) - len(admitted)
        return decisions

    # ------------------------------------------------------------------
    def observe(self, name: str, latency_ms: float) -> None:
        """Feed a measured inference latency back into the profiles."""
        self.store.observe(name, latency_ms)

    def observe_queue(self, name: str, wait_ms: float) -> None:
        """Feed an observed queue wait back into the profiles."""
        self.store.observe_queue(name, wait_ms)

    def reset(self) -> None:
        """Zero the ``stats()`` counters (and the admission controller's
        windowed state, e.g. class-share quotas).

        Counters are lifetime by default; a closed-loop consumer that
        needs *windowed* rates — the queue-target autoscaler reading
        shed/fallback rates per epoch — calls ``reset()`` at each window
        boundary so ``stats()`` reflects only the traffic since."""
        self.n_routed = 0
        self.n_admitted = 0
        self.n_shed = 0
        self.n_fallback = 0
        self.n_batches = 0
        self.admission.reset()

    def stats(self) -> Dict[str, float]:
        """Router-side counters: routed/admitted/shed/fallback/batches
        plus the mean routed batch size.  Lifetime totals since
        construction or the last ``reset()`` — see ``reset()`` for
        windowed consumption."""
        return {
            "n_routed": self.n_routed,
            "n_admitted": self.n_admitted,
            "n_shed": self.n_shed,
            "n_fallback": self.n_fallback,
            "n_batches": self.n_batches,
            "mean_batch": (self.n_routed / self.n_batches
                           if self.n_batches else 0.0),
        }
