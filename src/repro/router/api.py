"""Canonical request/decision schema for the unified Router API.

Every entry point (the closed-loop paper simulator, the discrete-event
engine, the live pool executor) expresses ModiPick's runtime decision
through the same two records:

- :class:`InferenceRequest` — what the device sends: arrival time, its
  *own* SLA (heterogeneous per-request SLAs are first-class, not a
  run-level constant), the measured/estimated uplink transfer, and an
  optional SLA class label for slicing results.
- :class:`RouterDecision` — what the router answers: the chosen variant,
  the full budget breakdown (Eq. 1 plus the queue-wait correction), the
  admission verdict, and the stage trace (base model, exploration set,
  probabilities) where the selection path produces one.

Times are milliseconds throughout, matching the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.core.policy import SelectionTrace


@dataclass(slots=True)
class InferenceRequest:
    """One inference request as the router sees it."""
    t_sla_ms: float                   # this request's SLA (end-to-end)
    t_input_ms: float                 # one-way input transfer (measured)
    rid: int = 0
    arrival_ms: float = 0.0
    sla_class: Optional[str] = None   # optional label, e.g. "interactive"


@dataclass(slots=True)
class BudgetBreakdown:
    """Where the SLA went: network, queueing, and what is left for
    inference.  ``t_budget_ms`` is Eq. 1 (``T_sla − 2·T_input``);
    ``t_effective_ms`` additionally charges the queue wait of the model
    the decision routed to (the queue-aware budget)."""
    t_sla_ms: float
    t_network_ms: float               # 2 · T_input (conservative, Eq. 1)
    w_queue_ms: float = 0.0           # W_queue of the chosen model

    @property
    def t_budget_ms(self) -> float:
        return self.t_sla_ms - self.t_network_ms

    @property
    def t_effective_ms(self) -> float:
        return self.t_budget_ms - self.w_queue_ms


@dataclass(slots=True)
class RouterDecision:
    """The router's answer for one request."""
    request: InferenceRequest
    variant: str                      # "" when the request was shed
    admitted: bool
    budget: BudgetBreakdown
    reject_reason: str = ""
    trace: Optional[SelectionTrace] = None

    @property
    def fallback(self) -> bool:
        return self.trace.fallback if self.trace is not None else False

    @property
    def base(self) -> Optional[str]:
        return self.trace.base if self.trace is not None else None

    @property
    def eligible(self) -> Tuple[str, ...]:
        return self.trace.eligible if self.trace is not None else ()

    @property
    def probs(self) -> Tuple[float, ...]:
        return self.trace.probs if self.trace is not None else ()
