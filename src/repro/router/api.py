"""Canonical request/decision schema for the unified Router API.

Every entry point (the closed-loop paper simulator, the discrete-event
engine, the live pool executor) expresses ModiPick's runtime decision
through the same two records:

- :class:`InferenceRequest` — what the device sends: arrival time, its
  *own* SLA (heterogeneous per-request SLAs are first-class, not a
  run-level constant), the measured/estimated uplink transfer, and an
  optional SLA class label for slicing results.
- :class:`RouterDecision` — what the router answers: the chosen variant,
  the full budget breakdown (Eq. 1 plus the queue-wait correction), the
  admission verdict, and the stage trace (base model, exploration set,
  probabilities) where the selection path produces one.

Times are milliseconds throughout, matching the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.core.policy import SelectionTrace


@dataclass(slots=True)
class InferenceRequest:
    """One inference request as the router sees it."""
    t_sla_ms: float                   # this request's SLA (end-to-end)
    t_input_ms: float                 # one-way input transfer (measured)
    rid: int = 0
    arrival_ms: float = 0.0
    sla_class: Optional[str] = None   # optional label, e.g. "interactive"
    # Cheap premodel features (input size, resolution bucket, ...): what
    # the premodel classifier maps to an input-class id.  Empty for
    # feature-less workloads — the historical path.
    features: Tuple[float, ...] = ()


@dataclass(slots=True)
class BudgetBreakdown:
    """Where the SLA went: network, cross-cell transit, queueing, and
    what is left for inference.  ``t_budget_ms`` is Eq. 1
    (``T_sla − 2·T_input``) minus any inter-cell RTT the fleet frontend
    spent spilling the request to a remote cell
    (``rtt_xcell_ms`` — 0 for home-cell service, so single-cell budgets
    are unchanged); ``t_effective_ms`` additionally charges the queue
    wait of the model the decision routed to (the queue-aware budget):
    ``T_sla − 2·T_input − RTT_xcell − W_queue(m)``."""
    t_sla_ms: float
    t_network_ms: float               # 2 · T_input (conservative, Eq. 1)
    w_queue_ms: float = 0.0           # W_queue of the chosen model
    rtt_xcell_ms: float = 0.0         # inter-cell spill RTT (fleet only)

    @property
    def t_budget_ms(self) -> float:
        return self.t_sla_ms - self.t_network_ms - self.rtt_xcell_ms

    @property
    def t_effective_ms(self) -> float:
        return self.t_budget_ms - self.w_queue_ms


@dataclass(slots=True)
class BatchDecisions:
    """Array-native answer of ``Router.route_batch_arrays``: one column
    per decision field, index-aligned with the input budget columns — no
    per-request object is materialised on the hot path.

    ``model_idx[i]`` is the chosen model's position in ``names`` (−1
    where the request was shed), ``replica_idx[i]`` the pool index of
    the replica the intra-batch charging placed the pick on (−1 when no
    replica topology was charged — snapshot mode, pseudo-replica
    charging, or a shed request), ``w_queue_ms[i]`` the chosen model's
    charged wait at decision time (for shed rows: the minimum wait over
    the pool, matching ``BudgetBreakdown``'s convention).
    ``reject_code[i]`` indexes ``reasons`` (code 0 == "" == admitted).
    ``traces`` is populated only for object-path consumers
    (``route_batch`` wraps them into :class:`RouterDecision`s); array
    consumers read the columns.
    """
    names: Tuple[str, ...]
    model_idx: np.ndarray            # (B,) int32; -1 = shed
    admitted: np.ndarray             # (B,) bool
    fallback: np.ndarray             # (B,) bool
    replica_idx: np.ndarray          # (B,) int32; -1 = caller places
    w_queue_ms: np.ndarray           # (B,) float64
    reject_code: np.ndarray          # (B,) int16 into reasons
    reasons: List[str]
    traces: Optional[List[Optional[SelectionTrace]]] = None

    @classmethod
    def empty(cls, n: int, names: Tuple[str, ...],
              traces: bool = False) -> "BatchDecisions":
        return cls(names=tuple(names),
                   model_idx=np.full(n, -1, dtype=np.int32),
                   admitted=np.zeros(n, dtype=bool),
                   fallback=np.zeros(n, dtype=bool),
                   replica_idx=np.full(n, -1, dtype=np.int32),
                   w_queue_ms=np.zeros(n, dtype=np.float64),
                   reject_code=np.zeros(n, dtype=np.int16),
                   reasons=[""],
                   traces=[None] * n if traces else None)

    def reason_of(self, i: int) -> str:
        return self.reasons[int(self.reject_code[i])]

    def __len__(self) -> int:
        return len(self.model_idx)


@dataclass(slots=True)
class RouterDecision:
    """The router's answer for one request.

    ``attempts`` counts placements including the first; a recovery
    re-route (replica failure or deadline-overrun hedge — see
    ``router.retry``) returns a new decision with ``attempts`` bumped
    and the abandoned variant appended to ``fallback_chain``."""
    request: InferenceRequest
    variant: str                      # "" when the request was shed
    admitted: bool
    budget: BudgetBreakdown
    reject_reason: str = ""
    trace: Optional[SelectionTrace] = None
    attempts: int = 1
    fallback_chain: Tuple[str, ...] = ()

    @property
    def fallback(self) -> bool:
        return self.trace.fallback if self.trace is not None else False

    @property
    def base(self) -> Optional[str]:
        return self.trace.base if self.trace is not None else None

    @property
    def eligible(self) -> Tuple[str, ...]:
        return self.trace.eligible if self.trace is not None else ()

    @property
    def probs(self) -> Tuple[float, ...]:
        return self.trace.probs if self.trace is not None else ()
