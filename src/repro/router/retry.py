"""Retry / hedged-fallback policy: what the router does when a placed
request loses its replica or its deadline headroom mid-flight.

The primary decision (``Router.route*``) optimises accuracy within the
full budget.  This module covers the *second* decision, made under
duress: a replica died with the request queued on it, or service is
about to start and the believed μ no longer fits what is left of the
SLA.  The recovery pick is deliberately different in character from the
primary one — no accuracy maximisation, no exploration, no RNG:
:func:`cheapest_viable` takes the model with the smallest believed
total latency (``W_queue + μ``) that still fits the *remaining* budget
(``T_sla − 2·T_input − elapsed``).  Deterministic and draw-free, so
retries never perturb the seeded selection stream of the surviving
traffic.

:class:`RetryPolicy` bounds the damage: ``max_attempts`` counts every
placement including the first (``max_attempts=1`` disables recovery
entirely), and ``reroute_on_overrun`` gates the deadline-overrun hedge
(checked when service is about to start) separately from the
failure-driven path (always eligible while attempts remain).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.profiles import ProfileTable


@dataclass(frozen=True)
class RetryPolicy:
    """Bounds and switches for the recovery path.

    ``max_attempts``: total placements per request including the first
    (so 2 = one retry).  ``reroute_on_overrun``: also hedge at
    service-start when the believed service time overruns the remaining
    budget (plus ``overrun_margin_ms`` of slack before the hedge
    triggers — 0 hedges on any predicted miss).
    """
    max_attempts: int = 2
    reroute_on_overrun: bool = True
    overrun_margin_ms: float = 0.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1 (it counts the "
                             "first placement)")
        if self.overrun_margin_ms < 0.0:
            raise ValueError("overrun_margin_ms must be non-negative")


def cheapest_viable(tab: ProfileTable,
                    waits: Optional[Dict[str, float]],
                    remaining_ms: float) -> int:
    """Index of the model with the smallest believed ``W_queue + μ``
    that fits ``remaining_ms``; −1 when none does (dead replicas
    surface ``inf`` waits, so a model with no live replica can never
    win).  First minimum wins ties — deterministic, no RNG."""
    best = -1
    best_cost = float("inf")
    for i, name in enumerate(tab.names):
        w = waits.get(name, 0.0) if waits is not None else 0.0
        cost = w + tab.mu[i]
        if cost < best_cost:
            best_cost = cost
            best = i
    if best < 0 or best_cost > remaining_ms:
        return -1
    return best
