"""Intra-batch load charging: the mutable wait state behind
``Router.route_batch_arrays``.

The staleness degeneracy this fixes: a burst of B simultaneous requests
judged against ONE ``W_queue`` snapshot all see the same (idle-looking)
accurate models, pile onto them, and attainment collapses — the
``batched`` rows of ``BENCH_engine_throughput.json`` sat at ~0.16 while
the singleton path held ~0.998.  ModiPick's queue-aware budget
``T_budget(m) = T_sla − 2·T_input − W_queue(m)`` only masks load if the
waits it reasons about include the requests routed *moments* earlier —
within the same batch, not just previous batches.

:class:`ChargedWaits` is that within-batch ledger: per-replica wait
columns plus the static model → candidate-replica topology.  After every
admitted pick the router charges the pick's mean service time μ(m) to
the replica that will serve it, so request ``i+1`` of the batch is
admitted and selected against waits that already include requests
``0..i`` — exactly what B sequential singleton routes (the trusted
scalar path) would have seen.  The charged batch is therefore
pick-for-pick the sequential oracle, at array-column cost.

Two constructors:

- :meth:`ChargedWaits.per_model` — one pseudo-replica per model, built
  from a name → wait snapshot.  The fallback when the caller only has
  model-level telemetry (e.g. the live executor's ``w_queue_fn``).
- the engine builds the real thing from its bound
  :class:`~repro.sim.replica.ReplicaPool` via
  ``ReplicaPool.charged_state(now)``: per-replica wait columns, cached
  candidate indices, speeds and the live μ list — the same floats its
  ``waits_by_name`` snapshot used to hand over as a frozen dict.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class ChargedWaits:
    """Per-replica wait columns + model topology, charged as a batch is
    routed.

    ``rep_wait[r]`` is replica ``r``'s estimated wait *now* (ms, ≥ 0);
    ``cand[m]`` the pool indices of the replicas that can serve model
    ``m`` (pool order — the historical ``min`` tie-break); ``speed[r]``
    replica ``r``'s speed factor; ``mu[m]`` the *current* profile mean
    used as the charge amount (a live list is fine — the engine shares
    its ``mu_now`` column).
    """

    __slots__ = ("rep_wait", "cand", "speed", "mu", "names", "pseudo")

    def __init__(self, rep_wait: Sequence[float],
                 cand: Sequence[Sequence[int]],
                 speed: Sequence[float],
                 mu: Sequence[float],
                 names: Sequence[str],
                 pseudo: bool = False):
        self.rep_wait = np.maximum(
            np.asarray(rep_wait, dtype=np.float64), 0.0)
        self.cand: List[np.ndarray] = [np.asarray(c, dtype=np.int64)
                                       for c in cand]
        self.speed = np.asarray(speed, dtype=np.float64)
        self.mu = mu
        self.names: Tuple[str, ...] = tuple(names)
        # Pseudo-replica states (per_model) carry indices that mean
        # nothing to a real pool — consumers must not place by them.
        self.pseudo = pseudo
        if len(self.cand) != len(self.names):
            raise ValueError("one candidate list per model required")
        for name, c in zip(self.names, self.cand):
            if len(c) == 0:
                raise ValueError(f"no replica serves model {name!r}")

    @classmethod
    def per_model(cls, names: Sequence[str], waits: Sequence[float],
                  mu: Sequence[float]) -> "ChargedWaits":
        """Model-granularity charging: each model is its own queue (the
        paper's per-model-endpoint topology).  Built from a model-level
        wait snapshot when no replica topology is known."""
        n = len(names)
        return cls(waits, [(i,) for i in range(n)], np.ones(n), mu, names,
                   pseudo=True)

    # ------------------------------------------------------------------
    def model_waits(self) -> np.ndarray:
        """(n_models,) ``W_queue(m)``: each model's wait at its current
        least-loaded capable replica — the same min-reduction (and the
        same floats) as ``ReplicaPool.waits_by_name``, but live."""
        rw = self.rep_wait
        return np.array([rw[c].min() for c in self.cand])

    def wait_of(self, mid: int) -> float:
        return float(self.rep_wait[self.cand[mid]].min())

    def as_map(self) -> Dict[str, float]:
        """Frozen name → wait snapshot of the current state (what the
        pre-charging path handed to the router whole)."""
        return dict(zip(self.names, self.model_waits().tolist()))

    def charge(self, mid: int) -> int:
        """Charge one admitted pick of model ``mid``: add μ(mid)/speed
        to its least-loaded capable replica (ties: pool order, matching
        ``ReplicaPool.best_for``) and return that replica's pool index —
        the caller can place the request there without re-deriving the
        choice."""
        c = self.cand[mid]
        if len(c) == 1:
            r = int(c[0])
        else:
            r = int(c[int(np.argmin(self.rep_wait[c]))])
        self.rep_wait[r] += float(self.mu[mid]) / float(self.speed[r])
        return r
