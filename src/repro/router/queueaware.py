"""Queue-aware ModiPick: fold server load into the latency budget.

The paper's budget (Eq. 1) only charges the network:

    T_budget = T_sla - 2 * T_input

Under concurrent traffic a request additionally waits ``W_queue(m)`` in
the FIFO of the replica that will serve model ``m``, so the effective
budget is per-model:

    T_budget(m) = T_sla - 2 * T_input - W_queue(m)

Rather than rewrite every policy to take per-model budgets, we use the
equivalent shift: a model fits a budget reduced by ``W_queue(m)`` iff the
model with mean ``mu + W_queue(m)`` fits the plain Eq. 1 budget (sigma is
unaffected — queueing shifts the location of the latency distribution the
router reasons about, not the inference jitter).  ``QueueAwareSelector``
therefore presents any unmodified ``Policy`` with a shifted *view* of the
profile store and plain ``T_budget``.  With ``W_queue == 0`` the view is
the store itself, so selection reduces *exactly* to Eq. 1 — the paper's
behaviour is the zero-load special case.

This module is substrate-independent (it lives under ``repro.router``
and is consumed by the simulator, the discrete-event engine and the live
executor alike).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.policy import Policy, SelectionTrace, budget
from repro.core.profiles import ModelProfile, ProfileStore, ProfileTable


@functools.lru_cache(maxsize=8)
def _shared_zeros(n: int) -> np.ndarray:
    """Read-only zeros shared by every shifted view of an ``n``-model
    pool (a view's ``queue_mu`` is zero by construction and never
    written)."""
    z = np.zeros(n)
    z.setflags(write=False)
    return z

WQueueFn = Callable[[str], float]


def queue_aware_budget(t_sla: float, t_input: float, w_queue: float) -> float:
    """T_budget(m) = T_sla - 2*T_input - W_queue(m).  Reduces to Eq. 1
    when ``w_queue == 0``."""
    return budget(t_sla, t_input) - w_queue


class _ShiftedView(ProfileStore):
    """Lazy shifted view of a :class:`ProfileStore`.

    Selection only ever touches the view's :class:`ProfileTable`
    snapshot, so that is all the constructor builds (reusing the base
    snapshot's cached accuracy order — a mu shift cannot reorder it).
    The per-profile dict of shifted :class:`ModelProfile` objects is
    materialised lazily, only if a consumer actually dereferences
    ``view.profiles`` / ``view[name]`` — the selection hot path never
    does, which removes the per-batch dataclass churn the eager view
    used to pay."""

    def __init__(self, store: ProfileStore, shifts: Dict[str, float]):
        # Deliberately NOT chaining to ProfileStore.__init__: the view
        # shares the base's configuration and builds its table directly.
        self.alpha = store.alpha
        self.cold_age = store.cold_age
        self.step = store.step
        self.base = store.base
        self._shift_src = store
        self.version = 0
        self._shifts = shifts
        base = store.table()
        # Shifted snapshot assembled directly (same fields
        # ``ProfileTable.shifted`` would produce, same IEEE doubles —
        # python float adds match the elementwise array add): accuracy,
        # sigma, the cached order and the name index are shared with the
        # base exactly as before; μ is new; queue_mu is zero because the
        # shift has consumed it.
        b_mu, b_sig, _, b_acc, b_ord, b_names = base.scalar_cache()
        mu_l = [m + shifts[n] for m, n in zip(b_mu, b_names)]
        fastest = 0
        best = mu_l[0]
        for i in range(1, len(mu_l)):
            if mu_l[i] < best:
                best = mu_l[i]
                fastest = i
        tab = ProfileTable.__new__(ProfileTable)
        tab.names = base.names
        tab.index = base.index
        tab.accuracy = base.accuracy
        tab.mu = np.asarray(mu_l)
        tab.sigma = base.sigma
        tab.queue_mu = _shared_zeros(len(mu_l))
        tab.acc_order = base.acc_order
        tab.fastest = fastest
        tab._device = None
        # Scalar-path cache derived from the base's by the same float
        # adds; sigma is copied (the base list is patched in place by
        # telemetry), accuracy/order/names can't drift and are shared.
        sig_l = b_sig[:]
        tab._scalar = (mu_l, sig_l,
                       [m + g for m, g in zip(mu_l, sig_l)],
                       b_acc, b_ord, b_names)
        self._table = tab
        self._profiles: Dict[str, ModelProfile] = None

    @property
    def profiles(self) -> Dict[str, ModelProfile]:
        if self._profiles is None:
            self._profiles = {
                p.name: ModelProfile(name=p.name, accuracy=p.accuracy,
                                     mu=p.mu + self._shifts[p.name],
                                     var=p.var, n_obs=p.n_obs,
                                     last_selected=p.last_selected)
                for p in self._shift_src.profiles.values()}
        return self._profiles

    def _refresh(self, name: str, p: ModelProfile) -> None:
        # Observing on a view must stay view-local (the historical copy
        # semantics): the prebuilt snapshot shares the BASE table's
        # sigma array and a read-only zeros queue_mu, so instead of
        # patching in place, drop it — the next ``table()`` rebuilds
        # from the view's own (lazily copied) profiles.
        self._table = None


def shifted_store(store: ProfileStore, w_queue_fn: WQueueFn, *,
                  shifts: Optional[Dict[str, float]] = None) -> ProfileStore:
    """View of ``store`` with each model's mean shifted by its estimated
    queue wait.  Returns ``store`` itself when every shift is zero, so
    the zero-load path is bit-identical to plain selection.

    The view's ``ProfileTable`` is derived from the base store's cached
    snapshot: a mu shift cannot change the accuracy order, so the view
    reuses it instead of re-sorting the pool on every selection.

    ``shifts`` (optional) hands over an already-clamped name -> wait
    snapshot — the Router builds exactly one per batch — so the view
    does not re-query ``w_queue_fn`` per model."""
    if shifts is None:
        shifts = {n: max(0.0, float(w_queue_fn(n)))
                  for n in store.profiles}
    if not any(shifts.values()):
        return store
    return _ShiftedView(store, shifts)


class QueueAwareSelector:
    """Wrap any ``Policy`` with per-model queue-wait awareness.

    ``select_traced(store, t_budget, w_queue_fn, rng)`` evaluates the
    wrapped policy against the shifted store view; the returned trace's
    names refer to the real store's models.
    """

    def __init__(self, policy: Policy):
        self.policy = policy
        self.name = f"qa_{policy.name}"

    def select_traced(self, store: ProfileStore, t_budget: float,
                      w_queue_fn: WQueueFn,
                      rng: np.random.Generator) -> SelectionTrace:
        return self.policy.select_traced(
            shifted_store(store, w_queue_fn), t_budget, rng)

    def select(self, store: ProfileStore, t_budget: float,
               w_queue_fn: WQueueFn, rng: np.random.Generator) -> str:
        return self.select_traced(store, t_budget, w_queue_fn, rng).chosen
