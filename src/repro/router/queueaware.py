"""Queue-aware ModiPick: fold server load into the latency budget.

The paper's budget (Eq. 1) only charges the network:

    T_budget = T_sla - 2 * T_input

Under concurrent traffic a request additionally waits ``W_queue(m)`` in
the FIFO of the replica that will serve model ``m``, so the effective
budget is per-model:

    T_budget(m) = T_sla - 2 * T_input - W_queue(m)

Rather than rewrite every policy to take per-model budgets, we use the
equivalent shift: a model fits a budget reduced by ``W_queue(m)`` iff the
model with mean ``mu + W_queue(m)`` fits the plain Eq. 1 budget (sigma is
unaffected — queueing shifts the location of the latency distribution the
router reasons about, not the inference jitter).  ``QueueAwareSelector``
therefore presents any unmodified ``Policy`` with a shifted *view* of the
profile store and plain ``T_budget``.  With ``W_queue == 0`` the view is
the store itself, so selection reduces *exactly* to Eq. 1 — the paper's
behaviour is the zero-load special case.

This module is substrate-independent (it lives under ``repro.router``
and is consumed by the simulator, the discrete-event engine and the live
executor alike); ``repro.sim.queueaware`` re-exports it for
backwards compatibility.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core.policy import Policy, SelectionTrace, budget
from repro.core.profiles import ModelProfile, ProfileStore

WQueueFn = Callable[[str], float]


def queue_aware_budget(t_sla: float, t_input: float, w_queue: float) -> float:
    """T_budget(m) = T_sla - 2*T_input - W_queue(m).  Reduces to Eq. 1
    when ``w_queue == 0``."""
    return budget(t_sla, t_input) - w_queue


def shifted_store(store: ProfileStore, w_queue_fn: WQueueFn) -> ProfileStore:
    """View of ``store`` with each model's mean shifted by its estimated
    queue wait.  Returns ``store`` itself when every shift is zero, so
    the zero-load path is bit-identical to plain selection.

    The view's ``ProfileTable`` is derived from the base store's cached
    snapshot: a mu shift cannot change the accuracy order, so the view
    reuses it instead of re-sorting the pool on every selection."""
    shifts: Dict[str, float] = {n: max(0.0, float(w_queue_fn(n)))
                                for n in store.profiles}
    if not any(shifts.values()):
        return store
    view = ProfileStore(
        [ModelProfile(name=p.name, accuracy=p.accuracy,
                      mu=p.mu + shifts[p.name], var=p.var, n_obs=p.n_obs,
                      last_selected=p.last_selected)
         for p in store.profiles.values()],
        alpha=store.alpha, cold_age=store.cold_age)
    view.step = store.step
    view.base = store.base
    base = store.table()
    view._table = base.shifted(
        np.array([shifts[n] for n in base.names]))
    return view


class QueueAwareSelector:
    """Wrap any ``Policy`` with per-model queue-wait awareness.

    ``select_traced(store, t_budget, w_queue_fn, rng)`` evaluates the
    wrapped policy against the shifted store view; the returned trace's
    names refer to the real store's models.
    """

    def __init__(self, policy: Policy):
        self.policy = policy
        self.name = f"qa_{policy.name}"

    def select_traced(self, store: ProfileStore, t_budget: float,
                      w_queue_fn: WQueueFn,
                      rng: np.random.Generator) -> SelectionTrace:
        return self.policy.select_traced(
            shifted_store(store, w_queue_fn), t_budget, rng)

    def select(self, store: ProfileStore, t_budget: float,
               w_queue_fn: WQueueFn, rng: np.random.Generator) -> str:
        return self.select_traced(store, t_budget, w_queue_fn, rng).chosen
