"""Mamba-2 SSD (state-space duality) mixer.

The XLA path implements the chunked SSD algorithm (intra-chunk quadratic
term + inter-chunk state recurrence via associative scan); the TPU Pallas
kernel in ``repro.kernels.ssd_scan`` fuses the same computation per chunk.
Decode maintains O(1) state: conv ring + (H, hd, N) SSM state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import ParamSpec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    return s, d_in, H


def ssd_template(cfg: ModelConfig) -> dict:
    """Separate projection matrices per stream (z, x, B, C, dt).

    A fused in_proj looks natural but TP-shards its output dim and then
    *slices* it at stream boundaries that don't align to the shards —
    GSPMD repairs that with halo collective-permutes (observed: 86 GiB/chip
    on mamba2 train_4k).  Separate matmuls give each stream its own clean
    sharding; same math, same parameter count."""
    s, d_in, H = _dims(cfg)
    d = cfg.d_model
    n = s.n_groups * s.d_state
    return {
        "in_z": ParamSpec((d, d_in), ("embed_fsdp", "heads_merged")),
        "in_x": ParamSpec((d, d_in), ("embed_fsdp", "heads_merged")),
        "in_B": ParamSpec((d, n), ("embed_fsdp", None)),
        "in_C": ParamSpec((d, n), ("embed_fsdp", None)),
        "in_dt": ParamSpec((d, H), ("embed_fsdp", "heads")),
        "conv_x_w": ParamSpec((s.conv_width, d_in), (None, "heads_merged")),
        "conv_x_b": ParamSpec((d_in,), ("heads_merged",), "zeros"),
        "conv_B_w": ParamSpec((s.conv_width, n), (None, None)),
        "conv_B_b": ParamSpec((n,), (None,), "zeros"),
        "conv_C_w": ParamSpec((s.conv_width, n), (None, None)),
        "conv_C_b": ParamSpec((n,), (None,), "zeros"),
        "A_log": ParamSpec((H,), (None,), "ones"),
        "D": ParamSpec((H,), (None,), "ones"),
        "dt_bias": ParamSpec((H,), (None,), "zeros"),
        "norm_z": ParamSpec((d_in,), (None,), "zeros"),
        "out_proj": ParamSpec((d_in, d), ("heads_merged", "embed_fsdp"), "normal_out", 0),
    }


def _causal_conv(x, w, b):
    """x: (B,S,C), w: (W,C) depthwise causal conv."""
    W = w.shape[0]
    xp = jnp.pad(x, [(0, 0), (W - 1, 0), (0, 0)])
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def ssd_chunked(xh, dt, A, B_, C_, chunk, return_final_state=False):
    """Chunked SSD. xh: (B,S,H,P), dt: (B,S,H) (post-softplus), A: (H,) (<0),
    B_,C_: (B,S,G,N) shared across the H//G heads of each group.
    Returns y: (B,S,H,P) (and the final (B,H,P,N) state if requested).
    All decay math in fp32.
    """
    Bb, S, H, P = xh.shape
    G = B_.shape[2]
    hg = H // G
    cs = min(chunk, S)
    if S % cs:  # pad to a chunk multiple; dt=0 ⇒ padded tokens are inert
        pad = cs - S % cs
        xh = jnp.pad(xh, [(0, 0), (0, pad), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        B_ = jnp.pad(B_, [(0, 0), (0, pad), (0, 0), (0, 0)])
        C_ = jnp.pad(C_, [(0, 0), (0, pad), (0, 0), (0, 0)])
        y = ssd_chunked(xh, dt, A, B_, C_, chunk, return_final_state)
        if return_final_state:
            return y[0][:, :S], y[1]
        return y[:, :S]
    nc = S // cs

    dtA = (dt.astype(jnp.float32) * A).reshape(Bb, nc, cs, G, hg)
    dtA = shard(dtA, "batch", None, None, None, "heads")
    cum = jnp.cumsum(dtA, axis=2)  # (B,nc,cs,G,hg) running log-decay
    total = cum[:, :, -1]  # (B,nc,G,hg)

    # Keep the big operands (x, B, C) in their storage dtype — fp32 happens
    # inside the matmul accumulators (preferred_element_type), not via
    # materialized fp32 copies of (B,S,d_inner)-sized tensors.
    xs = xh.reshape(Bb, nc, cs, G, hg, P)
    xs = shard(xs, "batch", None, None, None, "heads", None)
    dts = dt.reshape(Bb, nc, cs, G, hg).astype(jnp.float32)
    dts = shard(dts, "batch", None, None, None, "heads")
    Bs = B_.reshape(Bb, nc, cs, G, -1)
    Cs = C_.reshape(Bb, nc, cs, G, -1)

    # ---- intra-chunk (quadratic within chunk) --------------------------
    # scores shared per group; decay L per head.
    scores = jnp.einsum("bcign,bcjgn->bcgij", Cs, Bs,
                        preferred_element_type=jnp.float32)  # (B,nc,G,i,j)
    L = jnp.exp(jnp.clip(cum[:, :, :, None] - cum[:, :, None, :], -60.0, 0.0))
    # L: (B,nc,i,j,G,hg); apply causal mask.  NB: the head-sharded dim is
    # hg (the last), not G — annotating G here replicates L and triggers
    # per-layer all-gathers (observed: 553 GiB/chip on mamba2 train).
    causal = jnp.tril(jnp.ones((cs, cs), jnp.float32))
    L = L * causal[None, None, :, :, None, None]
    L = shard(L, "batch", None, None, None, None, "heads")
    M = scores.transpose(0, 1, 3, 4, 2)[..., None] * L \
        * dts[:, :, None, :, :, :]  # (B,nc,i,j,G,hg)
    M = shard(M, "batch", None, None, None, None, "heads")
    y_intra = jnp.einsum("bcijgh,bcjghp->bcighp", M, xs,
                         preferred_element_type=jnp.float32)

    # ---- chunk states ----------------------------------------------------
    decay_to_end = jnp.exp(jnp.clip(total[:, :, None] - cum, -60.0, 0.0))
    states = jnp.einsum("bcjgh,bcjgn,bcjghp->bcghpn",
                        dts * decay_to_end, Bs, xs,
                        preferred_element_type=jnp.float32)  # (B,nc,G,hg,P,N)
    states = shard(states, "batch", None, None, "heads", None, None)

    # ---- inter-chunk recurrence (associative scan over chunks) ---------
    chunk_decay = jnp.exp(jnp.clip(total, -60.0, 0.0))  # (B,nc,G,hg)

    def combine(a, b):
        da, sa = a
        db, sb = b
        return da * db, sa * db[..., None, None] + sb

    _, st_scan = jax.lax.associative_scan(combine, (chunk_decay, states), axis=1)
    st_prev = jnp.concatenate(
        [jnp.zeros_like(st_scan[:, :1]), st_scan[:, :-1]], axis=1)

    decay_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))  # (B,nc,cs,G,hg)
    y_inter = jnp.einsum("bcign,bcghpn,bcigh->bcighp", Cs, st_prev, decay_in,
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    if return_final_state:
        final = st_scan[:, -1].reshape(Bb, H, P, -1)
        return y.astype(xh.dtype), final
    return y.astype(xh.dtype)


def ssd_block_apply(params, x, cfg: ModelConfig, return_cache: bool = False):
    """Full-sequence SSD mixer. x: (B,S,D) → (B,S,D) [, decode cache]."""
    s, d_in, H = _dims(cfg)
    z = jnp.einsum("bsd,dp->bsp", x, params["in_z"])
    xc = jnp.einsum("bsd,dp->bsp", x, params["in_x"])
    B_ = jnp.einsum("bsd,dn->bsn", x, params["in_B"])
    C_ = jnp.einsum("bsd,dn->bsn", x, params["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["in_dt"])
    xc = shard(xc, "batch", "seq", "heads_merged")
    if return_cache:
        conv_hist = {
            "x": xc[:, -(s.conv_width - 1):],
            "B": B_[:, -(s.conv_width - 1):],
            "C": C_[:, -(s.conv_width - 1):],
        }
    xc = _causal_conv(xc, params["conv_x_w"], params["conv_x_b"])
    B_ = _causal_conv(B_, params["conv_B_w"], params["conv_B_b"])
    C_ = _causal_conv(C_, params["conv_C_w"], params["conv_C_b"])
    Bb, S = x.shape[:2]
    xh = xc.reshape(Bb, S, H, s.head_dim)
    xh = shard(xh, "batch", "seq", "heads", None)
    B_ = B_.reshape(Bb, S, s.n_groups, s.d_state)
    C_ = C_.reshape(Bb, S, s.n_groups, s.d_state)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    if return_cache:
        y, final_state = ssd_chunked(xh, dt_sp, A, B_, C_, s.chunk_size,
                                     return_final_state=True)
    else:
        y = ssd_chunked(xh, dt_sp, A, B_, C_, s.chunk_size)
    y = y + xh * params["D"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(Bb, S, d_in)
    # gated RMSNorm (mamba2): norm(y) * silu(z)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + params["norm_z"].astype(jnp.float32))
    y = yf.astype(x.dtype)
    out = jnp.einsum("bsp,pd->bsd", y, params["out_proj"])
    if return_cache:
        cache = dict(conv_hist, state=final_state.astype(x.dtype))
        return out, cache
    return out


# ----------------------------------------------------------------------
# Decode path: O(1) state
# ----------------------------------------------------------------------
def ssd_cache_template(cfg: ModelConfig, batch: int) -> dict:
    s, d_in, H = _dims(cfg)
    n = s.n_groups * s.d_state
    w = s.conv_width - 1
    return {
        "x": ParamSpec((batch, w, d_in), ("batch", None, "heads_merged"), "zeros"),
        "B": ParamSpec((batch, w, n), ("batch", None, None), "zeros"),
        "C": ParamSpec((batch, w, n), ("batch", None, None), "zeros"),
        "state": ParamSpec((batch, H, s.head_dim, s.d_state),
                           ("batch", "heads", None, None), "zeros"),
    }


def _conv_step(hist, new, w, b):
    """One causal-conv decode step; returns (out (B,C), new_hist)."""
    h = jnp.concatenate([hist, new[:, None]], axis=1)  # (B, W, C)
    return jnp.einsum("bwc,wc->bc", h, w) + b, h[:, 1:]


def ssd_decode_step(params, cache, x, cfg: ModelConfig):
    """x: (B,1,D). Returns (out (B,1,D), new_cache)."""
    s, d_in, H = _dims(cfg)
    z = jnp.einsum("bsd,dp->bsp", x, params["in_z"])[:, 0]
    xc = jnp.einsum("bsd,dp->bsp", x, params["in_x"])[:, 0]
    B_ = jnp.einsum("bsd,dn->bsn", x, params["in_B"])[:, 0]
    C_ = jnp.einsum("bsd,dn->bsn", x, params["in_C"])[:, 0]
    dt = jnp.einsum("bsd,dh->bsh", x, params["in_dt"])[:, 0]
    xc, new_x = _conv_step(cache["x"], xc, params["conv_x_w"], params["conv_x_b"])
    B_, new_B = _conv_step(cache["B"], B_, params["conv_B_w"], params["conv_B_b"])
    C_, new_C = _conv_step(cache["C"], C_, params["conv_C_w"], params["conv_C_b"])
    xc = jax.nn.silu(xc)
    B_ = jax.nn.silu(B_).reshape(-1, s.n_groups, s.d_state)
    C_ = jax.nn.silu(C_).reshape(-1, s.n_groups, s.d_state)
    xh = xc.reshape(-1, H, s.head_dim)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    decay = jnp.exp(dt_sp * A)  # (B,H)
    hg = H // s.n_groups
    Bh = jnp.repeat(B_, hg, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(C_, hg, axis=1).astype(jnp.float32)
    upd = (dt_sp[..., None, None] * xh.astype(jnp.float32)[..., None]
           * Bh[:, :, None, :])  # (B,H,P,N)
    new_state = cache["state"].astype(jnp.float32) * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + xh.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, d_in)
    yf = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + params["norm_z"].astype(jnp.float32))
    out = jnp.einsum("bp,pd->bd", yf.astype(x.dtype), params["out_proj"])
    new_cache = {"x": new_x, "B": new_B, "C": new_C,
                 "state": new_state.astype(cache["state"].dtype)}
    return out[:, None], new_cache
