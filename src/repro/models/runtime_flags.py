"""Runtime lowering flags (used by the dry-run's scan calibration).

XLA's ``cost_analysis()`` visits a while-loop body once, so scanned-layer
programs under-report FLOPs/collectives by the trip count.  The dry-run
therefore compiles shallow *unrolled* variants (1 and 2 pattern
repetitions) to measure the exact per-repetition delta, then corrects the
full-depth numbers.  These flags switch every internal ``lax.scan`` /
``lax.map`` to a Python loop for those calibration builds only.
"""
from __future__ import annotations

import contextlib

UNROLL_SCANS = False
Q_CHUNK_OVERRIDE = None   # larger q-chunks keep unrolled HLO small
KV_CHUNK_OVERRIDE = None  # ditto for the online-softmax kv loop


@contextlib.contextmanager
def unrolled(q_chunk: int | None = None, kv_chunk: int | None = None):
    global UNROLL_SCANS, Q_CHUNK_OVERRIDE, KV_CHUNK_OVERRIDE
    prev = (UNROLL_SCANS, Q_CHUNK_OVERRIDE, KV_CHUNK_OVERRIDE)
    UNROLL_SCANS, Q_CHUNK_OVERRIDE, KV_CHUNK_OVERRIDE = True, q_chunk, kv_chunk
    try:
        yield
    finally:
        UNROLL_SCANS, Q_CHUNK_OVERRIDE, KV_CHUNK_OVERRIDE = prev
