"""Model assembly: superblock-scanned decoder LMs, whisper enc-dec, VLM.

Layer stacks lower to ``lax.scan`` over *superblocks* (one repetition of the
config's block pattern) so HLO size — and XLA compile time — is independent
of depth.  The remainder layers (e.g. gemma3's 34 = 5×6 + 4) run unrolled
as the tail.  The same block code serves train, prefill and decode.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import runtime_flags
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamSpec, abstract, apply_norm, axes_tree, materialize, mlp_apply,
    mlp_template, norm_template, sinusoidal_pos, spec_map, stack_specs,
)

Params = Dict[str, Any]


# ======================================================================
# Templates
# ======================================================================
def block_template(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    t = {"norm1": norm_template(d)}
    if kind in ("attn", "local", "enc"):
        t["attn"] = attn.attn_template(cfg)
    elif kind == "xdec":
        t["attn"] = attn.attn_template(cfg)
        t["norm_x"] = norm_template(d)
        t["xattn"] = attn.attn_template(cfg)
    elif kind == "ssd":
        t["ssd"] = ssm_mod.ssd_template(cfg)
        return t  # mamba2 blocks carry no separate MLP
    elif kind == "rglru":
        t["rglru"] = rglru_mod.rglru_template(cfg)
    else:
        raise ValueError(kind)
    t["norm2"] = norm_template(d)
    t["mlp"] = moe_mod.moe_template(cfg) if cfg.moe else mlp_template(d, cfg.d_ff, cfg.mlp)
    return t


def param_template(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    t: dict = {"embed": {"table": ParamSpec((v, d), ("vocab", "embed_fsdp"))}}
    if cfg.n_superblocks > 0:
        t["blocks"] = {
            f"p{i}": stack_specs(block_template(cfg, decoder_kind(cfg, k)), cfg.n_superblocks)
            for i, k in enumerate(cfg.pattern)
        }
    t["tail"] = {
        f"t{i}": block_template(cfg, decoder_kind(cfg, k))
        for i, k in enumerate(cfg.tail_kinds)
    }
    t["final_norm"] = norm_template(d)
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((d, v), ("embed_fsdp", "vocab"))
    if cfg.encdec is not None:
        t["encoder"] = {
            "blocks": stack_specs(block_template(cfg, "enc"), cfg.encdec.n_encoder_layers),
            "final_norm": norm_template(d),
        }
    return t


def decoder_kind(cfg: ModelConfig, kind: str) -> str:
    if cfg.encdec is not None and kind == "attn":
        return "xdec"
    return kind


def block_cache_template(cfg: ModelConfig, kind: str, batch: int, cache_len: int) -> dict:
    if kind in ("attn", "local"):
        return attn.cache_template(cfg, kind, batch, cache_len)
    if kind == "xdec":
        c = attn.cache_template(cfg, "attn", batch, cache_len)
        hd, kv, F = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.encdec.n_frames
        c["xk"] = ParamSpec((batch, F, kv, hd), ("batch", None, "kv_heads", None), "zeros")
        c["xv"] = ParamSpec((batch, F, kv, hd), ("batch", None, "kv_heads", None), "zeros")
        return c
    if kind == "ssd":
        return ssm_mod.ssd_cache_template(cfg, batch)
    if kind == "rglru":
        return rglru_mod.rglru_cache_template(cfg, batch)
    raise ValueError(kind)


def cache_template(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    t: dict = {}
    if cfg.n_superblocks > 0:
        t["blocks"] = {
            f"p{i}": stack_specs(
                block_cache_template(cfg, decoder_kind(cfg, k), batch, cache_len),
                cfg.n_superblocks)
            for i, k in enumerate(cfg.pattern)
        }
    t["tail"] = {
        f"t{i}": block_cache_template(cfg, decoder_kind(cfg, k), batch, cache_len)
        for i, k in enumerate(cfg.tail_kinds)
    }
    return t


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Params:
    return materialize(param_template(cfg), key, dtype)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    return spec_map(lambda s: jnp.zeros(s.shape, dtype),
                    cache_template(cfg, batch, cache_len))


# ======================================================================
# Block forward (train / prefill)
# ======================================================================
def block_forward_full(cfg: ModelConfig, kind: str, p, x, positions, cache_len,
                       enc_out=None, enc_pos=None):
    """Returns (x, aux_loss, cache|None)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm, x, p["norm1"]["scale"], cfg.norm_eps)
    cache = None
    if kind in ("attn", "local"):
        out, cache = attn.prefill_attention(p["attn"], h, positions, cfg, kind,
                                            cache_len=cache_len)
        x = x + out
    elif kind == "enc":
        q, k, v = attn._project_qkv(p["attn"], h, cfg)
        o = attn.attention_full(q, k, v, positions, positions, causal=False)
        B, S = h.shape[:2]
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), p["attn"]["wo"])
    elif kind == "xdec":
        out, cache = attn.prefill_attention(p["attn"], h, positions, cfg, "attn",
                                            cache_len=cache_len)
        x = x + out
        hx = apply_norm(cfg.norm, x, p["norm_x"]["scale"], cfg.norm_eps)
        _, ek, ev = attn._project_qkv(p["xattn"], enc_out, cfg)
        xout, _ = attn.prefill_attention(p["xattn"], hx, positions, cfg, "attn",
                                         cross_kv=(ek, ev, enc_pos))
        x = x + xout
        if cache is not None:
            cache["xk"], cache["xv"] = ek, ev
    elif kind == "ssd":
        if cache_len is not None:
            out, cache = ssm_mod.ssd_block_apply(p["ssd"], h, cfg, return_cache=True)
        else:
            out = ssm_mod.ssd_block_apply(p["ssd"], h, cfg)
        return x + out, aux, cache  # no MLP
    elif kind == "rglru":
        if cache_len is not None:
            out, cache = rglru_mod.rglru_prefill_cache(p["rglru"], h, cfg)
        else:
            out = rglru_mod.rglru_block_apply(p["rglru"], h, cfg)
        x = x + out
    else:
        raise ValueError(kind)

    h2 = apply_norm(cfg.norm, x, p["norm2"]["scale"], cfg.norm_eps)
    if cfg.moe is not None and kind != "enc":
        mo, aux = moe_mod.moe_ffn(p["mlp"], h2, cfg)
        x = x + mo
    else:
        x = x + mlp_apply(p["mlp"], h2, cfg.mlp)
    x = shard(x, "batch", "seq", None)
    return x, aux, cache


def block_forward_decode(cfg: ModelConfig, kind: str, p, x, cache, pos):
    """x: (B,1,D). Returns (x, new_cache)."""
    h = apply_norm(cfg.norm, x, p["norm1"]["scale"], cfg.norm_eps)
    if kind in ("attn", "local"):
        out, new_cache = attn.decode_attention(p["attn"], cache, h, pos, cfg, kind)
        x = x + out
    elif kind == "xdec":
        self_cache = {"k": cache["k"], "v": cache["v"]}
        out, new_self = attn.decode_attention(p["attn"], self_cache, h, pos, cfg, "attn")
        x = x + out
        hx = apply_norm(cfg.norm, x, p["norm_x"]["scale"], cfg.norm_eps)
        x = x + _cross_decode(cfg, p["xattn"], hx, cache["xk"], cache["xv"])
        new_cache = dict(new_self, xk=cache["xk"], xv=cache["xv"])
    elif kind == "ssd":
        out, new_cache = ssm_mod.ssd_decode_step(p["ssd"], cache, h, cfg)
        return x + out, new_cache
    elif kind == "rglru":
        out, new_cache = rglru_mod.rglru_decode_step(p["rglru"], cache, h, cfg)
        x = x + out
    else:
        raise ValueError(kind)

    h2 = apply_norm(cfg.norm, x, p["norm2"]["scale"], cfg.norm_eps)
    if cfg.moe is not None:
        mo, _ = moe_mod.moe_ffn(p["mlp"], h2, cfg)
        x = x + mo
    else:
        x = x + mlp_apply(p["mlp"], h2, cfg.mlp)
    return x, new_cache


def _cross_decode(cfg, p, x, xk, xv):
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    KV = cfg.n_kv_heads
    qg = q.reshape(B, KV, cfg.n_heads // KV, hd)
    s = jnp.einsum("bngh,bknh->bngk", qg, xk, preferred_element_type=jnp.float32)
    pr = jax.nn.softmax(s * hd ** -0.5, axis=-1)
    o = jnp.einsum("bngk,bknh->bngh", pr.astype(xv.dtype), xv)
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1), p["wo"])


# ======================================================================
# Trunk application
# ======================================================================
def _apply_trunk_full(cfg, params, x, positions, cache_len, enc_out, enc_pos,
                      remat: bool):
    pattern = tuple(decoder_kind(cfg, k) for k in cfg.pattern)
    aux_total = jnp.zeros((), jnp.float32)
    caches: dict = {}

    def superblock(x, layer_params):
        aux_sb = jnp.zeros((), jnp.float32)
        sb_caches = {}
        for i, kind in enumerate(pattern):
            x, aux, c = block_forward_full(cfg, kind, layer_params[f"p{i}"], x,
                                           positions, cache_len, enc_out, enc_pos)
            aux_sb = aux_sb + aux
            if cache_len is not None:
                sb_caches[f"p{i}"] = c
        return x, aux_sb, sb_caches

    if remat:
        superblock = jax.checkpoint(superblock,
                                    policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.n_superblocks > 0:
        def body(carry, layer_params):
            x, aux = carry
            x, aux_sb, sb_caches = superblock(x, layer_params)
            return (x, aux + aux_sb), (sb_caches if cache_len is not None else 0)

        if runtime_flags.UNROLL_SCANS:
            ys_list = []
            for i in range(cfg.n_superblocks):
                lp = jax.tree.map(lambda a: a[i], params["blocks"])
                (x, aux_total), y = body((x, aux_total), lp)
                ys_list.append(y)
            ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys_list) \
                if cache_len is not None else None
        else:
            (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), params["blocks"])
        if cache_len is not None:
            caches["blocks"] = ys

    tail_caches = {}
    for i, k in enumerate(cfg.tail_kinds):
        kind = decoder_kind(cfg, k)
        x, aux, c = block_forward_full(cfg, kind, params["tail"][f"t{i}"], x,
                                       positions, cache_len, enc_out, enc_pos)
        aux_total = aux_total + aux
        if cache_len is not None:
            tail_caches[f"t{i}"] = c
    if cache_len is not None:
        caches["tail"] = tail_caches
    return x, aux_total, caches


def _apply_trunk_decode(cfg, params, x, cache, pos):
    pattern = tuple(decoder_kind(cfg, k) for k in cfg.pattern)

    if cfg.n_superblocks > 0:
        def body(x, xs):
            layer_params, layer_cache = xs
            new_caches = {}
            for i, kind in enumerate(pattern):
                x, nc = block_forward_decode(cfg, kind, layer_params[f"p{i}"],
                                             x, layer_cache[f"p{i}"], pos)
                new_caches[f"p{i}"] = nc
            return x, new_caches

        if runtime_flags.UNROLL_SCANS:
            ys_list = []
            for i in range(cfg.n_superblocks):
                xs_i = jax.tree.map(lambda a: a[i],
                                    (params["blocks"], cache["blocks"]))
                x, y = body(x, xs_i)
                ys_list.append(y)
            new_blocks = jax.tree.map(lambda *zs: jnp.stack(zs), *ys_list)
        else:
            x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
    else:
        new_blocks = None

    new_tail = {}
    for i, k in enumerate(cfg.tail_kinds):
        kind = decoder_kind(cfg, k)
        x, nc = block_forward_decode(cfg, kind, params["tail"][f"t{i}"],
                                     x, cache["tail"][f"t{i}"], pos)
        new_tail[f"t{i}"] = nc
    new_cache = {"tail": new_tail}
    if new_blocks is not None:
        new_cache["blocks"] = new_blocks
    return x, new_cache


# ======================================================================
# Embedding / unembedding
# ======================================================================
def embed_tokens(cfg: ModelConfig, params, tokens, positions=None):
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    if not cfg.use_rope and positions is not None:
        x = x + sinusoidal_pos(positions, cfg.d_model).astype(x.dtype)
    return x


def unembed(cfg: ModelConfig, params, x):
    table = params["embed"]["table"] if cfg.tie_embeddings else None
    if table is not None:
        logits = jnp.einsum("bsd,vd->bsv", x, table)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = shard(logits, "batch", "seq", "vocab")
    if cfg.padded_vocab != cfg.vocab_size:
        mask = (jnp.arange(cfg.padded_vocab) < cfg.vocab_size)
        logits = jnp.where(mask, logits, -1e30)
    return logits


def _encode(cfg, params, frames):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    F = frames.shape[1]
    pos = jnp.broadcast_to(jnp.arange(F), frames.shape[:2])
    dt = params["embed"]["table"].dtype
    x = frames.astype(dt) + sinusoidal_pos(pos, cfg.d_model).astype(dt)

    def body(x, layer_params):
        x, _, _ = block_forward_full(cfg, "enc", layer_params, x, pos, None)
        return x, 0

    if runtime_flags.UNROLL_SCANS:
        for i in range(cfg.encdec.n_encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["encoder"]["blocks"]))
    else:
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    x = apply_norm(cfg.norm, x, params["encoder"]["final_norm"]["scale"], cfg.norm_eps)
    return x, pos


def _assemble_input(cfg, params, batch):
    """Returns (x, positions, enc_out, enc_pos)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = enc_pos = None
    if cfg.vlm is not None:
        img = batch["image_embeds"].astype(params["embed"]["table"].dtype)
        n_img = img.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S + n_img), (B, S + n_img))
        x = jnp.concatenate([img, embed_tokens(cfg, params, tokens)], axis=1)
    elif cfg.encdec is not None:
        enc_out, enc_pos = _encode(cfg, params, batch["frames"])
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = embed_tokens(cfg, params, tokens, positions)
    else:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = embed_tokens(cfg, params, tokens, positions)
    x = shard(x, "batch", "seq", None)
    return x, positions, enc_out, enc_pos


# ======================================================================
# Public API: loss / prefill / decode
# ======================================================================
def forward_train(cfg: ModelConfig, params, batch, remat: bool = False):
    """batch: {'tokens', 'targets', ['image_embeds'|'frames']}.
    Returns (loss fp32, metrics)."""
    x, positions, enc_out, enc_pos = _assemble_input(cfg, params, batch)
    x, aux, _ = _apply_trunk_full(cfg, params, x, positions, None, enc_out,
                                  enc_pos, remat)
    x = apply_norm(cfg.norm, x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.vlm is not None:  # predict only over text positions
        x = x[:, -batch["tokens"].shape[1]:]
    logits = unembed(cfg, params, x)
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "ppl_proxy": jnp.exp(jnp.clip(loss, 0.0, 20.0))}


def prefill(cfg: ModelConfig, params, batch, cache_len: int):
    """Returns (cache, last_token_logits (B, V))."""
    x, positions, enc_out, enc_pos = _assemble_input(cfg, params, batch)
    x, _, caches = _apply_trunk_full(cfg, params, x, positions, cache_len,
                                     enc_out, enc_pos, remat=False)
    x = apply_norm(cfg.norm, x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, -1:])[:, 0]
    return caches, logits


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """tokens: (B,) int32; pos: (B,) absolute positions. → (logits, cache)."""
    positions = pos[:, None]
    x = embed_tokens(cfg, params, tokens[:, None], positions)
    x = shard(x, "batch", None, None)
    x, new_cache = _apply_trunk_decode(cfg, params, x, cache, pos)
    x = apply_norm(cfg.norm, x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(cfg, params, x)[:, 0]
    return logits, new_cache
