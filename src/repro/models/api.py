"""Public model API: input specs (ShapeDtypeStruct stand-ins for the
dry-run) and the three lowered step kinds (train / prefill / decode)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M


def _act_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for one forward batch (train/prefill modes).

    [audio]/[vlm] archs receive precomputed frame/patch embeddings from the
    stub frontend as additional inputs."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if cfg.vlm is not None:
        n_img = cfg.vlm.n_image_tokens
        specs["tokens"] = jax.ShapeDtypeStruct((B, S - n_img), jnp.int32)
        specs["image_embeds"] = jax.ShapeDtypeStruct((B, n_img, cfg.d_model), _act_dtype(cfg))
    elif cfg.encdec is not None:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.encdec.n_frames, cfg.d_model), _act_dtype(cfg))
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.mode == "train":
        specs["targets"] = jax.ShapeDtypeStruct(specs["tokens"].shape, jnp.int32)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Decode-step inputs: one new token per sequence + KV/state cache of
    length seq_len."""
    from repro.models.layers import abstract
    B = shape.global_batch
    cache = M.cache_template(cfg, B, shape.seq_len)
    cache_specs = abstract(cache, _act_dtype(cfg))
    return {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        "cache": cache_specs,
    }


def param_specs(cfg: ModelConfig) -> Any:
    from repro.models.layers import abstract
    return abstract(M.param_template(cfg), _act_dtype(cfg))


# ----------------------------------------------------------------------
# Step functions (what the launchers jit)
# ----------------------------------------------------------------------
def make_forward_loss(cfg: ModelConfig, remat: bool = False):
    def loss_fn(params, batch):
        return M.forward_train(cfg, params, batch, remat=remat)
    return loss_fn


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, cache_len)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos)
    return serve_step


def make_train_batch(cfg: ModelConfig, shape: ShapeConfig, key) -> Dict[str, Any]:
    """Random concrete batch (for smokes/benchmarks on CPU)."""
    specs = batch_specs(cfg, shape)
    out = {}
    for name, spec in specs.items():
        key, k = jax.random.split(key)
        if spec.dtype == jnp.int32:
            out[name] = jax.random.randint(k, spec.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(k, spec.shape, jnp.float32).astype(spec.dtype) * 0.02
    return out
