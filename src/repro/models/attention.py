"""Attention blocks: GQA, causal/sliding-window, prefill KV caches, decode.

The XLA path is q-chunked (``lax.map`` over query blocks) so 32k-token
prefills never materialize (S, S) score matrices; sliding-window layers use
banded KV slices so their FLOPs scale with S·window, not S².  On TPU the
Pallas kernels in ``repro.kernels`` replace the inner computation via
``shard_map`` (see repro/distributed); this module is the portable,
GSPMD-shardable fallback the dry-run lowers.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import runtime_flags
from repro.models.layers import ParamSpec, rope

NEG_INF = -1e30


def _chunk_loop(fn, n_chunks):
    """lax.map over chunk indices, or an unrolled Python loop when the
    dry-run's scan-calibration flag is set (static ints then enable causal
    block skipping with exact static bounds)."""
    if runtime_flags.UNROLL_SCANS:
        outs = [fn(i) for i in range(n_chunks)]
        return jnp.stack(outs, axis=0)
    return jax.lax.map(fn, jnp.arange(n_chunks))


def attn_template(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    t = {
        "wq": ParamSpec((d, h * hd), ("embed_fsdp", "heads_merged")),
        "wk": ParamSpec((d, kv * hd), ("embed_fsdp", "kv_merged")),
        "wv": ParamSpec((d, kv * hd), ("embed_fsdp", "kv_merged")),
        "wo": ParamSpec((h * hd, d), ("heads_merged", "embed_fsdp"), "normal_out", 0),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((h * hd,), (None,), "zeros")
        t["bk"] = ParamSpec((kv * hd,), (None,), "zeros")
        t["bv"] = ParamSpec((kv * hd,), (None,), "zeros")
    return t


def _project_qkv(params, x, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def _sdpa_block(q, k, v, mask, head_dim):
    """One (q-block × kv-block) grouped-query attention tile, fp32 softmax.

    q: (B, cq, KV, G, hd); k/v: (B, ck, KV, hd); mask: (B|1, cq, ck) bool.
    """
    scale = head_dim ** -0.5
    s = jnp.einsum("bqngh,bknh->bngqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngqk,bknh->bqngh", p.astype(v.dtype), v)
    return out


def _grouped(q, n_kv):
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


def attention_full(q, k, v, q_positions, kv_positions, *, causal=True,
                   q_chunk=2048, kv_chunk=2048, dynamic_skip=False):
    """Causal full attention: q-chunked outer loop × online-softmax kv
    scan (flash attention in portable XLA).  q: (B,Sq,H,hd); k/v:
    (B,Skv,KV,hd).  Never materializes (Sq, Skv) scores: per (q-block,
    kv-block) tiles are fp32 but transient, the carried state is
    (m, l, acc).

    positions: (B, S) absolute token positions (rows beyond a sequence's
    length should carry position < 0 to be masked)."""
    B, Sq, H, hd = q.shape
    KV, Skv = k.shape[2], k.shape[1]
    if runtime_flags.Q_CHUNK_OVERRIDE:
        q_chunk = runtime_flags.Q_CHUNK_OVERRIDE
    if runtime_flags.KV_CHUNK_OVERRIDE:
        kv_chunk = runtime_flags.KV_CHUNK_OVERRIDE
    cq = min(q_chunk, Sq)
    if Sq % cq:  # pad queries (position −1 ⇒ fully masked), trim after
        pad = cq - Sq % cq
        qp = jnp.pad(q, [(0, 0), (0, pad), (0, 0), (0, 0)])
        pp = jnp.pad(q_positions, [(0, 0), (0, pad)], constant_values=-1)
        out = attention_full(qp, k, v, pp, kv_positions, causal=causal,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)
        return out[:, :Sq]
    ck = min(kv_chunk, Skv)
    if Skv % ck:  # pad kv (position −1 ⇒ masked everywhere)
        pad = ck - Skv % ck
        kp = jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)])
        vp = jnp.pad(v, [(0, 0), (0, pad), (0, 0), (0, 0)])
        pp = jnp.pad(kv_positions, [(0, 0), (0, pad)], constant_values=-1)
        return attention_full(q, kp, vp, q_positions, pp, causal=causal,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)
    qg = _grouped(q, KV)
    n_q = Sq // cq
    n_k = Skv // ck
    G = H // KV
    scale = hd ** -0.5

    # kv blocks as scan xs: (n_k, B, ck, KV, hd)
    kb = jnp.moveaxis(k.reshape(B, n_k, ck, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, n_k, ck, KV, hd), 1, 0)
    pb = jnp.moveaxis(kv_positions.reshape(B, n_k, ck), 1, 0)

    def one_q_chunk(i):
        qs = jax.lax.dynamic_slice_in_dim(qg, i * cq, cq, axis=1).astype(jnp.float32)
        qpos = jax.lax.dynamic_slice_in_dim(q_positions, i * cq, cq, axis=1)

        def kv_step(carry, xs):
            m, l, acc = carry
            kblk, vblk, pblk = xs
            s = jnp.einsum("bqngh,bknh->bngqk", qs, kblk.astype(jnp.float32),
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = pblk[:, None, :] <= qpos[:, :, None]
            else:
                mask = jnp.broadcast_to(pblk[:, None, :] >= 0,
                                        (B, cq, ck))
            mask = jnp.logical_and(mask, pblk[:, None, :] >= 0)
            s = jnp.where(mask[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bngqk,bknh->bngqh", p, vblk.astype(jnp.float32))
            return (m_new, l, acc), 0

        m0 = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, cq, hd), jnp.float32)
        # Causal block skipping: q-chunk i only needs kv blocks covering
        # positions ≤ (i+1)·cq − 1 (standard contiguous positions; the
        # elementwise mask still guards exactness).  Halves attention
        # FLOPs/bytes vs masked-full.  The dynamic-bound loop is not
        # reverse-differentiable, so the train path keeps the full scan
        # (dynamic_skip=False) while prefill opts in.
        skip = causal and Sq == Skv and (
            dynamic_skip or runtime_flags.UNROLL_SCANS)
        if n_k == 1:
            (m, l, acc), _ = kv_step((m0, l0, a0), (kb[0], vb[0], pb[0]))
        elif runtime_flags.UNROLL_SCANS:
            carry = (m0, l0, a0)
            hi = min(n_k, (i * cq) // ck + (cq + ck - 1) // ck) \
                if (skip and isinstance(i, int)) else n_k
            for j in range(hi):
                carry, _ = kv_step(carry, (kb[j], vb[j], pb[j]))
            m, l, acc = carry
        elif skip:
            hi = jnp.minimum((i * cq) // ck + (cq + ck - 1) // ck, n_k)

            def fori_body(j, carry):
                xs = jax.tree.map(lambda a: a[j], (kb, vb, pb))
                return kv_step(carry, xs)[0]

            m, l, acc = jax.lax.fori_loop(0, hi, fori_body, (m0, l0, a0))
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, pb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,G,cq,hd)
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)  # (B,cq,KV,G,hd)

    if n_q == 1:
        out = one_q_chunk(0)
    else:
        out = _chunk_loop(one_q_chunk, n_q)
        out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, KV, G, hd)
    return out.reshape(B, Sq, H, hd)


def attention_windowed(q, k, v, q_positions, kv_positions, *, window, q_chunk=512):
    """Sliding-window causal attention with banded KV slices: each q-chunk
    only reads KV in [chunk_start - window, chunk_end) so FLOPs are
    O(S · (window + chunk)) rather than O(S²)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if runtime_flags.Q_CHUNK_OVERRIDE:
        q_chunk = runtime_flags.Q_CHUNK_OVERRIDE
    cq = min(q_chunk, Sq)
    if Sq % cq:
        pad = cq - Sq % cq
        qp = jnp.pad(q, [(0, 0), (0, pad), (0, 0), (0, 0)])
        pp = jnp.pad(q_positions, [(0, 0), (0, pad)], constant_values=-1)
        out = attention_windowed(qp, k, v, pp, kv_positions, window=window,
                                 q_chunk=q_chunk)
        return out[:, :Sq]
    qg = _grouped(q, KV)
    n_chunks = Sq // cq
    band = window + cq

    # Front-pad KV by `window` so every band slice is in range.
    pad = [(0, 0), (window, 0), (0, 0), (0, 0)]
    kp = jnp.pad(k, pad)
    vp = jnp.pad(v, pad)
    posp = jnp.pad(kv_positions, [(0, 0), (window, 0)], constant_values=-1)

    def one_chunk(i):
        start = i * cq  # band starts at (chunk_start - window) + window pad
        qs = jax.lax.dynamic_slice_in_dim(qg, i * cq, cq, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_positions, i * cq, cq, axis=1)
        ks = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
        bp = jax.lax.dynamic_slice_in_dim(posp, start, band, axis=1)
        mask = (bp[:, None, :] <= qp[:, :, None]) & (
            bp[:, None, :] > qp[:, :, None] - window) & (bp[:, None, :] >= 0)
        return _sdpa_block(qs, ks, vs, mask, hd)

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        out = _chunk_loop(one_chunk, n_chunks)
        out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, KV, H // KV, hd)
    return out.reshape(B, Sq, H, hd)


# ----------------------------------------------------------------------
# KV caches
# ----------------------------------------------------------------------
class AttnCache(NamedTuple):
    k: jax.Array  # (B, C, KV, hd) — C = full length (global) or window (local)
    v: jax.Array


def cache_template(cfg: ModelConfig, kind: str, batch: int, cache_len: int) -> dict:
    hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads
    C = min(cache_len, cfg.window) if kind == "local" else cache_len
    ax = ("batch", "cache_seq", "kv_heads", None)
    if cfg.kv_cache_dtype == "int8":
        # per-(batch, slot, kv-head) scaled int8 storage: halves the cache
        # footprint (the decode-capacity lever); scales are tiny fp32.
        return {
            "k": ParamSpec((batch, C, kv, hd), ax, "zeros", dtype="int8"),
            "v": ParamSpec((batch, C, kv, hd), ax, "zeros", dtype="int8"),
            "k_scale": ParamSpec((batch, C, kv), ax[:3], "zeros", dtype="float32"),
            "v_scale": ParamSpec((batch, C, kv), ax[:3], "zeros", dtype="float32"),
        }
    return {
        "k": ParamSpec((batch, C, kv, hd), ax, "zeros"),
        "v": ParamSpec((batch, C, kv, hd), ax, "zeros"),
    }


def _quantize_kv(x):
    """x: (..., hd) → (int8, f32 scale over the trailing dim)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def prefill_cache(cfg: ModelConfig, kind: str, k, v, cache_len: int):
    """Build the cache after a full prefill of S tokens (RoPE already applied
    to k).  Local layers keep a ring of the last `window` positions, stored
    at slot = position % window."""
    B, S = k.shape[:2]
    if kind == "local" and cfg.window < cache_len:
        W = cfg.window
        slots = jnp.arange(W)
        # latest position p < S with p % W == slot
        pos = (S - 1) - ((S - 1 - slots) % W)
        cache = {"k": jnp.take(k, pos, axis=1), "v": jnp.take(v, pos, axis=1)}
    elif kind == "local":
        W = min(cfg.window, cache_len)
        if S < W:
            pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
            cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        else:
            cache = {"k": k[:, :W], "v": v[:, :W]}
    else:
        if S < cache_len:
            pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache = {"k": k, "v": v}
    if cfg.kv_cache_dtype == "int8":
        qk, sk = _quantize_kv(cache["k"])
        qv, sv = _quantize_kv(cache["v"])
        cache = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    return cache


def decode_attention(params, cache, x, pos, cfg: ModelConfig, kind: str):
    """One decode step. x: (B, 1, D); pos: (B,) absolute position of the new
    token. Returns (attn_out (B,1,D), new_cache)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(params, x, cfg)
    if cfg.use_rope:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k_new = rope(k_new, pos[:, None], cfg.rope_theta)

    C = cache["k"].shape[1]
    if kind == "local":
        slot = pos % C
    else:
        slot = pos

    def write(c, t, s):
        return jax.lax.dynamic_update_slice(c, t, (s,) + (0,) * (c.ndim - 1))

    int8_kv = cfg.kv_cache_dtype == "int8"
    new_cache = {}
    if int8_kv:
        qk, sk = _quantize_kv(k_new)
        qv, sv = _quantize_kv(v_new)
        new_cache["k"] = jax.vmap(write)(cache["k"], qk, slot)
        new_cache["v"] = jax.vmap(write)(cache["v"], qv, slot)
        new_cache["k_scale"] = jax.vmap(write)(cache["k_scale"], sk, slot)
        new_cache["v_scale"] = jax.vmap(write)(cache["v_scale"], sv, slot)
        new_k = _dequantize_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
        new_v = _dequantize_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        new_k = jax.vmap(write)(cache["k"], k_new, slot)
        new_v = jax.vmap(write)(cache["v"], v_new, slot)
        new_cache = {"k": new_k, "v": new_v}

    # Slot-absolute positions for masking / validity.
    slots = jnp.arange(C)[None, :]
    if kind == "local":
        slot_pos = pos[:, None] - ((pos[:, None] - slots) % C)
    else:
        slot_pos = jnp.broadcast_to(slots, (B, C))
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None]) & (
        slot_pos > pos[:, None] - (cfg.window if kind == "local" else C + 1))

    KV = cfg.n_kv_heads
    qg = q.reshape(B, KV, cfg.n_heads // KV, hd)
    s = jnp.einsum("bngh,bknh->bngk", qg, new_k, preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngk,bknh->bngh", p.astype(new_v.dtype), new_v)
    out = out.reshape(B, 1, cfg.n_heads * hd)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return out, new_cache


def prefill_attention(params, x, positions, cfg: ModelConfig, kind: str,
                      cache_len: Optional[int] = None, cross_kv=None):
    """Full-sequence attention (train or prefill).

    Returns (out (B,S,D), cache_or_None)."""
    q, k, v = _project_qkv(params, x, cfg)
    if cross_kv is None:
        if cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)
        if kind == "local":
            out = attention_windowed(q, k, v, positions, positions, window=cfg.window)
        else:
            # prefill (cache_len set) has no backward pass ⇒ enable the
            # dynamic causal block skip; train keeps the scan path.
            out = attention_full(q, k, v, positions, positions, causal=True,
                                 dynamic_skip=cache_len is not None)
    else:
        ck, cv, cpos = cross_kv
        out = attention_full(q, ck, cv, positions, cpos, causal=False)
        k, v = ck, cv
    out = shard(out, "batch", "seq", "heads", None)
    B, S = x.shape[:2]
    out = out.reshape(B, S, -1)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    cache = None
    if cache_len is not None and cross_kv is None:
        cache = prefill_cache(cfg, kind, k, v, cache_len)
    return out, cache
