"""Mixture-of-Experts FFN with GShard-style one-hot dispatch.

Tokens are reshaped into fixed-size groups and dispatched to experts via
one-hot einsums with a static per-group capacity.  This is the formulation
GSPMD partitions well: expert-sharded weights (E over the `model` axis)
turn the dispatch/combine einsums into all-to-alls.  Capacity overflow
drops tokens (residual passes them through) — standard Switch behaviour.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import ParamSpec


def moe_template(cfg: ModelConfig) -> dict:
    d, e = cfg.d_model, cfg.moe
    return {
        "router": ParamSpec((d, e.n_experts), ("embed_fsdp", None)),
        "wi": ParamSpec((e.n_experts, d, e.d_ff_expert), ("experts", "embed_fsdp", "expert_ff")),
        "wg": ParamSpec((e.n_experts, d, e.d_ff_expert), ("experts", "embed_fsdp", "expert_ff")),
        "wo": ParamSpec((e.n_experts, e.d_ff_expert, d), ("experts", "expert_ff", "embed_fsdp"), "normal_out", 1),
    }


def _capacity(group: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(group * top_k * factor / n_experts)
    return max(4, ((c + 3) // 4) * 4)


def moe_ffn(params, x, cfg: ModelConfig):
    """x: (B, S, D) → (out (B, S, D), aux_loss scalar fp32)."""
    e = cfg.moe
    B, S, D = x.shape
    T = B * S
    g = min(e.group_size, T)
    if T % g:  # pad the flattened token dim to a group multiple
        pad = g - T % g
        xf = jnp.pad(x.reshape(T, D), [(0, pad), (0, 0)])
        out, aux = moe_ffn(params, xf[None], cfg)
        return out[0, :T].reshape(B, S, D), aux
    G = T // g
    E, K = e.n_experts, e.top_k
    C = _capacity(g, K, E, e.capacity_factor)

    xg = x.reshape(G, g, D)
    xg = shard(xg, "batch", None, None)
    logits = jnp.einsum("Ggd,de->Gge", xg, params["router"],
                        preferred_element_type=jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)  # (G, g, E) fp32

    top_gates, top_idx = jax.lax.top_k(gates, K)  # (G, g, K)
    top_gates = top_gates / jnp.sum(top_gates, axis=-1, keepdims=True)

    # Load-balancing aux loss (Switch): E * Σ_e fraction_e · mean_gate_e
    me = jnp.mean(gates, axis=(0, 1))
    one_hot_all = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (G,g,K,E)
    ce = jnp.mean(jnp.sum(one_hot_all, axis=2), axis=(0, 1)) / K
    aux_loss = E * jnp.sum(me * ce)

    # Position of each (token, k) entry within its expert, token-major,
    # k-minor priority (GShard).
    ohf = one_hot_all.reshape(G, g * K, E)
    pos = jnp.cumsum(ohf, axis=1) - ohf  # entries ahead of this one
    pos = jnp.sum(pos * ohf, axis=-1).reshape(G, g, K)  # (G, g, K)
    keep = pos < C

    gate_kept = top_gates * keep  # dropped entries contribute nothing
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    # combine[G,g,E,C] = Σ_k gate · 1[expert] · 1[slot]
    combine = jnp.einsum("GgKE,GgKC->GgEC", one_hot_all * gate_kept[..., None], pos_oh)
    dispatch = (combine > 0).astype(x.dtype)

    expert_in = jnp.einsum("GgEC,Ggd->EGCd", dispatch, xg)
    expert_in = shard(expert_in, "experts", "batch", None, None)
    h = jnp.einsum("EGCd,Edf->EGCf", expert_in, params["wi"])
    hg = jnp.einsum("EGCd,Edf->EGCf", expert_in, params["wg"])
    h = jax.nn.silu(h) * hg
    h = shard(h, "experts", "batch", None, "expert_ff")
    expert_out = jnp.einsum("EGCf,Efd->EGCd", h, params["wo"])
    out = jnp.einsum("GgEC,EGCd->Ggd", combine.astype(x.dtype), expert_out)
    return out.reshape(B, S, D), aux_loss


def moe_ffn_dense_eval(params, x, cfg: ModelConfig):
    """Dropless oracle: every token computed by all experts, weighted by its
    (renormalized) top-k gates.  O(E) FLOPs — for tests only."""
    e = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x, params["router"],
                        preferred_element_type=jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_gates, top_idx = jax.lax.top_k(gates, e.top_k)
    top_gates = top_gates / jnp.sum(top_gates, axis=-1, keepdims=True)
    w = jnp.sum(jax.nn.one_hot(top_idx, e.n_experts, dtype=jnp.float32)
                * top_gates[..., None], axis=-2)  # (B,S,E)
    h = jnp.einsum("bsd,Edf->bsEf", x, params["wi"])
    hg = jnp.einsum("bsd,Edf->bsEf", x, params["wg"])
    h = jax.nn.silu(h) * hg
    o = jnp.einsum("bsEf,Efd->bsEd", h, params["wo"])
    return jnp.einsum("bsE,bsEd->bsd", w.astype(x.dtype), o)
