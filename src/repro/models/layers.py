"""Shared layer primitives: norms, RoPE, MLPs, parameter templates."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


# ----------------------------------------------------------------------
# Parameter templates: shape + logical axes + init rule.  Templates let the
# dry-run build ShapeDtypeStructs and shardings without allocating, and let
# checkpoints be mesh-agnostic.
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | rglru_lambda
    scale_dim: int = -1  # fan-in dim index for normal init scaling
    dtype: Optional[str] = None  # override the tree-wide dtype (e.g. int8 KV)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_map(fn, tree, *rest):
    return jax.tree.map(fn, tree, *rest, is_leaf=is_spec)


def materialize(template, key, dtype):
    """Initialize real parameters from a template tree."""
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def init_one(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "rglru_lambda":
            # Λ init so that a = sigmoid(Λ)^c spreads over (0.9, 0.999)
            u = jax.random.uniform(k, spec.shape, jnp.float32, 0.9, 0.999)
            lam = jnp.log(u ** (-2.0) - 1.0) * 0.5  # inverse of the a(Λ) map
            return lam.astype(dtype)
        fan_in = spec.shape[spec.scale_dim] if spec.shape else 1
        if spec.init == "normal_out":  # residual-out projection: extra-scaled
            std = 0.02 / jnp.sqrt(2.0)
        else:
            std = 1.0 / jnp.sqrt(max(1, fan_in))
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)

    return treedef.unflatten([init_one(s, k) for s, k in zip(leaves, keys)])


def abstract(template, dtype):
    return spec_map(
        lambda s: jax.ShapeDtypeStruct(s.shape,
                                       jnp.dtype(s.dtype) if s.dtype else dtype),
        template)


def axes_tree(template):
    return spec_map(lambda s: s.axes, template)


def stack_specs(template, n: int, axis_name: Optional[str] = "layers"):
    """Add a leading stacked-layers dim to every spec (for scan)."""
    return spec_map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                            s.scale_dim, s.dtype),
        template,
    )


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------
def rmsnorm(x, scale, eps):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return ((h * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, eps):
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
    return (((h - mu) * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def apply_norm(kind, x, scale, eps):
    return rmsnorm(x, scale, eps) if kind == "rms" else layernorm(x, scale, eps)


def norm_template(d: int) -> dict:
    return {"scale": ParamSpec((d,), (None,), "zeros")}


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if positions.ndim == 1:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d, max_scale=10_000.0):
    half = d // 2
    freqs = max_scale ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------
def mlp_template(d: int, f: int, kind: str) -> dict:
    t = {"wo": ParamSpec((f, d), ("ff", "embed_fsdp"), "normal_out", 0)}
    if kind in ("swiglu", "geglu"):
        t["wi"] = ParamSpec((d, f), ("embed_fsdp", "ff"))
        t["wg"] = ParamSpec((d, f), ("embed_fsdp", "ff"))
    else:  # gelu
        t["wi"] = ParamSpec((d, f), ("embed_fsdp", "ff"))
    return t


def mlp_apply(params, x, kind: str):
    h = jnp.einsum("bsd,df->bsf", x, params["wi"])
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, params["wg"])
        act = jax.nn.silu(h) if kind == "swiglu" else jax.nn.gelu(h)
        h = act * g
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "batch", None, "ff")
    return jnp.einsum("bsf,fd->bsd", h, params["wo"])
