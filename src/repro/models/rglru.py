"""RecurrentGemma / Griffin RG-LRU recurrent block.

Recurrence (per channel): a_t = exp(-c · softplus(Λ) · r_t),
h_t = a_t · h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t), with input gate i_t and
recurrence gate r_t.  Full-sequence path uses ``lax.associative_scan``
(log-depth); the TPU Pallas kernel (repro.kernels.rglru_scan) runs a
blocked sequential scan in VMEM.  Decode keeps O(1) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import ParamSpec


def rglru_template(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rglru.width(d)
    cw = cfg.rglru.conv_width
    return {
        "in_x": ParamSpec((d, w), ("embed_fsdp", "rnn_width")),
        "in_gate": ParamSpec((d, w), ("embed_fsdp", "rnn_width")),
        "conv_w": ParamSpec((cw, w), (None, "rnn_width")),
        "conv_b": ParamSpec((w,), ("rnn_width",), "zeros"),
        "w_inp": ParamSpec((w, w), ("rnn_width", None)),
        "b_inp": ParamSpec((w,), ("rnn_width",), "zeros"),
        "w_rec": ParamSpec((w, w), ("rnn_width", None)),
        "b_rec": ParamSpec((w,), ("rnn_width",), "zeros"),
        "lam": ParamSpec((w,), ("rnn_width",), "rglru_lambda"),
        "out": ParamSpec((w, d), ("rnn_width", "embed_fsdp"), "normal_out", 0),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, [(0, 0), (W - 1, 0), (0, 0)])
    return sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b


def _gates(params, xb, cfg):
    c = cfg.rglru.c_exponent
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xb, params["w_rec"]).astype(jnp.float32)
                       + params["b_rec"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xb, params["w_inp"]).astype(jnp.float32)
                       + params["b_inp"].astype(jnp.float32))
    log_a = -c * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)) \
        * (i * xb.astype(jnp.float32))
    return a, b


def rglru_scan_xla(a, b, h0=None, block: int = 512):
    """Linear recurrence h_t = a_t h_{t-1} + b_t over axis 1 (fp32).

    Blocked formulation (mirrors the Pallas kernel): `lax.scan` over
    sequence blocks carrying the boundary state, log-depth doubling scan
    within each block.  A flat `associative_scan` over the full sequence
    materializes log2(S) full-length rounds (the dominant HBM traffic of
    recurrentgemma training at 4k+); blocking caps the round count at
    log2(block) and keeps the working set at block length.
    """
    B, S, W = a.shape
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    if S <= block or S % block:
        def combine(x, y):
            ax, bx = x
            ay, by = y
            return ax * ay, bx * ay + by
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        return h

    nb = S // block
    ab = jnp.moveaxis(a.reshape(B, nb, block, W), 1, 0)
    bb = jnp.moveaxis(b.reshape(B, nb, block, W), 1, 0)

    def body(h_in, xs):
        av, bv = xs  # (B, block, W)
        shift = 1
        while shift < block:  # inclusive doubling scan of affine maps
            a_sh = jnp.concatenate(
                [jnp.ones((B, shift, W), av.dtype), av[:, :-shift]], axis=1)
            b_sh = jnp.concatenate(
                [jnp.zeros((B, shift, W), bv.dtype), bv[:, :-shift]], axis=1)
            bv = b_sh * av + bv
            av = a_sh * av
            shift *= 2
        h = bv + av * h_in[:, None]
        return h[:, -1], h

    _, hs = jax.lax.scan(body, jnp.zeros((B, W), a.dtype), (ab, bb))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, W)


def rglru_block_apply(params, x, cfg: ModelConfig):
    """Full-sequence recurrent block. x: (B,S,D) → (B,S,D)."""
    xb = jnp.einsum("bsd,dw->bsw", x, params["in_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["in_gate"]))
    xb = _causal_conv(xb, params["conv_w"], params["conv_b"])
    xb = shard(xb, "batch", "seq", "rnn_width")
    a, b = _gates(params, xb, cfg)
    h = rglru_scan_xla(a, b).astype(x.dtype)
    y = h * gate
    return jnp.einsum("bsw,wd->bsd", y, params["out"])


def rglru_cache_template(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.rglru.width(cfg.d_model)
    cw = cfg.rglru.conv_width
    return {
        "conv": ParamSpec((batch, cw - 1, w), ("batch", None, "rnn_width"), "zeros"),
        "h": ParamSpec((batch, w), ("batch", "rnn_width"), "zeros"),
    }


def rglru_prefill_cache(params, x, cfg: ModelConfig):
    """Run the full-sequence path AND return the final state as cache."""
    xb = jnp.einsum("bsd,dw->bsw", x, params["in_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["in_gate"]))
    conv_hist = xb[:, -(cfg.rglru.conv_width - 1):]
    xb = _causal_conv(xb, params["conv_w"], params["conv_b"])
    a, b = _gates(params, xb, cfg)
    h = rglru_scan_xla(a, b)
    y = (h.astype(x.dtype)) * gate
    out = jnp.einsum("bsw,wd->bsd", y, params["out"])
    cache = {"conv": conv_hist, "h": h[:, -1].astype(x.dtype)}
    return out, cache


def rglru_decode_step(params, cache, x, cfg: ModelConfig):
    """x: (B,1,D). Returns (out (B,1,D), new_cache)."""
    xb = jnp.einsum("bsd,dw->bsw", x, params["in_x"])[:, 0]  # (B,W)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["in_gate"]))[:, 0]
    hist = jnp.concatenate([cache["conv"], xb[:, None]], axis=1)
    conv = jnp.einsum("bwc,wc->bc", hist, params["conv_w"]) + params["conv_b"]
    new_conv = hist[:, 1:]
    a, b = _gates(params, conv[:, None], cfg)
    h = a[:, 0] * cache["h"].astype(jnp.float32) + b[:, 0]
    y = h.astype(x.dtype) * gate
    out = jnp.einsum("bw,wd->bd", y, params["out"])
    return out[:, None], {"conv": new_conv, "h": h.astype(cache["h"].dtype)}
