"""Deterministic synthetic token pipeline.

Design goals of a production input pipeline, miniaturized:
- deterministic random access: batch at step s is a pure function of
  (seed, step, host) — so restarts resume exactly and any host can
  regenerate any shard (elastic re-sharding needs no data state transfer);
- host sharding: host i of n serves rows i::n of the global batch;
- checkpointable: state is a single integer step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass
class TokenStream:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int, host_id: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Host shard of the global batch at `step`.  The GLOBAL batch is a
        pure function of (seed, step) — independent of the host topology —
        so elastic restarts onto a different host count replay the exact
        same token stream."""
        host = self.host_id if host_id is None else host_id
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        tokens = rng.integers(0, self.vocab_size,
                              size=(self.global_batch, self.seq_len + 1),
                              dtype=np.int32)
        hb = self.host_batch
        shard = tokens[host * hb:(host + 1) * hb]
        return {"tokens": shard[:, :-1], "targets": shard[:, 1:]}

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    def __iter__(self):
        return self

    # -- checkpointing ---------------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.seed, "seed mismatch on restore"
        self.step = int(state["step"])

    def reshard(self, host_id: int, n_hosts: int) -> "TokenStream":
        """Elastic restart onto a different host topology; determinism keeps
        the global stream identical as long as global_batch divides."""
        return TokenStream(self.vocab_size, self.global_batch, self.seq_len,
                           self.seed, host_id, n_hosts, self.step)
