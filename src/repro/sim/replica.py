"""Replicas: FIFO-queued serving endpoints over a heterogeneous pool.

A ``Replica`` models one serving endpoint (the paper's per-model GPU
endpoint, or a TPU slice from ``core/tpu_pool.py``): a single server with
a FIFO queue, a speed factor (heterogeneity), and an optional queue-depth
cap (admission control).  ``ReplicaPool`` routes a selected model to the
least-loaded capable replica and answers the queue-wait estimates
``W_queue(m)`` that the queue-aware policy consumes.

Hot-path representation: the discrete-event engine ``bind()``s the pool
to its SoA request columns at run start, after which queues hold plain
request *indices* (ints into the engine's record arrays) instead of
request objects, and the wait estimate walks an int deque against a
model-id column and a current-μ list — no dict lookups, no attribute
chasing.  Each replica additionally tracks per-model queue counts, so
beyond ``EXACT_WALK_MAX`` queued requests the estimate switches to the
O(n_models) closed form ``Σ counts[m]·μ(m)/speed`` (identical up to
float associativity; the element-order walk is kept below the threshold
so moderate-load seeded runs stay bit-identical to the historical
object walk).  Unbound pools (constructed directly in tests) keep the
legacy object-queue behaviour.

``GaussianServiceModel`` is the ground-truth latency process shared with
the closed-loop simulator: truncated normal per model plus the optional
co-tenant spike process of ``core/simulate.py``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import ProfileStore
from repro.core.zoo import ZooEntry
from repro.router.charging import ChargedWaits

# Queue depth up to which the wait estimate walks the FIFO element by
# element (bit-identical to the historical per-object walk); deeper
# queues use the per-model-count closed form, which differs only by
# float-addition associativity but turns the saturated-load estimate
# from O(depth) into O(n_models).
EXACT_WALK_MAX = 64

# Replica health states (fault injection, ``sim/faults.py``; elastic
# lifecycle, ``sim/elastic.py``): UP and DEGRADED accept new work;
# WARMING is provisioned but still cold-starting (accepts nothing,
# serves nothing — it becomes UP only after ``cold_start_ms``);
# DRAINING finishes its queue but accepts nothing; DOWN serves nothing.
# ``Replica.accepting`` caches the accepts-new-work predicate so the
# wait-column hot path reads one bool.
UP = "up"
DEGRADED = "degraded"
WARMING = "warming"
DRAINING = "draining"
DOWN = "down"
HEALTH_STATES = (UP, DEGRADED, WARMING, DRAINING, DOWN)

_INF = float("inf")


@dataclass
class GaussianServiceModel:
    """True per-model inference latency: N(mu, sigma) truncated at a
    floor, optionally hit by a multiplicative co-tenant spike."""
    truth: Dict[str, ZooEntry]
    spike_prob: float = 0.0
    spike_mult: float = 10.0
    floor_ms: float = 0.05

    # Mid-run latency drift (``sim/faults.py`` LatencyDrift): absolute
    # multipliers vs the seeded truth, keyed by model name.  The shared
    # ZooEntry truth objects are never mutated; empty dicts take the
    # historical branch, so no-drift runs are draw-for-draw identical.
    mu_scale: Dict[str, float] = field(default_factory=dict)
    sigma_scale: Dict[str, float] = field(default_factory=dict)

    def sample(self, rng: np.random.Generator, model: str,
               speed: float = 1.0) -> float:
        e = self.truth[model]
        if self.mu_scale or self.sigma_scale:
            mu = e.mu_ms * self.mu_scale.get(model, 1.0)
            sg = e.sigma_ms * self.sigma_scale.get(model, 1.0)
            t = max(self.floor_ms, rng.normal(mu, sg))
        else:
            t = max(self.floor_ms, rng.normal(e.mu_ms, e.sigma_ms))
        if self.spike_prob > 0 and rng.random() < self.spike_prob:
            t *= self.spike_mult
        return t / speed

    def set_drift(self, model: str, mu_mult: float = 1.0,
                  sigma_mult: float = 1.0) -> None:
        """Apply a latency drift (absolute vs the seeded truth); 1.0
        removes the scale so a fully-recovered process is again the
        branch-free historical sampler."""
        if model not in self.truth:
            raise KeyError(f"unknown model {model!r}")
        if mu_mult == 1.0:
            self.mu_scale.pop(model, None)
        else:
            self.mu_scale[model] = float(mu_mult)
        if sigma_mult == 1.0:
            self.sigma_scale.pop(model, None)
        else:
            self.sigma_scale[model] = float(sigma_mult)


@dataclass
class Replica:
    """One FIFO-queued server.  ``models=()`` means it serves the whole
    zoo (shared endpoint); otherwise only the named models.

    When the owning pool is ``bind()``-ed, ``queue`` holds request
    indices (ints) and ``current`` the in-service request index; unbound
    replicas carry request objects, the legacy interface."""
    name: str
    models: Tuple[str, ...] = ()
    speed: float = 1.0
    max_queue_depth: Optional[int] = None

    queue: Deque = field(default_factory=deque, repr=False)
    current: Optional[object] = field(default=None, repr=False)
    busy_until: float = 0.0
    n_served: int = 0
    busy_ms: float = 0.0
    peak_depth: int = 0

    # Health (fault injection): ``accepting`` caches "takes new work"
    # so the wait-column hot path reads one bool per replica.  ``gen``
    # is the incarnation token: a kill bumps it, invalidating FINISH
    # events issued against the dead incarnation.
    health: str = UP
    accepting: bool = True
    gen: int = 0
    base_speed: Optional[float] = field(default=None, repr=False)

    # Elastic lifecycle cost accounting (``sim/elastic.py``): a replica
    # accrues cost from ``commission_ms`` (0.0 for the static pool the
    # run started with) until ``decommission_ms`` (None = still
    # committed at run end).  ``down_ms_total``/``down_since`` subtract
    # mid-run dead time (kill → recover windows) so the live-window
    # utilization the autoscaler reads is not diluted by intervals a
    # replica could not have served.
    commission_ms: float = 0.0
    decommission_ms: Optional[float] = None
    down_ms_total: float = 0.0
    down_since: Optional[float] = field(default=None, repr=False)

    # SoA binding (set by ReplicaPool.bind); None == legacy object mode.
    _model_of: Optional[Sequence[int]] = field(default=None, repr=False,
                                               init=False)
    _mu: Optional[List[float]] = field(default=None, repr=False, init=False)
    _counts: Optional[List[int]] = field(default=None, repr=False, init=False)

    def serves(self, model: str) -> bool:
        return not self.models or model in self.models

    # -- health transitions (fault injection) ---------------------------
    def kill(self, now: Optional[float] = None) -> None:
        """Hard failure: drop out of service.  The caller (engine FAULT
        handler) reads ``current`` and drains ``queue`` *before* calling
        this, then re-routes the victims; bumping ``gen`` invalidates
        the in-flight FINISH event.  ``now`` (when the caller knows the
        simulation clock) starts the dead-time window that live-window
        utilization subtracts; legacy no-arg calls skip the tracking."""
        self.health = DOWN
        self.accepting = False
        self.gen += 1
        self.current = None
        self.busy_until = 0.0
        if now is not None and self.down_since is None:
            self.down_since = now

    def degrade(self, factor: float) -> None:
        """Slow down by ``factor`` (co-tenant pressure, thermal
        throttling): still serving, still accepting.  Repeated degrades
        compound against the *base* speed, not each other."""
        if self.base_speed is None:
            self.base_speed = self.speed
        self.speed = self.base_speed / factor
        self.health = DEGRADED

    def drain(self) -> None:
        """Stop accepting new work; finish what is queued."""
        self.health = DRAINING
        self.accepting = False

    def recover(self, now: Optional[float] = None) -> None:
        """Back to full speed and accepting (from any state)."""
        if self.base_speed is not None:
            self.speed = self.base_speed
            self.base_speed = None
        self.health = UP
        self.accepting = True
        if now is not None and self.down_since is not None:
            self.down_ms_total += max(0.0, now - self.down_since)
            self.down_since = None

    # -- elastic lifecycle (``sim/elastic.py``) -------------------------
    def start_warming(self, now: float) -> None:
        """Provisioned but cold-starting: committed (accruing cost from
        ``now``) yet serving nothing until :meth:`warm_ready`."""
        self.health = WARMING
        self.accepting = False
        self.commission_ms = now

    def warm_ready(self) -> None:
        """Cold start complete: start accepting.  The caller (engine
        PROVISION handler) checks the incarnation token first, so a
        replica cancelled while warming never flips to UP."""
        if self.health == WARMING:
            self.health = UP
            self.accepting = True

    def decommission(self, now: float) -> None:
        """Leave the pool for good: stop accruing cost at ``now``.  Only
        legal on an idle replica — drain-based scale-in finishes the
        queue first, so no in-flight request is ever lost to a
        decommission."""
        assert self.current is None and not self.queue, \
            f"decommission of non-idle replica {self.name!r}"
        self.health = DOWN
        self.accepting = False
        self.decommission_ms = now

    def committed(self) -> bool:
        """Accruing cost: provisioned (even if still warming or
        draining) and not yet decommissioned/killed."""
        return self.decommission_ms is None and self.health != DOWN

    def alive_ms(self, first_ms: float, last_ms: float) -> float:
        """The committed window overlapped with ``[first_ms, last_ms]``,
        minus mid-run dead time — the denominator for live-window
        utilization and the replica-seconds cost integral.  Static
        always-up replicas report exactly the horizon."""
        start = max(first_ms, self.commission_ms)
        end = last_ms if self.decommission_ms is None \
            else min(last_ms, self.decommission_ms)
        alive = max(0.0, end - start) - self.down_ms_total
        if self.down_since is not None:     # still down at run end
            alive -= max(0.0, end - max(self.down_since, start))
        return max(alive, 0.0)

    def depth(self) -> int:
        return len(self.queue) + (1 if self.current is not None else 0)

    def full(self) -> bool:
        return (self.max_queue_depth is not None
                and self.depth() >= self.max_queue_depth)

    # -- SoA fast path --------------------------------------------------
    def enqueue(self, rid: int, mid: int) -> None:
        """Queue request ``rid`` (model id ``mid``) — bound mode only."""
        self.queue.append(rid)
        self._counts[mid] += 1

    def pop_request(self) -> int:
        """Dequeue the next request index — bound mode only."""
        rid = self.queue.popleft()
        self._counts[self._model_of[rid]] -= 1
        return rid

    def estimated_wait(self, now: float, store: ProfileStore) -> float:
        """Queue-wait estimate using what the router knows: the profile
        store's mean latency per queued model plus the in-flight
        remainder.  This is W_queue(m) for any model routed here."""
        w = max(0.0, self.busy_until - now) if self.current is not None else 0.0
        mu = self._mu
        if mu is not None:
            q = self.queue
            s = self.speed
            if len(q) <= EXACT_WALK_MAX:
                mo = self._model_of
                for rid in q:
                    w += mu[mo[rid]] / s
            else:
                for m, c in enumerate(self._counts):
                    if c:
                        w += c * (mu[m] / s)
            return w
        for req in self.queue:
            w += store[req.model].mu / self.speed
        return w

    def reset(self) -> None:
        self.queue.clear()
        self.current = None
        self.busy_until = 0.0
        self.n_served = 0
        self.busy_ms = 0.0
        self.peak_depth = 0
        self.health = UP
        self.accepting = True
        self.gen = 0
        self.commission_ms = 0.0
        self.decommission_ms = None
        self.down_ms_total = 0.0
        self.down_since = None
        if self.base_speed is not None:
            self.speed = self.base_speed
            self.base_speed = None
        self._model_of = None
        self._mu = None
        self._counts = None


class ReplicaPool:
    def __init__(self, replicas: List[Replica]):
        assert replicas, "need at least one replica"
        self.replicas = list(replicas)
        # model name -> capable replicas (and their pool indices), in
        # pool order (the tie-break order ``min`` preserved
        # historically).  Built on bind(); a None cache falls back to a
        # per-call scan.
        self._cands: Optional[Dict[str, List[Replica]]] = None
        self._cand_idx: Optional[Dict[str, List[int]]] = None
        # Charged-state caches (bind()): model order, candidate index
        # arrays in that order, the speed column, the live μ list.
        self._names: Optional[Tuple[str, ...]] = None
        self._cand_arrays: Optional[List[np.ndarray]] = None
        self._speeds: Optional[np.ndarray] = None
        self._mu_now: Optional[List[float]] = None

    def bind(self, model_names: Sequence[str], model_of: Sequence[int],
             mu_now: List[float]) -> None:
        """Attach the engine's SoA columns for one run: ``model_of`` maps
        request index -> model id (written by the engine as requests are
        routed), ``mu_now`` is the live model-id -> current-μ list the
        engine keeps in sync with the profile store.  Also freezes the
        model -> candidate-replica index (the topology is static within
        a run)."""
        n_models = len(model_names)
        for r in self.replicas:
            r._model_of = model_of
            r._mu = mu_now
            r._counts = [0] * n_models
        self._cands = {}
        self._cand_idx = {}
        for name in model_names:
            ix = [i for i, r in enumerate(self.replicas) if r.serves(name)]
            if not ix:
                raise KeyError(f"no replica serves model {name!r}")
            self._cands[name] = [self.replicas[i] for i in ix]
            self._cand_idx[name] = ix
        self._names = tuple(model_names)
        self._cand_arrays = [np.asarray(self._cand_idx[n], dtype=np.int64)
                             for n in model_names]
        self._speeds = np.array([r.speed for r in self.replicas])
        self._mu_now = mu_now

    def add_replica(self, r: Replica) -> int:
        """Mid-run pool extension (elastic scale-up): append ``r`` and —
        when the pool is bound — splice it into every bind-frozen SoA
        cache (candidate lists/arrays, speed column, per-replica count
        vector) so the wait-column and charged-state hot paths see the
        newcomer without a rebind.  Returns the new pool index."""
        idx = len(self.replicas)
        self.replicas.append(r)
        if self._cands is not None:
            r._model_of = self.replicas[0]._model_of
            r._mu = self._mu_now
            r._counts = [0] * len(self._names)
            for j, name in enumerate(self._names):
                if r.serves(name):
                    self._cands[name].append(r)
                    self._cand_idx[name].append(idx)
                    self._cand_arrays[j] = np.append(self._cand_arrays[j],
                                                     np.int64(idx))
            self._speeds = np.append(self._speeds, r.speed)
        return idx

    def candidates(self, model: str) -> List[Replica]:
        if self._cands is not None:
            try:
                return self._cands[model]
            except KeyError:
                raise KeyError(f"no replica serves model {model!r}")
        out = [r for r in self.replicas if r.serves(model)]
        if not out:
            raise KeyError(f"no replica serves model {model!r}")
        return out

    def best_for(self, model: str, now: float,
                 store: ProfileStore) -> Optional[Replica]:
        """Least-estimated-wait capable *accepting* replica (ties: pool
        order, matching the historical ``min``).  ``None`` when every
        capable replica is down/draining — the caller rejects or
        re-routes."""
        cands = self.candidates(model)
        if len(cands) == 1:
            r = cands[0]
            return r if r.accepting else None
        best = None
        best_w = _INF
        for r in cands:
            if not r.accepting:
                continue
            w = r.estimated_wait(now, store)
            if w < best_w:
                best_w = w
                best = r
        return best

    def queue_wait(self, model: str, now: float,
                   store: ProfileStore) -> float:
        """W_queue(m): wait at the replica that would serve ``model``."""
        return min(r.estimated_wait(now, store)
                   for r in self.candidates(model))

    def wait_columns(self, now: float) -> List[float]:
        """Every replica's wait estimate computed exactly once (the
        estimate inlined — same ops, same floats as ``estimated_wait``)
        — the per-replica column behind both the frozen
        ``waits_by_name`` snapshot and the live ``charged_state``.
        Requires ``bind()`` (the engine's per-run setup)."""
        assert self._cands is not None, "wait_columns requires bind()"
        ws = []
        for r in self.replicas:
            if not r.accepting:
                ws.append(_INF)
                continue
            w = max(0.0, r.busy_until - now) if r.current is not None \
                else 0.0
            q = r.queue
            if q:
                mu, s = r._mu, r.speed
                if len(q) <= EXACT_WALK_MAX:
                    mo = r._model_of
                    for rid in q:
                        w += mu[mo[rid]] / s
                else:
                    for m, c in enumerate(r._counts):
                        if c:
                            w += c * (mu[m] / s)
            ws.append(w)
        return ws

    def charged_state(self, now: float) -> ChargedWaits:
        """The intra-batch charging ledger for one routing batch:
        per-replica wait columns plus the bind-frozen candidate
        topology, speeds and the engine's live μ list — the same floats
        ``waits_by_name`` reduces into its frozen dict, but mutable, so
        the router can charge each admitted pick before judging the
        next request of the batch."""
        assert self._cand_arrays is not None, "charged_state requires bind()"
        return ChargedWaits(self.wait_columns(now), self._cand_arrays,
                            self._speeds, self._mu_now, self._names)

    def waits_by_name(self, now: float, store: ProfileStore
                      ) -> Dict[str, float]:
        """One frozen routing snapshot: :meth:`wait_columns` reduced per
        model over its cached candidate indices — what ``queue_wait``
        would produce per model, without re-walking shared queues once
        per pool member.  Requires ``bind()``."""
        ws = self.wait_columns(now)
        out = {}
        for m, ix in self._cand_idx.items():
            w = ws[ix[0]]
            for j in ix[1:]:
                if ws[j] < w:
                    w = ws[j]
            out[m] = w
        return out

    def reset(self) -> None:
        for r in self.replicas:
            r.reset()
        self._cands = None
        self._cand_idx = None
        self._names = None
        self._cand_arrays = None
        self._speeds = None
        self._mu_now = None


def shared_replicas(n: int = 1, *, speeds: Optional[List[float]] = None,
                    max_queue_depth: Optional[int] = None) -> ReplicaPool:
    """``n`` replicas that each serve every model (shared endpoints)."""
    speeds = speeds or [1.0] * n
    assert len(speeds) == n
    return ReplicaPool([
        Replica(name=f"r{i}", models=(), speed=s,
                max_queue_depth=max_queue_depth)
        for i, s in enumerate(speeds)])


def per_model_replicas(entries: List[ZooEntry], *,
                       replicas_per_model: int = 1,
                       speed: float = 1.0,
                       max_queue_depth: Optional[int] = None) -> ReplicaPool:
    """The paper's topology: a dedicated endpoint per zoo member."""
    out = []
    for e in entries:
        for k in range(replicas_per_model):
            out.append(Replica(name=f"{e.name}/{k}", models=(e.name,),
                               speed=speed, max_queue_depth=max_queue_depth))
    return ReplicaPool(out)
