"""Replicas: FIFO-queued serving endpoints over a heterogeneous pool.

A ``Replica`` models one serving endpoint (the paper's per-model GPU
endpoint, or a TPU slice from ``core/tpu_pool.py``): a single server with
a FIFO queue, a speed factor (heterogeneity), and an optional queue-depth
cap (admission control).  ``ReplicaPool`` routes a selected model to the
least-loaded capable replica and answers the queue-wait estimates
``W_queue(m)`` that the queue-aware policy consumes.

``GaussianServiceModel`` is the ground-truth latency process shared with
the closed-loop simulator: truncated normal per model plus the optional
co-tenant spike process of ``core/simulate.py``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.profiles import ProfileStore
from repro.core.zoo import ZooEntry


@dataclass
class GaussianServiceModel:
    """True per-model inference latency: N(mu, sigma) truncated at a
    floor, optionally hit by a multiplicative co-tenant spike."""
    truth: Dict[str, ZooEntry]
    spike_prob: float = 0.0
    spike_mult: float = 10.0
    floor_ms: float = 0.05

    def sample(self, rng: np.random.Generator, model: str,
               speed: float = 1.0) -> float:
        e = self.truth[model]
        t = max(self.floor_ms, rng.normal(e.mu_ms, e.sigma_ms))
        if self.spike_prob > 0 and rng.random() < self.spike_prob:
            t *= self.spike_mult
        return t / speed


@dataclass
class Replica:
    """One FIFO-queued server.  ``models=()`` means it serves the whole
    zoo (shared endpoint); otherwise only the named models."""
    name: str
    models: Tuple[str, ...] = ()
    speed: float = 1.0
    max_queue_depth: Optional[int] = None

    queue: Deque = field(default_factory=deque, repr=False)
    current: Optional[object] = field(default=None, repr=False)
    busy_until: float = 0.0
    n_served: int = 0
    busy_ms: float = 0.0
    peak_depth: int = 0

    def serves(self, model: str) -> bool:
        return not self.models or model in self.models

    def depth(self) -> int:
        return len(self.queue) + (1 if self.current is not None else 0)

    def full(self) -> bool:
        return (self.max_queue_depth is not None
                and self.depth() >= self.max_queue_depth)

    def estimated_wait(self, now: float, store: ProfileStore) -> float:
        """Queue-wait estimate using what the router knows: the profile
        store's mean latency per queued model plus the in-flight
        remainder.  This is W_queue(m) for any model routed here."""
        w = max(0.0, self.busy_until - now) if self.current is not None else 0.0
        for req in self.queue:
            w += store[req.model].mu / self.speed
        return w

    def reset(self) -> None:
        self.queue.clear()
        self.current = None
        self.busy_until = 0.0
        self.n_served = 0
        self.busy_ms = 0.0
        self.peak_depth = 0


class ReplicaPool:
    def __init__(self, replicas: List[Replica]):
        assert replicas, "need at least one replica"
        self.replicas = list(replicas)

    def candidates(self, model: str) -> List[Replica]:
        out = [r for r in self.replicas if r.serves(model)]
        if not out:
            raise KeyError(f"no replica serves model {model!r}")
        return out

    def best_for(self, model: str, now: float,
                 store: ProfileStore) -> Replica:
        """Least-estimated-wait capable replica (ties: pool order)."""
        return min(self.candidates(model),
                   key=lambda r: r.estimated_wait(now, store))

    def queue_wait(self, model: str, now: float,
                   store: ProfileStore) -> float:
        """W_queue(m): wait at the replica that would serve ``model``."""
        return min(r.estimated_wait(now, store)
                   for r in self.candidates(model))

    def reset(self) -> None:
        for r in self.replicas:
            r.reset()


def shared_replicas(n: int = 1, *, speeds: Optional[List[float]] = None,
                    max_queue_depth: Optional[int] = None) -> ReplicaPool:
    """``n`` replicas that each serve every model (shared endpoints)."""
    speeds = speeds or [1.0] * n
    assert len(speeds) == n
    return ReplicaPool([
        Replica(name=f"r{i}", models=(), speed=s,
                max_queue_depth=max_queue_depth)
        for i, s in enumerate(speeds)])


def per_model_replicas(entries: List[ZooEntry], *,
                       replicas_per_model: int = 1,
                       speed: float = 1.0,
                       max_queue_depth: Optional[int] = None) -> ReplicaPool:
    """The paper's topology: a dedicated endpoint per zoo member."""
    out = []
    for e in entries:
        for k in range(replicas_per_model):
            out.append(Replica(name=f"{e.name}/{k}", models=(e.name,),
                               speed=speed, max_queue_depth=max_queue_depth))
    return ReplicaPool(out)
