"""Elastic replica lifecycle on the event queue: cold-start
provisioning, drain-based scale-in, and mid-run controllers.

PR 7 made replica *failure* a first-class event on the engine's
``EventQueue`` (kill/degrade/drain/recover with incarnation tokens);
this module is the symmetric robustness story for *capacity*.  Instead
of the epoch-boundary ``QueueTargetAutoscaler`` resizing the pool from
outside the engine — instantaneous, free, and blind to anything shorter
than an epoch — the engine itself runs a controller tick every
``control_interval_ms`` (a CONTROL event), reads one window of
telemetry (windowed ``Router.stats()`` deltas plus queue-wait
readings), and acts on its own queue:

- **scale-up** pushes a PROVISION event: each new replica is born in
  the ``WARMING`` health state (not accepting — its wait column is
  ``inf``, so the router never routes to it) and flips to ``UP`` only
  after ``cold_start_ms``.  Capacity is paid for from commission time
  but delivers nothing until the cold start completes — the realistic
  provisioning delay the paper's static-capacity assumption hides.
- **scale-in** reuses the fault machinery's ``drain`` state: the victim
  stops accepting, finishes every queued request, and only then
  decommissions (stops accruing cost).  Zero in-flight requests are
  lost, by construction.
- a replica cancelled *while still warming* has its incarnation token
  bumped, orphaning the pending ready event — it never serves.

Three controller kinds share one interface (``target(n, reading)`` —
the desired committed replica count, deterministic and draw-free so
seeded runs stay reproducible):

- ``step``: the ``QueueTargetAutoscaler`` thresholds verbatim, applied
  per tick instead of per epoch — the degenerate
  ``control_interval_ms == 0`` scenario path *is* the old epoch
  autoscaler, golden-pinned.
- ``proportional``: HPA-style — desired ≈ ``ceil(n · wait/target)``,
  so a 10× queue-wait overshoot is answered in one tick instead of
  one step per window; scale-in stays hysteretic (one replica per
  comfortable tick).
- ``cost_weighted``: a replica-second has a price
  (``cost_per_replica_s``), so scale-up must clear a higher bar (two
  consecutive hot windows, ramp capped at ``step`` per tick) and
  scale-in a lower one (idle threshold relaxed with the price) — the
  cheap-and-slightly-late end of the SLA-vs-cost frontier.

``benchmarks/elastic_controllers.py`` sweeps controller kind ×
``target_queue_ms`` × ``cold_start_ms`` into that frontier.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

CONTROLLER_KINDS = ("step", "proportional", "cost_weighted")


@dataclass(frozen=True)
class ElasticConfig:
    """Engine-side elastic lifecycle knobs (the scenario layer compiles
    ``AutoscalerSpec`` into one of these when ``control_interval_ms``
    is positive)."""
    kind: str = "step"
    control_interval_ms: float = 1000.0
    cold_start_ms: float = 0.0
    target_queue_ms: float = 50.0
    max_shed_rate: float = 0.02
    max_fallback_rate: float = 0.25
    min_replicas: int = 1
    max_replicas: int = 8
    step: int = 1
    low_utilization: float = 0.3
    cost_per_replica_s: float = 0.0
    # Consecutive pressure windows before scale-up acts.  A one-window
    # control reading is a handful of requests at low load — one request
    # queued behind a single slow inference trips any tight queue target
    # — so a transient never buys capacity; 1 restores act-immediately.
    confirm_windows: int = 2

    def __post_init__(self):
        if self.kind not in CONTROLLER_KINDS:
            raise ValueError(f"controller kind must be one of "
                             f"{CONTROLLER_KINDS}, got {self.kind!r}")
        if self.control_interval_ms <= 0.0:
            raise ValueError("control_interval_ms must be positive "
                             "(0 means the epoch-boundary path — build "
                             "no ElasticConfig at all)")
        if self.cold_start_ms < 0.0:
            raise ValueError("cold_start_ms must be non-negative")
        if self.target_queue_ms <= 0.0:
            raise ValueError("target_queue_ms must be positive")
        if not 1 <= self.min_replicas <= self.max_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        if self.step < 1:
            raise ValueError("step must be >= 1")
        if self.cost_per_replica_s < 0.0:
            raise ValueError("cost_per_replica_s must be non-negative")
        if self.confirm_windows < 1:
            raise ValueError("confirm_windows must be >= 1")


@dataclass(frozen=True)
class ControlReading:
    """One control window's telemetry, as the engine's tick hands it to
    a controller: the queue-wait signal (max of the window's observed
    service-start waits and the instantaneous backlog estimate — the
    observed mean alone lags a load step by a queue's length), windowed
    router shed/fallback rates, and the busy fraction of serving
    capacity over the window."""
    mean_queue_wait_ms: float = 0.0
    shed_rate: float = 0.0
    fallback_rate: float = 0.0
    utilization: float = 0.0
    n_routed: int = 0


class _BaseController:
    """The confirm-and-act shell every controller kind shares: pressure
    (wait over target, or shed/fallback over their caps) must persist
    for ``confirm_windows`` consecutive readings before scale-up acts —
    a one-window reading at low load is a handful of requests, and one
    of them queued behind a single slow inference trips any tight
    target.  Scale-in carries its own hysteresis (each kind's ``_idle``
    test) and acts immediately: reclaiming an idle replica late only
    costs replica-seconds, never SLA."""

    def __init__(self, cfg: ElasticConfig):
        self.cfg = cfg
        self._hot = 0

    def _confirm(self) -> int:
        return self.cfg.confirm_windows

    def _pressure(self, r: ControlReading) -> bool:
        cfg = self.cfg
        return (r.mean_queue_wait_ms > cfg.target_queue_ms
                or r.shed_rate > cfg.max_shed_rate
                or r.fallback_rate > cfg.max_fallback_rate)

    def target(self, n: int, r: ControlReading) -> int:
        if self._pressure(r):
            self._hot += 1
            if self._hot < self._confirm():
                return n
            return min(max(self._up(n, r), n + 1), self.cfg.max_replicas)
        self._hot = 0
        if self._idle(r):
            return max(self._down(n), self.cfg.min_replicas)
        return n


class StepController(_BaseController):
    """``QueueTargetAutoscaler``'s thresholds, per tick: up by ``step``
    when the window missed its queue target, down by ``step`` only when
    comfortably idle — hysteresis so the pool does not flap."""

    def _up(self, n: int, r: ControlReading) -> int:
        return n + self.cfg.step

    def _idle(self, r: ControlReading) -> bool:
        cfg = self.cfg
        return (r.shed_rate == 0.0
                and r.mean_queue_wait_ms < 0.25 * cfg.target_queue_ms
                and r.utilization < cfg.low_utilization)

    def _down(self, n: int) -> int:
        return n - self.cfg.step


class ProportionalController(_BaseController):
    """HPA-style proportional scaling: desired ≈
    ``ceil(n · wait/target)``, so the answer to a K× overshoot is K×
    the capacity in ONE confirmed tick.  Shedding with a low wait still
    forces at least one step up (a shed request never queued, so it
    left no wait signal).  Scale-in stays one replica per comfortable
    tick — the asymmetry is deliberate: under-capacity costs SLA misses
    now, over-capacity only costs replica-seconds."""

    def _up(self, n: int, r: ControlReading) -> int:
        ratio = r.mean_queue_wait_ms / self.cfg.target_queue_ms
        return int(math.ceil(n * max(ratio, 1.0)))

    def _idle(self, r: ControlReading) -> bool:
        cfg = self.cfg
        return (r.mean_queue_wait_ms < 0.25 * cfg.target_queue_ms
                and r.shed_rate == 0.0
                and r.utilization < cfg.low_utilization)

    def _down(self, n: int) -> int:
        return n - 1


class CostWeightedController(_BaseController):
    """Proportional control with a price on replica-seconds: a positive
    ``cost_per_replica_s`` raises the scale-up bar (at least two
    confirmed hot windows) and caps the ramp at ``step`` per tick,
    while scale-in triggers at a relaxed idle threshold that grows with
    the price — the cheap-and-slightly-late end of the SLA-vs-cost
    frontier.  With a zero price it is a capped-ramp proportional
    controller."""

    def __init__(self, cfg: ElasticConfig):
        super().__init__(cfg)
        self._patience = max(cfg.confirm_windows,
                             2 if cfg.cost_per_replica_s > 0.0 else 1)
        self._idle_util = min(1.0, cfg.low_utilization
                              * (1.0 + cfg.cost_per_replica_s))

    def _confirm(self) -> int:
        return self._patience

    def _up(self, n: int, r: ControlReading) -> int:
        ratio = r.mean_queue_wait_ms / self.cfg.target_queue_ms
        return min(int(math.ceil(n * max(ratio, 1.0))), n + self.cfg.step)

    def _idle(self, r: ControlReading) -> bool:
        cfg = self.cfg
        return (r.mean_queue_wait_ms < 0.5 * cfg.target_queue_ms
                and r.shed_rate == 0.0
                and r.utilization < self._idle_util)

    def _down(self, n: int) -> int:
        return n - self.cfg.step


def make_controller(cfg: ElasticConfig):
    """Controller factory: ``cfg.kind`` → a fresh controller instance
    (cost_weighted is stateful — never share one across runs)."""
    if cfg.kind == "step":
        return StepController(cfg)
    if cfg.kind == "proportional":
        return ProportionalController(cfg)
    return CostWeightedController(cfg)
