"""Backwards-compatibility shim: the queue-aware machinery is now part
of the substrate-independent router layer, ``repro.router.queueaware``.
Import from there (or from ``repro.router``) in new code."""
from repro.router.queueaware import (QueueAwareSelector, WQueueFn,
                                     queue_aware_budget, shifted_store)

__all__ = ["QueueAwareSelector", "WQueueFn", "queue_aware_budget",
           "shifted_store"]
