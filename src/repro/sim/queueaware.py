"""Deprecated location: the queue-aware machinery is part of the
substrate-independent router layer, ``repro.router.queueaware``.
Importing this module works but warns; new code should import from
``repro.router.queueaware`` (or ``repro.router``).
"""
import warnings

from repro.router.queueaware import (QueueAwareSelector, WQueueFn,
                                     queue_aware_budget, shifted_store)

warnings.warn(
    "repro.sim.queueaware is deprecated; import from "
    "repro.router.queueaware (or repro.router) instead",
    DeprecationWarning, stacklevel=2)

__all__ = ["QueueAwareSelector", "WQueueFn", "queue_aware_budget",
           "shifted_store"]
