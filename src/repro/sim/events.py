"""Discrete-event core: a time-ordered event queue with stable FIFO
tie-breaking.

Every simulation entity (arrival generator, replica, network) interacts
through this queue only; handlers never advance time themselves.  Ties
are broken by insertion order (monotonic sequence number) so runs are
bit-deterministic under a fixed seed regardless of heap internals.
"""
from __future__ import annotations

import heapq
from typing import Any, List, NamedTuple

# Event kinds (request lifecycle: uplink -> queue -> inference -> downlink).
ARRIVAL = "arrival"    # request leaves the device; uplink transfer starts
ENQUEUE = "enqueue"    # input arrived at the server; select model + queue
FINISH = "finish"      # inference finished on a replica
DEPART = "depart"      # downlink done; response reached the device
# Environment events (not tied to one request): replica lifecycle faults
# and ground-truth drift, scheduled on the same queue (``sim/faults.py``).
FAULT = "fault"
# Elastic replica lifecycle (``sim/elastic.py``): PROVISION carries both
# halves of a scale-up — ``("create", count)`` materializes replicas in
# the WARMING state, ``("ready", replica, gen)`` flips one to UP after
# its cold start (the ``gen`` token orphans readies for replicas that
# were cancelled while warming).  CONTROL is the mid-run controller
# tick, rescheduling itself every ``control_interval_ms`` while
# requests remain outstanding.
PROVISION = "provision"
CONTROL = "control"


class Event(NamedTuple):
    """Heap record.  A NamedTuple compares field-by-field in C — the
    unique ``seq`` always breaks ``time`` ties before ``kind``/``data``
    are ever reached, preserving the FIFO tie-break while keeping the
    heap's comparison off the Python bytecode path."""
    time: float
    seq: int
    kind: str
    data: Any = None


class EventQueue:
    def __init__(self) -> None:
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, data: Any = None) -> Event:
        ev = Event(time=float(time), seq=self._seq, kind=kind, data=data)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Event:
        """Next event without removing it (the batching lookahead)."""
        return self._heap[0]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
