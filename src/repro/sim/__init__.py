"""Discrete-event serving simulation for ModiPick at production scale.

The paper (ModiPick: SLA-aware Accuracy Optimization For Mobile Deep
Inference) evaluates model selection with a single-request closed loop
(§4).  This package generalises that loop into an event-driven serving
simulator — concurrent traffic, FIFO queues, heterogeneous replicas —
so selection can be studied in the regime where queueing delay, not
network jitter, dominates latency variability.

Paper-section → code map:

- §3.1 mobile inference lifecycle (uplink → inference → downlink):
  ``engine.ServingSimulator`` request lifecycle events
  (``events.ARRIVAL/ENQUEUE/FINISH/DEPART``), plus the FIFO-wait stage
  the paper's single-request loop cannot express.
- §3.2 Eq. 1 budget ``T_sla - 2*T_input``: ``core.policy.budget``;
  the queue-aware generalisation ``T_sla - 2*T_input - W_queue(m)`` is
  ``repro.router.queueaware``.
- §3.3 three-stage selection + EWMA profiles + cold-model refresh:
  unchanged in ``core.policy`` / ``core.profiles``; the engine feeds
  observed inference latency and queue waits back into the store.
- Request routing — admission, budget math, selection — is the unified
  ``repro.router.Router``; the engine groups same-timestamp ENQUEUEs
  (plus an optional lookahead window) into one ``route_batch_arrays``
  call with intra-batch load charging (``router.charging``).
- §4 closed-loop evaluation: ``arrivals.ClosedLoopArrivals`` over a
  single shared replica — ``core.simulate.Simulator`` is now a thin
  wrapper that replays the paper's loop draw-for-draw.
- Beyond-paper: ``arrivals.PoissonArrivals`` / ``TraceArrivals`` open
  loops, ``replica.per_model_replicas`` (endpoint-per-model topology),
  admission control via ``Replica.max_queue_depth``, and
  ``engine.rate_sweep`` for SLA-attainment-vs-load curves
  (``benchmarks/load_sweep.py``).
"""
from repro.router.queueaware import (QueueAwareSelector, queue_aware_budget,
                                     shifted_store)
from repro.sim.arrivals import (ArrivalProcess, ClosedLoopArrivals,
                                PoissonArrivals, TraceArrivals, burst_trace,
                                diurnal_trace)
from repro.sim.elastic import (CONTROLLER_KINDS, ControlReading,
                               ElasticConfig, make_controller)
from repro.sim.engine import (LoadSimResult, ServingSimulator, SimRequest,
                              rate_sweep)
from repro.sim.events import (ARRIVAL, CONTROL, DEPART, ENQUEUE, FAULT,
                              FINISH, PROVISION, EventQueue)
from repro.sim.faults import (FaultEvent, LatencyDrift, NetworkDrift,
                              ReplicaFault, schedule_faults)
from repro.sim.replica import (DEGRADED, DOWN, DRAINING, HEALTH_STATES, UP,
                               WARMING, GaussianServiceModel, Replica,
                               ReplicaPool, per_model_replicas,
                               shared_replicas)

__all__ = [
    "ArrivalProcess", "ClosedLoopArrivals", "PoissonArrivals",
    "TraceArrivals", "burst_trace", "diurnal_trace", "LoadSimResult",
    "ServingSimulator", "SimRequest",
    "rate_sweep", "ARRIVAL", "CONTROL", "DEPART", "ENQUEUE", "FAULT",
    "FINISH", "PROVISION", "EventQueue",
    "FaultEvent", "LatencyDrift", "NetworkDrift", "ReplicaFault",
    "schedule_faults",
    "QueueAwareSelector", "queue_aware_budget", "shifted_store",
    "GaussianServiceModel", "Replica", "ReplicaPool", "per_model_replicas",
    "shared_replicas",
    "UP", "DEGRADED", "WARMING", "DRAINING", "DOWN", "HEALTH_STATES",
    "CONTROLLER_KINDS", "ControlReading", "ElasticConfig",
    "make_controller",
]
