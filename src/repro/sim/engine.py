"""The discrete-event serving simulator.

Request lifecycle (all times ms):

    ARRIVAL ── uplink (T_input) ──▶ ENQUEUE ── FIFO wait ──▶ service
            ── inference ──▶ FINISH ── downlink (T_input) ──▶ DEPART

At ENQUEUE the engine hands the request to the unified
``repro.router.Router`` — admission verdict, budget math and model
selection all live there.  Consecutive same-timestamp ENQUEUE events
(plus an optional ``batch_window_ms`` speculative lookahead) are grouped
into ONE ``route_batch_arrays`` call: budget/class columns in, decision
columns out, no per-request objects on the hot path.  A singleton batch
takes the scalar selection route, which is draw-for-draw identical to
the historical per-request call — seeded runs with continuous
(never-colliding) event times are bit-identical to the pre-router
engine.  Multi-request batches are routed with intra-batch load
charging by default (``charge_batches=True``): the engine hands the
router its live per-replica wait columns
(``ReplicaPool.charged_state``) and each admitted pick's μ is charged
to its chosen replica before the next request of the batch is judged,
so simultaneous bursts spread across the pool instead of piling onto
one stale-idle model; the charged replica is also where the engine
places the request.  ``charge_batches=False`` restores the historical
one-frozen-snapshot batch semantics.  Queue-aware mode presents the
policy with per-model budgets ``T_sla - 2*T_input - W_queue(m)`` via
the router's shifted store view.  The admitted request joins the FIFO
of its replica, and — exactly like the live serving path — the profile
store receives the *inference* latency at FINISH and the observed queue
wait at service start (telemetry mirroring ``serving/batcher.py``).

Hot-path representation (the million-request regime): per-request state
lives in preallocated structure-of-arrays columns indexed by request id
— no per-request dataclass is ever constructed inside the event loop.
Replica FIFOs hold request indices (``sim/replica.py`` bound mode), the
per-batch ``W_queue`` snapshot computes each replica's wait once, and
per-request SLA/class assignments are materialized into columns up
front (they never touch the RNG, so labelled runs stay draw-for-draw
identical).  ``completed_requests``/``rejected_requests`` materialize
:class:`SimRequest` views lazily from the columns for inspection;
``_summarise`` and the per-class slices are vectorized reductions over
the same columns.

Per-request SLAs are first-class: ``run(..., sla_for=...)`` assigns each
request its own ``t_sla_ms`` (heterogeneous mixes become one more column
of the batched budget vector) and attainment is scored per request.

Driven by ``ClosedLoopArrivals`` over a single shared replica this
engine replays the paper's §4 closed loop draw-for-draw —
``core/simulate.Simulator`` is now a thin wrapper around it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.core.policy import Policy
from repro.core.profiles import ProfileStore
from repro.core.zoo import ZooEntry, make_store, true_profiles
from repro.router import AdmissionController, Router
from repro.router.retry import RetryPolicy
from repro.sim.arrivals import ArrivalProcess, ClosedLoopArrivals
from repro.sim.elastic import ControlReading, ElasticConfig, make_controller
from repro.sim.events import (ARRIVAL, CONTROL, DEPART, ENQUEUE, FAULT,
                              FINISH, PROVISION, EventQueue)
from repro.sim.faults import (FaultEvent, LatencyDrift, NetworkDrift,
                              ReplicaFault, schedule_faults)
from repro.sim.replica import (DEGRADED, UP, WARMING, GaussianServiceModel,
                               Replica, ReplicaPool, shared_replicas)


@dataclass
class SimRequest:
    rid: int
    arrival_ms: float
    t_input_ms: float = 0.0
    t_sla_ms: float = 0.0
    sla_class: str = ""
    model: str = ""
    replica: str = ""
    fallback: bool = False
    rejected: bool = False
    reject_reason: str = ""
    enqueue_ms: float = 0.0
    service_start_ms: float = 0.0
    service_ms: float = 0.0
    finish_ms: float = 0.0
    depart_ms: float = 0.0
    retries: int = 0          # recovery re-placements (attempts - 1)

    @property
    def queue_wait_ms(self) -> float:
        return self.service_start_ms - self.enqueue_ms

    @property
    def e2e_ms(self) -> float:
        # Component sum (not event-time subtraction): uplink + FIFO wait
        # + inference + downlink.  Bit-identical to the legacy closed
        # loop's ``2*T_input + T_inf`` at zero queue wait.
        return 2.0 * self.t_input_ms + self.queue_wait_ms + self.service_ms


class _Columns:
    """Preallocated SoA record arrays for one run's request state.
    Index == request id; every field of the historical ``SimRequest``
    dataclass is one contiguous column."""

    __slots__ = ("arrival", "t_input", "t_sla", "enqueue", "sstart",
                 "service", "finish", "depart", "model", "replica",
                 "cls", "icls", "fallback", "rejected", "reason", "retries")

    def __init__(self, n: int):
        z = lambda dt: np.zeros(n, dtype=dt)
        self.arrival = z(np.float64)
        self.t_input = z(np.float64)
        self.t_sla = z(np.float64)
        self.enqueue = z(np.float64)
        self.sstart = z(np.float64)
        self.service = z(np.float64)
        self.finish = z(np.float64)
        self.depart = z(np.float64)
        self.model = np.full(n, -1, dtype=np.int32)     # model id, -1 = none
        self.replica = np.full(n, -1, dtype=np.int32)   # pool index
        self.cls = z(np.int32)                          # class-label code
        self.icls = np.full(n, -1, dtype=np.int32)      # premodel input class
        self.fallback = z(bool)
        self.rejected = z(bool)
        self.reason = z(np.int16)                       # reject-reason code
        self.retries = z(np.int16)                      # recovery re-placements


@dataclass
class LoadSimResult:
    policy: str
    t_sla: float
    n_arrived: int
    n_completed: int
    n_rejected: int
    sla_attainment: float        # met / arrived (rejections are misses)
    mean_accuracy: float         # over completed requests
    mean_latency: float          # e2e ms over completed
    p50_latency: float
    p99_latency: float
    mean_queue_wait: float
    p99_queue_wait: float
    peak_queue_depth: int
    model_usage: Dict[str, float]          # fraction of completed
    replica_utilization: Dict[str, float]  # busy time / horizon
    horizon_ms: float = 0.0
    # Per-SLA-class slice (populated when any request carried a class
    # label): class -> {n_arrived, n_rejected, attainment, accuracy,
    # shed_rate, mean_latency}.  Attainment counts rejections as misses,
    # exactly like the run-level number.
    per_class: Dict[str, Dict[str, float]] = field(default_factory=dict)
    # Recovery re-placements across all requests (replica failure or
    # deadline-overrun hedges that found a viable fallback) — 0 for
    # fault-free runs.
    n_retries: int = 0
    # Tail percentiles between the median and the p99 (the tail-SLA
    # study's operating point); defaulted so positional constructions
    # and serialized results predating them keep working.
    p95_latency: float = 0.0
    p95_queue_wait: float = 0.0
    # Elastic lifecycle cost accounting: committed replica time
    # integrated over the horizon (seconds — the frontier's cost axis;
    # a static pool reports exactly n × horizon), provision and
    # drain-decommission counts, and utilization normalized by each
    # replica's *alive* window instead of the whole horizon (the
    # scale-in guard's undiluted signal — identical to the
    # replica_utilization mean on static fault-free pools).
    replica_seconds: float = 0.0
    mean_live_utilization: float = 0.0
    n_provisioned: int = 0
    n_decommissioned: int = 0

    @property
    def violation_rate(self) -> float:
        return 1.0 - self.sla_attainment


class ServingSimulator:
    """Event-driven serving over a pool of heterogeneous replicas."""

    def __init__(self, entries: Sequence[ZooEntry], network: NetworkModel,
                 replicas: Optional[Union[ReplicaPool, List[Replica]]] = None,
                 *, seed: int = 0, alpha: float = 0.1, cold_age: int = 500,
                 cold_probe: bool = True, spike_prob: float = 0.0,
                 spike_mult: float = 10.0, queue_aware: bool = False,
                 admission: Optional[AdmissionController] = None,
                 batch_window_ms: float = 0.0,
                 backend: Optional[str] = None,
                 charge_batches: bool = True,
                 faults: Sequence[FaultEvent] = (),
                 retry: Optional[RetryPolicy] = None,
                 elastic: Optional[ElasticConfig] = None):
        self.entries = list(entries)
        self.network = network
        if replicas is None:
            replicas = shared_replicas(1)
        self.pool = (replicas if isinstance(replicas, ReplicaPool)
                     else ReplicaPool(replicas))
        self.seed = seed
        self.alpha = alpha
        self.cold_age = cold_age
        self.cold_probe = cold_probe
        self.spike_prob = spike_prob
        self.spike_mult = spike_mult
        self.queue_aware = queue_aware
        self.admission = admission
        # policy_vec backend override for batched route_batch selection
        self.backend = backend
        # Speculative lookahead for route_batch grouping: consecutive
        # ENQUEUE events within this window of the first one are routed
        # together against one queue snapshot.  0.0 batches only exact
        # timestamp ties (simultaneous arrivals), which keeps runs with
        # continuous event times bit-identical to per-request routing.
        self.batch_window_ms = batch_window_ms
        # Intra-batch load charging (default): each admitted pick's μ is
        # charged to its chosen replica before the next request of the
        # batch is judged, so simultaneous bursts don't pile onto one
        # idle-looking model off a stale W_queue snapshot.  False keeps
        # the historical one-snapshot batch semantics (the ablation
        # baseline, and the mode pinned by pre-charging goldens).
        self.charge_batches = charge_batches
        # Fault injection (``sim/faults.py``): environment events pushed
        # onto the run's queue; () keeps the fair-weather world and the
        # seeded goldens bit-identical.  ``retry`` arms the recovery
        # path (re-route on replica failure / deadline overrun); None
        # means a lost request is simply rejected.
        self.faults = tuple(faults)
        self.retry = retry
        # Elastic replica lifecycle (``sim/elastic.py``): a mid-run
        # controller ticking on the event queue, provisioning WARMING
        # replicas and drain-decommissioning idle ones.  None keeps the
        # static pool and every seeded golden bit-identical (the
        # controller path is draw-free, but None skips it entirely).
        self.elastic = elastic
        self._n_provisioned = 0
        self._n_decommissioned = 0
        # The constructed pool size: run() truncates back to it so
        # replicas provisioned by a previous run never leak into the
        # next one (pool.reset() alone would resurrect them as UP).
        self._base_pool_n = len(self.pool.replicas)
        self.router: Optional[Router] = None  # built per run()
        # Post-run SoA state (lazy SimRequest materialization).
        self._cols: Optional[_Columns] = None
        self._completed_rids: List[int] = []
        self._rejected_rids: List[int] = []
        self._model_names: List[str] = []
        self._replica_names: List[str] = []
        self._class_labels: List[str] = [""]
        self._reasons: List[str] = [""]
        self._completed_objs: Optional[List[SimRequest]] = None
        self._rejected_objs: Optional[List[SimRequest]] = None

    @classmethod
    def from_scenario(cls, scenario, *,
                      n_replicas: Optional[int] = None) -> "ServingSimulator":
        """Adapter: build an engine from a declarative
        :class:`repro.scenario.Scenario` (``n_replicas`` overrides the
        deployment's replica count — the autoscaler knob)."""
        from repro.scenario.build import build_engine
        return build_engine(scenario, n_replicas=n_replicas)

    # ------------------------------------------------------------------
    def run(self, policy: Policy, t_sla: float,
            n_requests: int = 10_000,
            arrivals: Optional[ArrivalProcess] = None,
            warm: bool = True,
            store: Optional[ProfileStore] = None,
            sla_for: Optional[Callable[[int], float]] = None,
            class_for: Optional[Callable[[int], str]] = None,
            extra_input_for=None,
            feature_for=None,
            premodel=None,
            service_scale_for=None
            ) -> LoadSimResult:
        """Simulate ``n_requests``.  ``sla_for(rid)`` (optional) assigns
        per-request SLAs; ``t_sla`` remains the reporting label and the
        default for requests without an override.  ``class_for(rid)``
        (optional) labels each request with an SLA class — the label
        rides ``InferenceRequest.sla_class`` into class-aware admission
        and slices the summary's ``per_class`` rows.  Both are
        materialized into SoA columns before the event loop starts
        (batched, in rid order); they never touch the RNG, so labelled
        runs stay draw-for-draw identical to unlabelled ones under the
        same seed.

        ``extra_input_for`` (optional; a ``(n,)`` array or an
        ``rid -> ms`` callable) adds a deterministic per-request
        constant to the *sampled* uplink time — the fleet layer's
        cross-cell spill penalty (half the inter-cell RTT on each
        direction, so ``2·T_input`` grows by exactly ``RTT_xcell`` and
        every downstream budget — admission, queue-aware selection,
        SLA scoring — judges the spilled request honestly).  Applied
        after the network draw, so the RNG stream is untouched and
        ``None`` (or all-zero) runs are bit-identical to the
        historical engine.

        The premodel hooks (all optional, all RNG-neutral):
        ``feature_for`` (an ``(n, d)`` array or an ``rid -> features``
        callable) attaches cheap request features, materialized into a
        column before the loop; ``premodel`` (an object with
        ``classify``/``update``) maps them to input-class ids at
        ENQUEUE, flips the store's class cursor for the selection, and
        attributes the FINISH latency observation to the request's
        class (the ``store`` must then be a
        ``premodel.conditional.ConditionalProfileStore``);
        ``service_scale_for`` (an ``(n,)`` array or callable) multiplies
        the *sampled* inference time by a per-request constant — the
        ground-truth easy/hard input effect, applied after the draw so
        ``None`` (or all-ones) runs are bit-identical."""
        arrivals = arrivals or ClosedLoopArrivals()
        rng = np.random.default_rng(self.seed)
        store = store or make_store(self.entries, alpha=self.alpha,
                                    cold_age=self.cold_age, warm=warm)
        truth = true_profiles(self.entries)
        svc = GaussianServiceModel(truth, spike_prob=self.spike_prob,
                                   spike_mult=self.spike_mult)
        # trace_detail=False: the event loop consumes only variant +
        # fallback, so decisions skip stage-tuple materialization.
        router = Router(store, policy, admission=self.admission,
                        queue_aware=self.queue_aware, backend=self.backend,
                        trace_detail=False)
        self.router = router
        del self.pool.replicas[self._base_pool_n:]
        self.pool.reset()

        n = n_requests
        names = list(truth)
        model_ids = {nm: i for i, nm in enumerate(names)}
        profiles = [store.profiles[nm] for nm in names]
        cols = _Columns(n)
        # Batched SLA/class materialization (RNG-free, rid order).
        if sla_for is None:
            cols.t_sla.fill(t_sla)
        else:
            cols.t_sla[:] = [float(sla_for(i)) for i in range(n)]
        labels: List[str] = [""]
        if class_for is not None:
            code_of: Dict[str, int] = {"": 0}
            cls_col = cols.cls
            for i in range(n):
                lab = str(class_for(i))
                code = code_of.get(lab)
                if code is None:
                    code = code_of[lab] = len(labels)
                    labels.append(lab)
                cls_col[i] = code
        class_names = [lab if lab else None for lab in labels]
        if extra_input_for is None:
            extra_in = None
        elif callable(extra_input_for):
            extra_in = np.fromiter((float(extra_input_for(i))
                                    for i in range(n)), np.float64, count=n)
        else:
            extra_in = np.asarray(extra_input_for, dtype=np.float64)
            if extra_in.shape != (n,):
                raise ValueError(f"extra_input_for array has shape "
                                 f"{extra_in.shape}, expected ({n},)")
        # Premodel columns (RNG-free, rid order, like sla_for/class_for).
        if feature_for is None:
            feats = None
        elif callable(feature_for):
            feats = np.asarray([feature_for(i) for i in range(n)],
                               dtype=np.float64)
        else:
            feats = np.asarray(feature_for, dtype=np.float64)
            if len(feats) != n:
                raise ValueError(f"feature_for array has {len(feats)} "
                                 f"rows, expected {n}")
        if premodel is not None:
            if feats is None:
                raise ValueError("premodel needs feature_for")
            if not hasattr(store, "observe_class"):
                raise ValueError("premodel routing needs a "
                                 "ConditionalProfileStore (got "
                                 f"{type(store).__name__})")
        if service_scale_for is None:
            svc_scale = None
        elif callable(service_scale_for):
            svc_scale = np.fromiter(
                (float(service_scale_for(i)) for i in range(n)),
                np.float64, count=n)
        else:
            svc_scale = np.asarray(service_scale_for, dtype=np.float64)
            if svc_scale.shape != (n,):
                raise ValueError(f"service_scale_for array has shape "
                                 f"{svc_scale.shape}, expected ({n},)")

        # Replica binding: int queues + live per-model μ for the O(1)
        # wait estimates (the index-based free-list replacing the
        # per-event object walks).
        mu_now: List[float] = [p.mu for p in profiles]
        self.pool.bind(names, cols.model, mu_now)
        replica_index = {id(r): i for i, r in enumerate(self.pool.replicas)}

        reasons: List[str] = [""]
        reason_code: Dict[str, int] = {"": 0}

        evq = EventQueue()
        completed: List[int] = []
        rejected: List[int] = []
        n_issued = 0
        if n > 0:
            evq.push(arrivals.first(rng), ARRIVAL, 0)
            n_issued = 1

        # Fault schedule: validated against this run's topology, then
        # pushed as FAULT events.  () schedules nothing — the queue and
        # every RNG stream are exactly the fair-weather run's.
        replica_by_name = {r.name: r for r in self.pool.replicas}
        for f in self.faults:
            if isinstance(f, ReplicaFault) and f.replica not in replica_by_name:
                raise ValueError(f"fault targets unknown replica "
                                 f"{f.replica!r} (pool: "
                                 f"{sorted(replica_by_name)})")
            if isinstance(f, LatencyDrift) and f.model not in truth:
                raise ValueError(f"drift targets unknown model "
                                 f"{f.model!r} (zoo: {names})")
        schedule_faults(evq, self.faults)
        net_scale = 1.0           # live RTT multiplier (NetworkDrift)
        # Elastic lifecycle (``sim/elastic.py``): the controller tick
        # rides the same queue as faults and requests.  The whole path
        # is draw-free — it never touches the RNG — and ``None`` skips
        # it entirely, keeping static-pool seeded runs bit-identical.
        elastic = self.elastic
        controller = make_controller(elastic) if elastic is not None \
            else None
        self._n_provisioned = 0
        self._n_decommissioned = 0
        track_wait = elastic is not None
        win_wait = [0.0, 0]       # window's observed start-waits (sum, n)
        last_busy = [0.0]         # pool busy-ms integral at the last tick
        drain_pending: Dict[int, Replica] = {}   # id -> draining victim
        tmpl_depth = self.pool.replicas[0].max_queue_depth
        if elastic is not None and n > 0:
            evq.push(elastic.control_interval_ms, CONTROL, None)
        retry = self.retry
        retries_c = cols.retries
        check_overrun = retry is not None and retry.reroute_on_overrun
        overrun_margin = retry.overrun_margin_ms if retry is not None else 0.0

        arrival_c, t_input_c, t_sla_c = cols.arrival, cols.t_input, cols.t_sla
        enq_c, sstart_c, service_c = cols.enqueue, cols.sstart, cols.service
        finish_c, depart_c = cols.finish, cols.depart
        model_c, replica_c, cls_c = cols.model, cols.replica, cols.cls
        icls_c = cols.icls
        fallback_c, rejected_c, reason_c = cols.fallback, cols.rejected, \
            cols.reason
        closed_loop = arrivals.closed_loop
        needs_waits = router.queue_aware or router.admission.needs_w_queue

        def start_service(replica: Replica, now: float) -> None:
            # With an armed overrun hedge, requests whose believed
            # service time no longer fits their remaining budget are
            # diverted to the recovery path instead of being served into
            # a certain miss; the loop walks the FIFO until one request
            # is serveable.  Without a retry policy the loop body runs
            # exactly once — op-for-op the historical single-pop path.
            pending_div: List[int] = []
            while replica.queue:
                rid = replica.pop_request()
                # A speculatively-routed request (lookahead batching) may
                # be popped before its uplink completes; service cannot
                # start before the input is on the server.  No-op without
                # lookahead.
                t_enq = enq_c[rid]
                t0 = now if now >= t_enq else t_enq
                mid = model_c[rid]
                if check_overrun:
                    remaining = (t_sla_c[rid] - 2.0 * t_input_c[rid]
                                 - (t0 - t_enq))
                    # The hedge consults the store's *live* belief (not
                    # the FINISH-synced mu_now cache): a staleness-decayed
                    # presented μ is an explicit invitation to re-probe,
                    # and vetoing it here would exile the model forever.
                    if profiles[mid].mu / replica.speed > \
                            remaining + overrun_margin:
                        pending_div.append(rid)
                        continue
                sstart_c[rid] = t0
                store.observe_queue(names[mid], t0 - t_enq)
                if track_wait:
                    win_wait[0] += t0 - t_enq
                    win_wait[1] += 1
                t_inf = svc.sample(rng, names[mid], replica.speed)
                if svc_scale is not None:
                    # The TRUE input class's latency effect (easy inputs
                    # run fast, hard ones slow) — a post-draw multiply,
                    # so the RNG stream matches scale-free runs.
                    t_inf *= svc_scale[rid]
                service_c[rid] = t_inf
                replica.current = rid
                replica.busy_until = t0 + t_inf
                evq.push(t0 + t_inf, FINISH, (replica, rid, replica.gen))
                break
            # Diversions are flushed after the serve decision so a
            # re-placement landing back on this replica re-enters
            # ``start_service`` against settled state (recursion is
            # bounded by the per-request attempt budget).
            for rid in pending_div:
                reroute(rid, now, "deadline overrun")

        def place(rid: int, mid: int, now: float) -> None:
            """Recovery placement: enqueue ``rid`` on the best live
            replica of model ``mid`` (reject when none survives)."""
            model_c[rid] = mid
            replica = self.pool.best_for(names[mid], now, store)
            if replica is None:
                reject(rid, "no live replica for " + names[mid],
                       max(now, enq_c[rid]), now)
                return
            replica_c[rid] = replica_index[id(replica)]
            if replica.full():
                reject(rid, "replica queue full", max(now, enq_c[rid]), now)
                return
            replica.enqueue(rid, mid)
            depth = replica.depth()
            if depth > replica.peak_depth:
                replica.peak_depth = depth
            if replica.current is None:
                start_service(replica, now)

        def reroute(rid: int, now: float, why: str) -> None:
            """Recovery path: replica failure or deadline overrun.  With
            attempts left, re-route to the cheapest still-viable model
            within the *remaining* budget (deterministic, draw-free —
            ``router.retry``); otherwise the request is rejected."""
            if retry is None or retries_c[rid] + 1 >= retry.max_attempts:
                reject(rid, why + (" (attempts exhausted)"
                                   if retry is not None else ""),
                       max(now, enq_c[rid]), now)
                return
            remaining = (t_sla_c[rid] - 2.0 * t_input_c[rid]
                         - (now - enq_c[rid]))
            mid = router.reroute_one(
                remaining, w_queue_map=self.pool.waits_by_name(now, store))
            if mid < 0:
                reject(rid, why + "; no viable model within the "
                       "remaining budget", max(now, enq_c[rid]), now)
                return
            retries_c[rid] += 1
            place(rid, int(mid), now)

        def issue_next_closed_loop(now: float) -> None:
            nonlocal n_issued
            if closed_loop and n_issued < n:
                evq.push(arrivals.next_after(rng, now, n_issued),
                         ARRIVAL, n_issued)
                n_issued += 1

        def reject(rid: int, reason: str, depart_ms: float,
                   now: float) -> None:
            rejected_c[rid] = True
            code = reason_code.get(reason)
            if code is None:
                code = reason_code[reason] = len(reasons)
                reasons.append(reason)
            reason_c[rid] = code
            depart_c[rid] = depart_ms
            rejected.append(rid)
            issue_next_closed_loop(now)

        # -- elastic lifecycle actions (scale decisions act here) -------
        def try_decommission(replica: Replica, now: float) -> None:
            """Drain-based scale-in completes: the victim's queue is
            empty and nothing is in flight — stop accruing cost.  Every
            request it held has finished; zero are lost."""
            if (id(replica) in drain_pending and replica.current is None
                    and not replica.queue):
                del drain_pending[id(replica)]
                replica.decommission(now)
                self._n_decommissioned += 1

        def provision(count: int, now: float) -> None:
            """Scale-up: ``count`` replicas born WARMING (not accepting,
            ``inf`` wait columns) — each flips to UP only when its ready
            event fires after ``cold_start_ms``."""
            for _ in range(count):
                r = Replica(name=f"e{self._n_provisioned}", models=(),
                            speed=1.0, max_queue_depth=tmpl_depth)
                r.start_warming(now)
                idx = self.pool.add_replica(r)
                replica_index[id(r)] = idx
                replica_by_name[r.name] = r
                self._n_provisioned += 1
                if elastic.cold_start_ms > 0.0:
                    evq.push(now + elastic.cold_start_ms, PROVISION,
                             ("ready", r, r.gen))
                else:
                    r.warm_ready()

        def scale_in(count: int, now: float) -> None:
            """Scale-in: cancel still-WARMING replicas first (newest
            first — they never served, and the bumped incarnation
            orphans their pending ready events), then drain the
            least-loaded accepting replicas; a drained victim
            decommissions only once its queue is empty."""
            warming = [r for r in reversed(self.pool.replicas)
                       if r.health == WARMING]
            for r in warming[:count]:
                r.gen += 1
                r.decommission(now)
                self._n_decommissioned += 1
            count -= min(count, len(warming))
            if count <= 0:
                return
            victims = sorted((r.depth(), -i, r) for i, r in
                             enumerate(self.pool.replicas) if r.accepting)
            for _, _, r in victims[:count]:
                r.drain()
                drain_pending[id(r)] = r
                try_decommission(r, now)    # already idle → gone now

        while evq:
            ev = evq.pop()
            now = ev.time

            if ev.kind == ARRIVAL:
                rid = ev.data
                arrival_c[rid] = now
                t_in = float(self.network.sample_one(rng))
                # NetworkDrift: scale after the draw so the RNG stream
                # is untouched (drift-free runs multiply by nothing).
                if net_scale != 1.0:
                    t_in *= net_scale
                if extra_in is not None:
                    # Cross-cell spill penalty: constant add after the
                    # draw, same RNG-neutrality rule as NetworkDrift.
                    t_in += extra_in[rid]
                t_input_c[rid] = t_in
                evq.push(now + t_in, ENQUEUE, rid)
                if not closed_loop and n_issued < n:
                    t_next = arrivals.next_after(rng, now, n_issued)
                    if t_next is not None:
                        evq.push(t_next, ARRIVAL, n_issued)
                        n_issued += 1

            elif ev.kind == ENQUEUE:
                # Group consecutive ENQUEUEs inside the batching window
                # into ONE route_batch call (vectorized selection).
                rid = ev.data
                enq_c[rid] = now
                batch: List[int] = [rid]
                limit = now + self.batch_window_ms
                while evq:
                    head = evq.peek()
                    if head.kind != ENQUEUE or head.time > limit:
                        break
                    nxt = evq.pop()
                    enq_c[nxt.data] = nxt.time
                    batch.append(nxt.data)
                # One charged-wait state per batch: every replica's wait
                # computed exactly once, handed to the router as live
                # per-replica columns (the router charges each admitted
                # pick's μ into it before judging the next request).
                # A batch of one has nothing within it to charge — and
                # uncharged batches judge one frozen snapshot — so both
                # take the cheap name->wait map instead of building a
                # per-replica ledger (the singleton path dominates
                # continuous-arrival runs; keep it allocation-lean).
                state = w_map = None
                if needs_waits:
                    if self.charge_batches and len(batch) > 1:
                        state = self.pool.charged_state(now)
                    else:
                        w_map = self.pool.waits_by_name(now, store)
                if premodel is not None:
                    # Classify at ENQUEUE — the premodel sees the
                    # feature vector the device sent, before selection.
                    # The stored id is the *belief at routing time*
                    # (classify before update), so the FINISH
                    # observation lands on the class that was routed on.
                    for r in batch:
                        icls_c[r] = premodel.classify(feats[r])
                        premodel.update(feats[r])
                if len(batch) == 1:
                    # Scalar fast path: tuple out, no BatchDecisions
                    # column set allocated per request (continuous
                    # arrivals make every batch a singleton, ~1M/run).
                    if premodel is not None:
                        store.set_class(int(icls_c[rid]))
                    try:
                        mid, fb, _w, reason = router.route_one(
                            t_sla_c[rid], t_input_c[rid], rng,
                            w_queue_map=w_map,
                            sla_class=(None if router._admits_all else
                                       class_names[cls_c[rid]]),
                            depth_fn=lambda m: min(r.depth() for r in
                                                   self.pool.candidates(m)))
                    finally:
                        if premodel is not None:
                            store.set_class(-1)
                    if mid < 0:
                        reject(rid, reason, enq_c[rid], now)
                        continue
                    model_c[rid] = mid
                    fallback_c[rid] = fb
                    replica = self.pool.best_for(names[mid], now, store)
                    if replica is None:
                        reject(rid, "no live replica for " + names[mid],
                               now, now)
                        continue
                    replica_c[rid] = replica_index[id(replica)]
                    if replica.full():
                        reject(rid, "replica queue full", now, now)
                        continue
                    replica.enqueue(rid, mid)
                    depth = replica.depth()
                    if depth > replica.peak_depth:
                        replica.peak_depth = depth
                    if replica.current is None:
                        start_service(replica, now)
                    continue
                # Array-in/array-out routing: budget/class columns in,
                # decision columns out — no per-request objects.
                if premodel is not None:
                    # Class-conditional batch: per-request class rows
                    # gathered from the stacked (K × pool) snapshot in
                    # one device call (snapshot wait semantics — the
                    # classed path has no charging ledger).
                    res = router.route_batch_classed(
                        t_sla_c[batch], t_input_c[batch], icls_c[batch],
                        rng,
                        w_queue_map=(state.as_map() if state is not None
                                     else w_map),
                        depth_fn=lambda m: min(r.depth() for r in
                                               self.pool.candidates(m)))
                else:
                    res = router.route_batch_arrays(
                        t_sla_c[batch], t_input_c[batch], rng,
                        sla_class=(None if router._admits_all else
                                   [class_names[cls_c[r]] for r in batch]),
                        charged=state, w_queue_map=w_map,
                        depth_fn=lambda m: min(r.depth() for r in
                                               self.pool.candidates(m)),
                        charge=self.charge_batches)
                pool_replicas = self.pool.replicas
                for j, rid in enumerate(batch):
                    if not res.admitted[j]:
                        # Router-side shed: no selection spent, no
                        # replica touched.
                        reject(rid, res.reason_of(j), enq_c[rid], now)
                        continue
                    mid = int(res.model_idx[j])
                    model_c[rid] = mid
                    fallback_c[rid] = res.fallback[j]
                    ridx = int(res.replica_idx[j])
                    if ridx >= 0 and pool_replicas[ridx].accepting:
                        # Charged placement: the replica the router's
                        # ledger charged this pick to.
                        replica = pool_replicas[ridx]
                    else:
                        # No charged placement — or the ledger's argmin
                        # landed on a dead replica (every candidate at
                        # inf): fall back to the live-pool pick.
                        replica = self.pool.best_for(names[mid], now,
                                                     store)
                        if replica is None:
                            reject(rid, "no live replica for " + names[mid],
                                   max(now, enq_c[rid]), now)
                            continue
                        ridx = replica_index[id(replica)]
                    replica_c[rid] = ridx
                    if replica.full():
                        # == now without lookahead; a speculatively-routed
                        # request cannot depart before its own enqueue.
                        reject(rid, "replica queue full",
                               max(now, enq_c[rid]), now)
                        continue
                    replica.enqueue(rid, mid)
                    depth = replica.depth()
                    if depth > replica.peak_depth:
                        replica.peak_depth = depth
                    if replica.current is None:
                        start_service(replica, now)

            elif ev.kind == FINISH:
                replica, rid, gen = ev.data
                if gen != replica.gen:
                    # Stale completion: the replica was killed (and its
                    # incarnation bumped) after this FINISH was pushed;
                    # the victim has already been rerouted or rejected.
                    continue
                finish_c[rid] = now
                replica.current = None
                replica.n_served += 1
                t_inf = float(service_c[rid])
                replica.busy_ms += t_inf
                mid = model_c[rid]
                if premodel is not None and icls_c[rid] >= 0:
                    # Class-attributed telemetry: feeds the request's
                    # believed class AND the pooled estimate.
                    store.observe_class(int(icls_c[rid]), names[mid], t_inf)
                else:
                    store.observe(names[mid], t_inf)
                mu_now[mid] = profiles[mid].mu
                # Cold-model refresh (§3.3): probe one stale model
                # out-of-band, as in the original closed loop.
                if self.cold_probe:
                    cold = store.cold_models()
                    if cold:
                        probe = cold[int(rng.integers(len(cold)))]
                        store.observe(probe, svc.sample(rng, probe))
                        mu_now[model_ids[probe]] = store.profiles[probe].mu
                        store.profiles[probe].last_selected = store.step
                evq.push(now + t_input_c[rid], DEPART, rid)
                if replica.queue:
                    start_service(replica, now)
                if drain_pending and replica.current is None \
                        and not replica.queue:
                    try_decommission(replica, now)

            elif ev.kind == DEPART:
                rid = ev.data
                depart_c[rid] = now
                completed.append(rid)
                if closed_loop and n_issued < n:
                    evq.push(arrivals.next_after(rng, now, n_issued),
                             ARRIVAL, n_issued)
                    n_issued += 1

            elif ev.kind == FAULT:
                f = ev.data
                if isinstance(f, ReplicaFault):
                    r = replica_by_name[f.replica]
                    if f.kind == "kill":
                        # Collect the in-flight request and the FIFO
                        # *before* the transition (kill() clears both and
                        # bumps the incarnation, orphaning the stale
                        # FINISH), then push every victim through the
                        # recovery path.
                        victims: List[int] = []
                        if r.current is not None:
                            victims.append(int(r.current))
                        while r.queue:
                            victims.append(r.pop_request())
                        r.kill(now)
                        for vid in victims:
                            reroute(vid, now, "replica failure")
                    elif f.kind == "degrade":
                        r.degrade(f.factor)
                    elif f.kind == "drain":
                        r.drain()
                    else:   # recover
                        r.recover(now)
                elif isinstance(f, LatencyDrift):
                    svc.set_drift(f.model, f.mu_mult, f.sigma_mult)
                else:       # NetworkDrift
                    net_scale = f.rtt_mult

            elif ev.kind == CONTROL:
                # Mid-run controller tick: one window of telemetry in,
                # one desired committed-replica count out.  Entirely
                # draw-free — the RNG stream is untouched.
                for r in list(drain_pending.values()):
                    try_decommission(r, now)
                wstats = router.window_stats()
                routed = max(int(wstats["n_routed"]), 1)
                # The observed start-wait mean lags a load step by a
                # queue's length (requests still waiting left no sample
                # yet), so pair it with the instantaneous backlog
                # estimate and act on whichever is worse.  The backlog
                # excludes each replica's in-service remainder: a lone
                # busy server with an empty queue is healthy, not a
                # scale-up signal.
                inst = []
                for r, w in zip(self.pool.replicas,
                                self.pool.wait_columns(now)):
                    if w == float("inf"):
                        continue
                    if r.current is not None:
                        w -= max(0.0, r.busy_until - now)
                    inst.append(w)
                obs = win_wait[0] / win_wait[1] if win_wait[1] else 0.0
                wait_sig = max(obs, float(np.mean(inst)) if inst else 0.0)
                serving = [r for r in self.pool.replicas
                           if r.health in (UP, DEGRADED)]
                busy_now = sum(r.busy_ms for r in self.pool.replicas)
                util = ((busy_now - last_busy[0])
                        / (max(len(serving), 1)
                           * elastic.control_interval_ms))
                reading = ControlReading(
                    mean_queue_wait_ms=wait_sig,
                    shed_rate=wstats["n_shed"] / routed,
                    fallback_rate=wstats["n_fallback"] / routed,
                    utilization=util,
                    n_routed=int(wstats["n_routed"]))
                # WARMING replicas count as committed capacity — they
                # are already paid for and about to come up; excluding
                # them would double-provision through every cold start.
                n_ctl = len(serving) + sum(
                    1 for r in self.pool.replicas if r.health == WARMING)
                desired = controller.target(n_ctl, reading)
                if desired > n_ctl:
                    evq.push(now, PROVISION, ("create", desired - n_ctl))
                elif desired < n_ctl:
                    scale_in(n_ctl - desired, now)
                last_busy[0] = busy_now
                win_wait[0] = 0.0
                win_wait[1] = 0
                if len(completed) + len(rejected) < n:
                    evq.push(now + elastic.control_interval_ms,
                             CONTROL, None)

            elif ev.kind == PROVISION:
                if ev.data[0] == "create":
                    provision(ev.data[1], now)
                else:
                    _, r, gen = ev.data
                    if r.gen == gen:
                        # Cold start complete: WARMING -> UP.  A bumped
                        # incarnation means the replica was cancelled
                        # while warming — it never serves.
                        r.warm_ready()

        # Per-run request records stay inspectable (per-SLA-class slicing
        # in tests and frontier studies reads them after run()) —
        # materialized lazily from the columns on first access.
        self._cols = cols
        self._completed_rids = completed
        self._rejected_rids = rejected
        self._model_names = names
        self._replica_names = [r.name for r in self.pool.replicas]
        self._class_labels = labels
        self._reasons = reasons
        self._completed_objs = None
        self._rejected_objs = None
        return self._summarise_cols(router.name, t_sla, truth, cols,
                                    completed, rejected, labels)

    # ------------------------------------------------------------------
    # lazy SimRequest materialization from the SoA columns
    # ------------------------------------------------------------------
    def _make_request(self, rid: int) -> SimRequest:
        c = self._cols
        mid = int(c.model[rid])
        rep = int(c.replica[rid])
        return SimRequest(
            rid=rid,
            arrival_ms=float(c.arrival[rid]),
            t_input_ms=float(c.t_input[rid]),
            t_sla_ms=float(c.t_sla[rid]),
            sla_class=self._class_labels[int(c.cls[rid])],
            model=self._model_names[mid] if mid >= 0 else "",
            replica=self._replica_names[rep] if rep >= 0 else "",
            fallback=bool(c.fallback[rid]),
            rejected=bool(c.rejected[rid]),
            reject_reason=self._reasons[int(c.reason[rid])],
            enqueue_ms=float(c.enqueue[rid]),
            service_start_ms=float(c.sstart[rid]),
            service_ms=float(c.service[rid]),
            finish_ms=float(c.finish[rid]),
            depart_ms=float(c.depart[rid]),
            retries=int(c.retries[rid]))

    @property
    def completed_requests(self) -> List[SimRequest]:
        if self._completed_objs is None:
            self._completed_objs = [self._make_request(r)
                                    for r in self._completed_rids]
        return self._completed_objs

    @property
    def rejected_requests(self) -> List[SimRequest]:
        if self._rejected_objs is None:
            self._rejected_objs = [self._make_request(r)
                                   for r in self._rejected_rids]
        return self._rejected_objs

    # ------------------------------------------------------------------
    def attainment_timeline(self, bucket_ms: float = 10_000.0
                            ) -> List[Dict[str, float]]:
        """Post-run time series over ``bucket_ms`` windows of enqueue
        time: one row per bucket with SLA attainment (rejections count
        as misses, like the run-level number), shed rate, mean accuracy
        over the bucket's completions, and recovery re-placements.  The
        dip-and-recovery chart of ``benchmarks/drift_resilience.py``
        reads this directly."""
        c = self._cols
        assert c is not None, "attainment_timeline requires a prior run()"
        ci = np.asarray(self._completed_rids, dtype=np.int64)
        rj = np.asarray(self._rejected_rids, dtype=np.int64)
        last = 0.0
        if len(ci):
            last = float(c.enqueue[ci].max())
        if len(rj):
            last = max(last, float(c.enqueue[rj].max()))
        n_b = int(last // bucket_ms) + 1
        total = np.zeros(n_b)
        met = np.zeros(n_b)
        shed = np.zeros(n_b)
        acc = np.zeros(n_b)
        done = np.zeros(n_b)
        retr = np.zeros(n_b)
        acc_by_id = np.array([e.top1 / 100.0 for e in self.entries])
        if len(ci):
            b = (c.enqueue[ci] // bucket_ms).astype(np.int64)
            e2e = (2.0 * c.t_input[ci] + (c.sstart[ci] - c.enqueue[ci])
                   + c.service[ci])
            np.add.at(total, b, 1.0)
            np.add.at(done, b, 1.0)
            np.add.at(met, b, (e2e <= c.t_sla[ci]).astype(np.float64))
            np.add.at(acc, b, acc_by_id[c.model[ci]])
            np.add.at(retr, b, c.retries[ci].astype(np.float64))
        if len(rj):
            b = (c.enqueue[rj] // bucket_ms).astype(np.int64)
            np.add.at(total, b, 1.0)
            np.add.at(shed, b, 1.0)
            np.add.at(retr, b, c.retries[rj].astype(np.float64))
        rows: List[Dict[str, float]] = []
        for i in range(n_b):
            n_i = total[i]
            if n_i == 0:
                continue
            rows.append({
                "t_ms": i * bucket_ms,
                "n": int(n_i),
                "attainment": float(met[i] / n_i),
                "shed_rate": float(shed[i] / n_i),
                "accuracy": float(acc[i] / done[i]) if done[i] else 0.0,
                "retries": int(retr[i]),
            })
        return rows

    # ------------------------------------------------------------------
    # SoA summary: every statistic is a vectorized reduction over the
    # request columns (sliced in completion order, matching the
    # historical per-object iteration element for element).
    # ------------------------------------------------------------------
    def _summarise(self, policy_name, t_sla, truth, completed, rejected
                   ) -> LoadSimResult:
        """Back-compat entry point over ``SimRequest`` object lists
        (tests and external harnesses call it directly): packs the
        objects into columns and defers to the vectorized summary."""
        objs = list(completed) + list(rejected)
        cols = _Columns(len(objs))
        model_ids = {name: i for i, name in enumerate(truth)}
        labels: List[str] = [""]
        code_of = {"": 0}
        for i, r in enumerate(objs):
            cols.arrival[i] = r.arrival_ms
            cols.t_input[i] = r.t_input_ms
            cols.t_sla[i] = r.t_sla_ms
            cols.enqueue[i] = r.enqueue_ms
            cols.sstart[i] = r.service_start_ms
            cols.service[i] = r.service_ms
            cols.finish[i] = r.finish_ms
            cols.depart[i] = r.depart_ms
            cols.model[i] = model_ids.get(r.model, -1)
            cols.rejected[i] = r.rejected
            code = code_of.get(r.sla_class)
            if code is None:
                code = code_of[r.sla_class] = len(labels)
                labels.append(r.sla_class)
            cols.cls[i] = code
        return self._summarise_cols(policy_name, t_sla, truth, cols,
                                    list(range(len(completed))),
                                    list(range(len(completed), len(objs))),
                                    labels)

    def _summarise_cols(self, policy_name, t_sla, truth, cols: _Columns,
                        completed: List[int], rejected: List[int],
                        labels: List[str]) -> LoadSimResult:
        n_arrived = len(completed) + len(rejected)
        acc_of = {name: e.top1 / 100.0 for name, e in truth.items()}
        rj = np.asarray(rejected, dtype=np.int64)
        per_class = self._per_class_cols(cols, completed, rejected, labels,
                                         truth, acc_of)
        n_retries = int(cols.retries.sum())
        if not completed:
            if len(rj):
                first = float(cols.arrival[rj].min())
                last = float(cols.depart[rj].max())
            else:
                first = last = 0.0
            rep_s, live_util = self._elastic_cost(first, last)
            return LoadSimResult(
                policy=policy_name, t_sla=t_sla,
                n_arrived=n_arrived, n_completed=0, n_rejected=len(rejected),
                sla_attainment=0.0, mean_accuracy=0.0, mean_latency=0.0,
                p50_latency=0.0, p99_latency=0.0, mean_queue_wait=0.0,
                p99_queue_wait=0.0, peak_queue_depth=0, model_usage={},
                replica_utilization={}, per_class=per_class,
                n_retries=n_retries,
                replica_seconds=rep_s, mean_live_utilization=live_util,
                n_provisioned=self._n_provisioned,
                n_decommissioned=self._n_decommissioned)
        model_ids = {name: i for i, name in enumerate(truth)}
        ci = np.asarray(completed, dtype=np.int64)
        t_input = cols.t_input[ci]
        wait = cols.sstart[ci] - cols.enqueue[ci]
        service = cols.service[ci]
        model = cols.model[ci]
        # Component sum, identical to SimRequest.e2e_ms per element.
        e2e = 2.0 * t_input + wait + service
        # Scored against each request's own SLA (identical to the scalar
        # comparison when every request carries the run-level t_sla).
        met = int((e2e <= cols.t_sla[ci]).sum())
        acc_by_id = np.array([e.top1 / 100.0 for e in truth.values()])
        counts = np.bincount(model, minlength=len(model_ids))
        usage = {name: int(counts[i]) for name, i in model_ids.items()
                 if counts[i]}
        # Horizon spans *every* request the pool saw — rejected ones
        # included, so utilization is not inflated under heavy shedding
        # (a shed request still occupies wall-clock on the timeline).
        first = float(cols.arrival[ci].min())
        last = float(cols.depart[ci].max())
        if len(rj):
            first = min(first, float(cols.arrival[rj].min()))
            last = max(last, float(cols.depart[rj].max()))
        horizon = max(last - first, 1e-9)
        rep_s, live_util = self._elastic_cost(first, last)
        return LoadSimResult(
            policy=policy_name, t_sla=t_sla,
            n_arrived=n_arrived, n_completed=len(completed),
            n_rejected=len(rejected),
            sla_attainment=met / max(n_arrived, 1),
            mean_accuracy=float(np.mean(acc_by_id[model])),
            mean_latency=float(e2e.mean()),
            p50_latency=float(np.percentile(e2e, 50)),
            p99_latency=float(np.percentile(e2e, 99)),
            p95_latency=float(np.percentile(e2e, 95)),
            mean_queue_wait=float(wait.mean()),
            p99_queue_wait=float(np.percentile(wait, 99)),
            p95_queue_wait=float(np.percentile(wait, 95)),
            peak_queue_depth=max(r.peak_depth for r in self.pool.replicas),
            model_usage={k: v / len(completed)
                         for k, v in sorted(usage.items())},
            replica_utilization={r.name: r.busy_ms / horizon
                                 for r in self.pool.replicas},
            horizon_ms=horizon,
            per_class=per_class,
            n_retries=n_retries,
            replica_seconds=rep_s, mean_live_utilization=live_util,
            n_provisioned=self._n_provisioned,
            n_decommissioned=self._n_decommissioned)

    def _elastic_cost(self, first: float, last: float):
        """Replica-seconds (committed window ∩ horizon, minus dead time,
        summed over the pool — the frontier's cost axis) and the
        alive-window-normalized mean utilization.  On a static
        fault-free pool: exactly n × horizon and the plain mean of
        ``replica_utilization``."""
        alive = [r.alive_ms(first, last) for r in self.pool.replicas]
        live = [r.busy_ms / a for r, a in zip(self.pool.replicas, alive)
                if a > 1e-9]
        return (sum(alive) / 1000.0,
                float(np.mean(live)) if live else 0.0)

    def committed_replica_count(self) -> int:
        """Replicas still accruing cost and able to (eventually) serve —
        UP, DEGRADED, or WARMING.  The scenario harness carries this
        across epochs when a mid-run controller resizes the pool."""
        return sum(1 for r in self.pool.replicas
                   if r.health in (UP, DEGRADED, WARMING))

    @staticmethod
    def _per_class_cols(cols: _Columns, completed: List[int],
                        rejected: List[int], labels: List[str],
                        truth, acc_of) -> Dict[str, Dict[str, float]]:
        """Class-sliced attainment/accuracy/shed rows, vectorized over
        the record columns; {} when no request carried a class label
        (the common single-class run)."""
        ci = np.asarray(completed, dtype=np.int64)
        rj = np.asarray(rejected, dtype=np.int64)
        cc = cols.cls[ci] if len(ci) else np.empty(0, np.int32)
        rc = cols.cls[rj] if len(rj) else np.empty(0, np.int32)
        seen = set(np.unique(cc)) | set(np.unique(rc))
        seen = {int(c) for c in seen if labels[int(c)]}
        if not seen:
            return {}
        acc_by_id = np.array([acc_of[name] for name in truth])
        t_input = cols.t_input[ci]
        wait = cols.sstart[ci] - cols.enqueue[ci]
        e2e = 2.0 * t_input + wait + cols.service[ci]
        met_mask = e2e <= cols.t_sla[ci]
        out: Dict[str, Dict[str, float]] = {}
        # All arrived requests carry a code (unlabelled == code 0 == "");
        # classes are reported in sorted label order, like the legacy
        # per-object slicing.
        present = sorted({labels[int(c)] for c in
                          set(np.unique(cc)) | set(np.unique(rc))})
        for lab in present:
            code = labels.index(lab)
            dmask = cc == code
            n_done = int(dmask.sum())
            n_shed = int((rc == code).sum())
            n_cls = n_done + n_shed
            out[lab or "default"] = {
                "n_arrived": n_cls,
                "n_rejected": n_shed,
                "shed_rate": n_shed / max(n_cls, 1),
                "attainment": int(met_mask[dmask].sum()) / max(n_cls, 1),
                "accuracy": (float(np.mean(acc_by_id[cols.model[ci][dmask]]))
                             if n_done else 0.0),
                "mean_latency": (float(np.mean(e2e[dmask]))
                                 if n_done else 0.0),
            }
        return out


def rate_sweep(sim: ServingSimulator, policy_fn, rates_rps: Sequence[float],
               t_sla: float, n_requests: int = 2000) -> List[LoadSimResult]:
    """Arrival-rate sweep: SLA attainment vs offered load.

    ``policy_fn()`` builds a fresh policy per point (stateful policies
    like ``StaticGreedy`` must not leak across runs)."""
    from repro.sim.arrivals import PoissonArrivals
    return [sim.run(policy_fn(), t_sla, n_requests,
                    arrivals=PoissonArrivals(rate))
            for rate in rates_rps]
